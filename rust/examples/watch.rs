//! Push-based event watching demo: start an in-process head service with
//! the event bus armed, subscribe to the SSE feed with
//! [`idds::rest::Client::watch_events`], submit a workflow, and print every
//! event the pipeline commits while [`idds::rest::Client::wait_request`]
//! blocks — push-driven, no polling loop — until the request finishes.
//!
//!     cargo run --release --example watch

use std::sync::Arc;
use std::time::Duration;

use idds::broker::Broker;
use idds::config::Config;
use idds::daemons::executors::{ExecutorSet, NoopExecutor};
use idds::daemons::{AgentHost, Daemon, Pipeline};
use idds::metrics::Registry;
use idds::persist::{BusPersister, EventBus};
use idds::rest::{serve, Client, ServerState};
use idds::store::{RequestKind, Store};
use idds::util::clock::WallClock;
use idds::workflow::{Condition, WorkTemplate, Workflow};

fn main() -> anyhow::Result<()> {
    // in-memory head stack with the bus published from the apply path
    // (a durable deployment publishes from the WAL flusher instead)
    let clock = Arc::new(WallClock::new());
    let store = Store::new(clock.clone());
    let broker = Broker::new(clock);
    let metrics = Registry::default();
    let bus = EventBus::new(&metrics);
    store.set_persister(Arc::new(BusPersister::new(bus.clone())));
    broker.set_persister(Arc::new(BusPersister::new(bus.clone())));

    let executors =
        ExecutorSet::default().with(idds::workflow::WorkKind::Noop, Arc::new(NoopExecutor::default()));
    let pipeline = Pipeline::new(store.clone(), broker.clone(), metrics.clone(), executors)
        .with_bus(bus.clone());
    let (c, m, t, ca, co) = pipeline.daemons();
    let daemons: Vec<Arc<dyn Daemon>> =
        vec![Arc::new(c), Arc::new(m), Arc::new(t), Arc::new(ca), Arc::new(co)];
    // bus-armed: daemons sleep until a table in their interest set commits
    let host = AgentHost::start_with_bus(
        daemons,
        Duration::from_millis(2),
        Duration::from_millis(500),
        Some(&bus),
    );

    let cfg = Config::defaults();
    let server = serve(
        ServerState::new(store, broker, metrics, &cfg).with_bus(bus.clone()),
        &cfg,
    )?;
    println!("head service on {}", server.addr);

    // a second connection tails the full firehose and prints everything
    let tail = Client::new(server.addr, "dev-token");
    let printer = std::thread::spawn(move || {
        let Ok(watch) = tail.watch_events(None, None) else { return };
        for ev in watch {
            let Ok(ev) = ev else { break };
            println!("  [{:>4}] {:<20} {}", ev.lsn, ev.op, ev.data);
        }
    });

    let client = Client::new(server.addr, "dev-token");
    let wf = Workflow::new("watch-demo")
        .add_template(WorkTemplate::new("prep"))
        .add_template(WorkTemplate::new("main"))
        .add_condition(Condition::always("prep", "main"))
        .entry("prep");
    let req = client.submit("watch-demo", "alice", RequestKind::Workflow, &wf)?;
    println!("submitted request {req}; waiting push-driven ...");
    let status = client.wait_request(req, Duration::from_secs(30))?;
    println!("request {req} -> {status}");

    // give the printer a beat to drain the tail of the feed, then stop
    std::thread::sleep(Duration::from_millis(200));
    host.stop();
    server.stop();
    drop(printer); // detach: the watch ends when the server closes it
    Ok(())
}
