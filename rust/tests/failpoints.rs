//! Degraded-write drills driven by the `persist::failpoints` sites: the
//! rarest inputs the durability stack handles — fsync failures, write
//! failures, checkpoint publish failures, silently-truncated checkpoint
//! files — forced on demand, and the promised behavior asserted end to
//! end: `persist.sync_submit` answers 503 (never a false 201, never a
//! hang), health surfaces the sticky `persist.io_error`, write errors
//! rotate to a fresh segment so later batches stay reachable, a failed
//! checkpoint publish restores the dirty sets for the next attempt, and
//! a truncated checkpoint is sidelined as `.corrupt` at recovery.
//!
//! Failpoints are process-global, so every test takes the same guard:
//! it serializes the tests in this binary and disarms everything on drop
//! (panic included).

use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};

use idds::broker::Broker;
use idds::config::Config;
use idds::metrics::Registry;
use idds::persist::{failpoints, FsyncMode, Persist, PersistOptions};
use idds::rest::http::http_request;
use idds::rest::{serve, ServerState};
use idds::store::{RequestKind, Store};
use idds::util::clock::WallClock;
use idds::util::json::{parse, Json};

struct FpGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for FpGuard {
    fn drop(&mut self) {
        failpoints::disarm_all();
    }
}

fn serial() -> FpGuard {
    static GATE: Mutex<()> = Mutex::new(());
    FpGuard(GATE.lock().unwrap_or_else(|p| p.into_inner()))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "idds-fp-{tag}-{}-{}",
        std::process::id(),
        idds::util::next_id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn opts() -> PersistOptions {
    PersistOptions {
        segment_bytes: 16 * 1024,
        fsync: FsyncMode::Group, // fsync paths must be live here
        checkpoint_keep: 2,
        flush_idle_ms: 2,
        ..PersistOptions::default()
    }
}

fn store() -> Store {
    Store::new(Arc::new(WallClock::new()))
}

fn canon(mut snap: Json) -> Json {
    if let Json::Obj(m) = &mut snap {
        for arr in m.values_mut() {
            if let Json::Arr(a) = arr {
                a.sort_by_key(|row| row.get("id").and_then(|v| v.as_u64()).unwrap_or(0));
            }
        }
    }
    snap
}

fn submit_body() -> String {
    let wf = idds::workflow::Workflow::new("w")
        .add_template(idds::workflow::WorkTemplate::new("a"))
        .entry("a");
    Json::obj()
        .set("name", "fp")
        .set("requester", "u")
        .set("workflow", wf.to_json())
        .to_string()
}

#[test]
fn injected_fsync_failure_degrades_sync_submit_to_503() {
    let _g = serial();
    let dir = tmp_dir("fsync503");
    let s = store();
    let broker = Broker::new(Arc::new(WallClock::new()));
    let (persist, _) =
        Persist::open_with_broker(&dir, opts(), &s, Some(&broker), Registry::default()).unwrap();
    let mut cfg = Config::defaults();
    cfg.apply_override("persist.sync_submit=true").unwrap();
    let server = serve(
        ServerState::new(s.clone(), broker, Registry::default(), &cfg)
            .with_persist(persist.clone()),
        &cfg,
    )
    .unwrap();
    let auth = [("Authorization", "Bearer dev-token"), ("Content-Type", "application/json")];
    let body = submit_body();

    // healthy head: synchronous submit acknowledges with 201
    let (st, _) =
        http_request(server.addr, "POST", "/api/requests", &auth, body.as_bytes()).unwrap();
    assert_eq!(st, 201);

    // one injected fsync failure: the event's bytes reach the file but
    // durability is unacknowledged — the submit must degrade to a 503,
    // not hang on the flusher and not claim a durable 201
    failpoints::arm("wal.fsync", Some(1));
    let (st, resp) =
        http_request(server.addr, "POST", "/api/requests", &auth, body.as_bytes()).unwrap();
    assert_eq!(st, 503, "degraded write must 503: {:?}", String::from_utf8_lossy(&resp));

    // the error is sticky: later submits stay 503 even though their own
    // fsync would succeed, until an operator intervenes
    let (st, _) =
        http_request(server.addr, "POST", "/api/requests", &auth, body.as_bytes()).unwrap();
    assert_eq!(st, 503);

    // and health tells the operator why
    let (st, resp) = http_request(server.addr, "GET", "/api/health", &[], b"").unwrap();
    assert_eq!(st, 200);
    let health = parse(std::str::from_utf8(&resp).unwrap()).unwrap();
    assert!(
        health.get_path(&["persist", "io_error"]).and_then(|v| v.as_str()).is_some(),
        "health must surface the sticky io_error"
    );

    // recovery after the fault clears: every 503'd submit was written
    // before its failed fsync, so recover == live — nothing acknowledged
    // was lost and nothing written is missing
    let live = canon(s.snapshot());
    assert_eq!(s.counts().get("requests").and_then(|v| v.as_u64()), Some(3));
    server.stop();
    persist.shutdown();
    failpoints::disarm_all();
    let s2 = store();
    let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
    assert!(report.events_replayed > 0);
    assert_eq!(canon(s2.snapshot()), live);
    p2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn injected_write_failure_is_sticky_and_rotates_to_a_fresh_segment() {
    let _g = serial();
    let dir = tmp_dir("writerot");
    let s = store();
    let (persist, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();

    let a = s.add_request("alpha", "u", RequestKind::Workflow, Json::Null);
    persist.flush();
    assert!(persist.wal().io_error().is_none());
    let segments_before = persist.wal().segment_count();

    // the failing batch is lost (its segment may end in a torn frame),
    // the error goes sticky, and the writer rotates so later batches
    // land in a fresh segment instead of behind a poisoned tail
    failpoints::arm("wal.write", Some(1));
    let b = s.add_request("bravo", "u", RequestKind::Workflow, Json::Null);
    persist.flush();
    assert!(persist.wal().io_error().is_some(), "write failure must stick");
    assert!(persist.wal().segment_count() > segments_before, "rotated after the error");

    failpoints::disarm_all();
    let c = s.add_request("charlie", "u", RequestKind::Workflow, Json::Null);
    persist.flush();
    persist.shutdown();

    // recovery: everything around the lost batch survives — the rotation
    // kept charlie's frame out of the torn segment's shadow
    let s2 = store();
    let (p2, _) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
    assert_eq!(s2.get_request(a).unwrap().name, "alpha");
    assert!(s2.get_request(b).is_err(), "the failed batch is lost, by design");
    assert_eq!(s2.get_request(c).unwrap().name, "charlie");
    p2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn injected_checkpoint_publish_failure_restores_dirty_sets() {
    let _g = serial();
    let dir = tmp_dir("ckptrename");
    let s = store();
    let (persist, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();

    // a base, then dirty rows on top of it
    s.add_request("base-row", "u", RequestKind::Workflow, Json::Null);
    persist.flush();
    persist.checkpoint_full(&s).unwrap();
    let rid = s.add_request("delta-row", "u", RequestKind::Workflow, Json::Null);
    persist.flush();

    // publish fails at the atomic rename: the delta must error out AND
    // put the drained dirty ids back, or the next delta would silently
    // skip these rows
    failpoints::arm("checkpoint.rename", Some(1));
    assert!(persist.checkpoint_delta(&s).is_err());

    let report = persist.checkpoint_delta(&s).unwrap();
    assert!(!report.full);
    assert!(report.rows >= 1, "restored dirty rows written by the retry, got {}", report.rows);

    // the tmp file from the failed publish is swept at the next open and
    // the recovered store matches the live one
    let live = canon(s.snapshot());
    s.update_request_status(rid, idds::store::RequestStatus::Cancelled).unwrap();
    let live_after = canon(s.snapshot());
    assert_ne!(live, live_after);
    persist.flush();
    persist.shutdown();
    let s2 = store();
    let (p2, _) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
    assert_eq!(canon(s2.snapshot()), live_after);
    assert!(
        std::fs::read_dir(&dir).unwrap().all(|e| {
            let p = e.unwrap().path();
            p.extension().map(|x| x != "tmp").unwrap_or(true)
        }),
        "failed-publish tmp files are swept at open"
    );
    p2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_checkpoint_is_sidelined_and_recovery_falls_back() {
    let _g = serial();
    let dir = tmp_dir("ckptcorrupt");
    let s = store();
    let (persist, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();

    s.add_request("one", "u", RequestKind::Workflow, Json::Null);
    persist.flush();
    persist.checkpoint_full(&s).unwrap(); // good base

    s.add_request("two", "u", RequestKind::Workflow, Json::Null);
    persist.flush();
    // this base "succeeds" but its body is truncated on disk — the shape
    // of a torn-at-power-loss or bit-rotted checkpoint file
    failpoints::arm("checkpoint.corrupt", Some(1));
    persist.checkpoint_full(&s).unwrap();

    let live = canon(s.snapshot());
    persist.shutdown();
    failpoints::disarm_all();

    // recovery must refuse the truncated base, set it aside as .corrupt,
    // and fold the older base + WAL suffix back to the live state (WAL
    // retention keeps segments back to the oldest *retained* base cut)
    let s2 = store();
    let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
    assert_eq!(canon(s2.snapshot()), live, "fallback recovery must equal live");
    assert!(report.checkpoint_seq.is_some());
    let sidelined = std::fs::read_dir(&dir).unwrap().any(|e| {
        e.unwrap().path().extension().map(|x| x == "corrupt").unwrap_or(false)
    });
    assert!(sidelined, "the truncated checkpoint must be set aside as .corrupt");
    p2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Worker-side fault mid-lease: a worker finishes a Work but the
/// `worker.complete` failpoint eats the report — the crash-in-the-gap
/// between doing the work and telling the head. The lease must expire,
/// the Work must redeliver to a healthy worker, and the head must accept
/// exactly ONE completion for it — no duplicate transform-status
/// transition, however many times the Work actually executed.
#[test]
fn worker_complete_fault_redelivers_without_duplicate_completion() {
    let _g = serial();
    use idds::broker::lease::WorkerRegistry;
    use idds::daemons::executors::{ExecutorSet, NoopExecutor, RemoteExecutor};
    use idds::daemons::{AgentHost, Daemon, Pipeline};
    use idds::workflow::{WorkKind, WorkTemplate, Workflow};

    // head: store + broker (short lease timeout so the drill runs in
    // milliseconds) + registry + the full daemon pipeline, with Noop
    // delegated to the remote fleet — the same wiring cmd_serve does
    // under workers.remote_kinds=Noop
    let clock = Arc::new(WallClock::new());
    let s = store();
    let broker = Broker::new(clock.clone()).with_redelivery_timeout(0.3);
    let metrics = Registry::default();
    let registry = WorkerRegistry::new(broker.clone(), clock, metrics.clone());
    let executors = ExecutorSet::default().with(
        WorkKind::Noop,
        Arc::new(RemoteExecutor::new(registry.clone(), WorkKind::Noop)),
    );
    let pipeline = Pipeline::new(s.clone(), broker.clone(), metrics.clone(), executors);
    let (clerk, marsh, tfr, carrier, conductor) = pipeline.daemons();
    let daemons: Vec<Arc<dyn Daemon>> = vec![
        Arc::new(clerk),
        Arc::new(marsh),
        Arc::new(tfr),
        Arc::new(carrier),
        Arc::new(conductor),
    ];
    let host = AgentHost::start(daemons, std::time::Duration::from_millis(2));
    let cfg = Config::defaults();
    let server = serve(
        ServerState::new(s.clone(), broker, metrics.clone(), &cfg)
            .with_workers(registry.clone()),
        &cfg,
    )
    .unwrap();

    // the next completion report — whichever worker thread gets there
    // first — is dropped on the floor
    failpoints::arm("worker.complete", Some(1));

    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut workers = Vec::new();
    for name in ["fp-worker-a", "fp-worker-b"] {
        let stop = stop.clone();
        let addr = server.addr;
        workers.push(std::thread::spawn(move || {
            let client = idds::rest::Client::new(addr, "dev-token");
            let executors = ExecutorSet::default()
                .with(WorkKind::Noop, Arc::new(NoopExecutor::default()));
            let opts = idds::worker::WorkerOptions {
                name: name.to_string(),
                heartbeat_s: 0.05,
                lease_batch: 2,
                idle_sleep_ms: 5,
            };
            idds::worker::run(&client, &executors, &opts, &stop).unwrap()
        }));
    }

    let client = idds::rest::Client::new(server.addr, "dev-token");
    let wf = Workflow::new("w").add_template(WorkTemplate::new("a")).entry("a");
    let id = client.submit("fp-remote", "u", RequestKind::Workflow, &wf).unwrap();
    // the campaign completes despite the eaten report: the lease expired
    // and the Work redelivered to a worker whose report got through
    let status = client.wait_terminal(id, std::time::Duration::from_secs(30)).unwrap();
    assert_eq!(status, idds::store::RequestStatus::Finished);

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let stats: Vec<idds::worker::WorkerStats> =
        workers.into_iter().map(|w| w.join().unwrap()).collect();
    let faulted: u64 = stats.iter().map(|st| st.faulted).sum();
    let completed: u64 = stats.iter().map(|st| st.completed).sum();
    assert_eq!(faulted, 1, "exactly one report was eaten: {stats:?}");
    assert_eq!(completed, 1, "the redelivered Work completed exactly once: {stats:?}");
    assert_eq!(
        metrics.counter("workers.completions_accepted").get(),
        1,
        "one accepted completion → one transform-status transition"
    );
    assert_eq!(
        metrics.counter("workers.completions_rejected").get(),
        0,
        "nobody even attempted a duplicate"
    );
    host.stop();
    server.stop();
}

#[test]
fn failpoints_armed_from_persist_options_spec() {
    let _g = serial();
    let dir = tmp_dir("spec");
    let s = store();
    // the `persist.failpoints` config string arms sites at open — the
    // operator-facing chaos-drill path (no code changes, just config)
    let o = PersistOptions { failpoints: "wal.write=1".into(), ..opts() };
    let (persist, _) = Persist::open(&dir, o, &s, Registry::default()).unwrap();
    s.add_request("doomed", "u", RequestKind::Workflow, Json::Null);
    persist.flush();
    assert!(persist.wal().io_error().is_some(), "spec-armed site must fire");
    persist.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
