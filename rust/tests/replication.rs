//! WAL-shipping replication integration tests: two full head stacks
//! (store + broker + persist + REST server) in one process over real
//! sockets. Covered here:
//!
//! * the ship endpoint serves CRC-framed durable WAL bytes with epoch +
//!   durable-LSN headers;
//! * the flagship failover: primary runs a campaign, a warm standby
//!   follows over REST, the primary dies mid-flight, the standby is
//!   promoted and `recover == live` holds across the ship/promote
//!   boundary — then the standby's daemons finish the campaign;
//! * fencing: promoting next to a *live* old primary fences it (writes
//!   503, direct WAL appends dropped with a sticky io_error, FENCED
//!   marker on disk, stale-epoch ship requests 409);
//! * a standby 503s every mutating route and reports lag in health;
//! * snapshot bootstrap when the primary pruned the history a fresh
//!   standby would need (410 → snapshot → frames);
//! * standby restart resumes from its local WAL copy (no re-bootstrap).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use idds::broker::Broker;
use idds::config::Config;
use idds::daemons::executors::{ExecutorSet, NoopExecutor};
use idds::daemons::{AgentHost, Daemon, Pipeline};
use idds::metrics::Registry;
use idds::persist::replicate::{read_epoch, read_fenced, write_epoch};
use idds::persist::wal::decode_frames;
use idds::persist::{
    ClusterState, FsyncMode, Persist, PersistOptions, Replica, ReplicationOptions,
};
use idds::rest::http::{http_request, http_request_full, HttpServer};
use idds::rest::{serve, Client, ServerState};
use idds::store::{RequestKind, RequestStatus, Store};
use idds::util::clock::WallClock;
use idds::util::json::{parse, Json};
use idds::workflow::{Condition, WorkKind, WorkTemplate, Workflow};

const TOKEN: &str = "dev-token";
const AUTH: &str = "Bearer dev-token";

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "idds-repl-{tag}-{}-{}",
        std::process::id(),
        idds::util::next_id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn opts() -> PersistOptions {
    PersistOptions {
        segment_bytes: 16 * 1024, // small: ship spans segment rotations
        fsync: FsyncMode::Never,
        checkpoint_keep: 2,
        flush_idle_ms: 2,
        ..PersistOptions::default()
    }
}

fn ropts() -> ReplicationOptions {
    ReplicationOptions { poll_interval_ms: 2, batch_bytes: 8 * 1024, retry_ms: 10 }
}

fn two_step() -> Workflow {
    Workflow::new("two-step")
        .add_template(WorkTemplate::new("a"))
        .add_template(WorkTemplate::new("b"))
        .add_condition(Condition::always("a", "b"))
        .entry("a")
}

fn canon(mut snap: Json) -> Json {
    if let Json::Obj(m) = &mut snap {
        for arr in m.values_mut() {
            if let Json::Arr(a) = arr {
                a.sort_by_key(|row| row.get("id").and_then(|v| v.as_u64()).unwrap_or(0));
            }
        }
    }
    snap
}

fn wait_until(what: &str, timeout: std::time::Duration, mut f: impl FnMut() -> bool) {
    let deadline = std::time::Instant::now() + timeout;
    while !f() {
        assert!(std::time::Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
}

/// A primary head: full daemon pipeline + REST server over a data dir.
struct PrimaryStack {
    store: Store,
    broker: Broker,
    persist: Persist,
    cluster: Arc<ClusterState>,
    host: Option<AgentHost>,
    server: HttpServer,
    client: Client,
}

impl PrimaryStack {
    fn addr(&self) -> String {
        self.server.addr.to_string()
    }

    fn quiesce(&mut self) {
        if let Some(h) = self.host.take() {
            h.stop();
        }
        self.persist.flush();
    }

    /// "Kill" the primary: stop the listener and drain/release the WAL
    /// (drops the LOCK so the dir could be reopened).
    fn kill(mut self) -> Store {
        self.quiesce();
        self.server.stop();
        self.persist.shutdown();
        self.store
    }
}

fn primary_stack(dir: &Path, popts: PersistOptions) -> PrimaryStack {
    let clock = Arc::new(WallClock::new());
    let store = Store::new(clock.clone());
    let broker = Broker::new(clock);
    let metrics = Registry::default();
    let (persist, _) =
        Persist::open_with_broker(dir, popts, &store, Some(&broker), metrics.clone()).unwrap();
    write_epoch(dir, 1).unwrap();
    let cluster = ClusterState::primary(Some(dir.to_path_buf()), 1);
    let executors =
        ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default()));
    let pipeline = Pipeline::new(store.clone(), broker.clone(), metrics.clone(), executors);
    let (c, m, t, ca, co) = pipeline.daemons();
    let daemons: Vec<Arc<dyn Daemon>> =
        vec![Arc::new(c), Arc::new(m), Arc::new(t), Arc::new(ca), Arc::new(co)];
    let host = AgentHost::start(daemons, std::time::Duration::from_millis(2));
    let cfg = Config::defaults();
    let server = serve(
        ServerState::new(store.clone(), broker.clone(), metrics, &cfg)
            .with_persist(persist.clone())
            .with_cluster(Arc::clone(&cluster)),
        &cfg,
    )
    .unwrap();
    let client = Client::new(server.addr, TOKEN);
    PrimaryStack { store, broker, persist, cluster, host: Some(host), server, client }
}

/// A warm standby: pull loop + read-only REST server, daemons parked.
struct StandbyStack {
    store: Store,
    broker: Broker,
    persist: Persist,
    replica: Arc<Replica>,
    metrics: Registry,
    server: HttpServer,
}

impl StandbyStack {
    fn cluster(&self) -> Arc<ClusterState> {
        self.replica.cluster()
    }

    fn wait_applied(&self, lsn: u64) {
        wait_until("standby catch-up", std::time::Duration::from_secs(20), || {
            self.cluster().applied_lsn() >= lsn
        });
    }
}

fn standby_stack(dir: &Path, primary_addr: &str) -> StandbyStack {
    let clock = Arc::new(WallClock::new());
    let store = Store::new(clock.clone());
    let broker = Broker::new(clock);
    let metrics = Registry::default();
    let (persist, _) =
        Persist::open_replica(dir, opts(), &store, &broker, metrics.clone()).unwrap();
    let cluster = ClusterState::replica(dir.to_path_buf(), primary_addr, read_epoch(dir));
    let replica = Replica::start(
        store.clone(),
        broker.clone(),
        persist.clone(),
        cluster,
        TOKEN,
        ropts(),
        metrics.clone(),
    )
    .unwrap();
    let cfg = Config::defaults();
    let server = serve(
        ServerState::new(store.clone(), broker.clone(), metrics.clone(), &cfg)
            .with_persist(persist.clone())
            .with_replica(Arc::clone(&replica)),
        &cfg,
    )
    .unwrap();
    StandbyStack { store, broker, persist, replica, metrics, server }
}

fn submit_body() -> String {
    format!(
        r#"{{"name": "r", "requester": "u", "workflow": {}}}"#,
        two_step().to_json()
    )
}

#[test]
fn ship_endpoint_serves_crc_framed_durable_wal() {
    let dir = tmp_dir("ship");
    let mut p = primary_stack(&dir, opts());
    for i in 0..20 {
        p.client.submit(&format!("c{i}"), "u", RequestKind::Workflow, &two_step()).unwrap();
    }
    p.quiesce();
    let durable = p.persist.wal().durable_lsn();
    assert!(durable >= 20);

    let resp = http_request_full(
        p.addr().as_str(),
        "GET",
        "/api/replication/wal?from_lsn=1&max_bytes=1048576",
        &[("Authorization", AUTH), ("X-IDDS-Peer-Epoch", "1")],
        b"",
    )
    .unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header_u64("X-IDDS-Epoch"), Some(1));
    assert_eq!(resp.header_u64("X-IDDS-Durable-LSN"), Some(durable));
    let frames = decode_frames(&resp.body).expect("shipped bytes are valid WAL framing");
    assert_eq!(frames.first().unwrap().0, 1, "ships from the requested lsn");
    assert_eq!(frames.last().unwrap().0, durable, "ships through the durable mark");
    let lsns: Vec<u64> = frames.iter().map(|(l, _)| *l).collect();
    assert!(lsns.windows(2).all(|w| w[1] == w[0] + 1), "dense lsn sequence");

    // caught-up pull: empty body, still 200 with watermarks
    let resp = http_request_full(
        p.addr().as_str(),
        "GET",
        &format!("/api/replication/wal?from_lsn={}", durable + 1),
        &[("Authorization", AUTH), ("X-IDDS-Peer-Epoch", "1")],
        b"",
    )
    .unwrap();
    assert_eq!(resp.status, 200);
    assert!(resp.body.is_empty());

    // chunking: a tiny max_bytes still makes progress (>= 1 frame)
    let resp = http_request_full(
        p.addr().as_str(),
        "GET",
        "/api/replication/wal?from_lsn=1&max_bytes=4096",
        &[("Authorization", AUTH)],
        b"",
    )
    .unwrap();
    let chunk = decode_frames(&resp.body).unwrap();
    assert!(!chunk.is_empty());
    assert!(chunk.len() < frames.len(), "max_bytes chunks the transfer");

    p.kill();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn failover_preserves_state_and_finishes_the_campaign() {
    let dir_p = tmp_dir("failover-p");
    let dir_s = tmp_dir("failover-s");
    let mut primary = primary_stack(&dir_p, opts());

    // a few campaigns run to completion on the primary
    for i in 0..3 {
        let req = primary
            .client
            .submit(&format!("camp{i}"), "alice", RequestKind::Workflow, &two_step())
            .unwrap();
        let st = primary.client.wait_terminal(req, std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(st, RequestStatus::Finished);
    }

    // warm standby comes up and follows
    let standby = standby_stack(&dir_s, &primary.addr());

    // standby is read-only and reports replication health while following
    let (st, body) = http_request(
        standby.server.addr,
        "POST",
        "/api/requests",
        &[("Authorization", AUTH), ("Content-Type", "application/json")],
        submit_body().as_bytes(),
    )
    .unwrap();
    assert_eq!(st, 503, "writes rejected on a standby: {body:?}");
    let (st, _) = http_request(
        standby.server.addr,
        "GET",
        "/api/messages?sub=1&max=1",
        &[("Authorization", AUTH)],
        b"",
    )
    .unwrap();
    assert_eq!(st, 503, "message polling mutates delivery state: gated too");
    let (st, body) =
        http_request(standby.server.addr, "GET", "/api/health", &[], b"").unwrap();
    assert_eq!(st, 200);
    let health = parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(
        health.get_path(&["replication", "role"]).and_then(|v| v.as_str()),
        Some("replica")
    );
    assert!(health.get_path(&["replication", "lag_lsn"]).is_some());

    // mid-flight campaign: daemons quiesced right after the submit, so the
    // request is underway but unfinished when the primary dies
    let midflight = primary
        .client
        .submit("midflight", "alice", RequestKind::Workflow, &two_step())
        .unwrap();
    primary.quiesce();
    let durable = primary.persist.wal().durable_lsn();
    standby.wait_applied(durable);
    let live_snapshot = canon(primary.store.snapshot());
    let live_counts = primary.store.counts();

    // the primary dies; the standby is promoted
    primary.kill();
    let (st, body) = http_request(
        standby.server.addr,
        "POST",
        "/api/admin/promote",
        &[("Authorization", AUTH)],
        b"",
    )
    .unwrap();
    assert_eq!(st, 200, "promote: {body:?}");
    let j = parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(j.get("epoch").and_then(|v| v.as_u64()), Some(2), "epoch bumped");
    assert_eq!(read_epoch(&dir_s), 2, "epoch persisted next to the standby's LOCK");

    // recover == live across the ship/promote boundary
    assert_eq!(canon(standby.store.snapshot()), live_snapshot);
    assert_eq!(standby.store.counts(), live_counts);

    // promote is idempotent
    let (st, body) = http_request(
        standby.server.addr,
        "POST",
        "/api/admin/promote",
        &[("Authorization", AUTH)],
        b"",
    )
    .unwrap();
    assert_eq!(st, 200);
    let j = parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(j.get("already").and_then(|v| v.as_bool()), Some(true));

    // writes flow on the new primary...
    let client = Client::new(standby.server.addr, TOKEN);
    let post_failover = client.submit("after", "alice", RequestKind::Workflow, &two_step()).unwrap();
    assert!(post_failover > midflight, "id allocator advanced past replicated ids");

    // ...and the daemons (started on promote) finish both the mid-flight
    // and the post-failover campaign on the standby's state
    let executors =
        ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default()));
    let pipeline = Pipeline::new(
        standby.store.clone(),
        standby.broker.clone(),
        standby.metrics.clone(),
        executors,
    );
    let (c, m, t, ca, co) = pipeline.daemons();
    idds::daemons::pump(&[&c, &m, &t, &ca, &co], 2000);
    assert_eq!(standby.store.get_request(midflight).unwrap().status, RequestStatus::Finished);
    assert_eq!(
        standby.store.get_request(post_failover).unwrap().status,
        RequestStatus::Finished
    );

    // the new primary's writes are durable: recover its dir and compare
    standby.server.stop();
    standby.replica.stop();
    standby.persist.flush();
    let final_snapshot = canon(standby.store.snapshot());
    standby.persist.shutdown();
    let clock = Arc::new(WallClock::new());
    let recovered = Store::new(clock.clone());
    let rbroker = Broker::new(clock);
    let (p2, _) = Persist::open_with_broker(
        &dir_s,
        opts(),
        &recovered,
        Some(&rbroker),
        Registry::default(),
    )
    .unwrap();
    assert_eq!(canon(recovered.snapshot()), final_snapshot, "post-promote writes recovered");
    p2.shutdown();

    std::fs::remove_dir_all(&dir_p).ok();
    std::fs::remove_dir_all(&dir_s).ok();
}

#[test]
fn promote_fences_a_live_old_primary() {
    let dir_p = tmp_dir("fence-p");
    let dir_s = tmp_dir("fence-s");
    let mut primary = primary_stack(&dir_p, opts());
    for i in 0..5 {
        primary.client.submit(&format!("c{i}"), "u", RequestKind::Workflow, &two_step()).unwrap();
    }
    primary.quiesce();
    let standby = standby_stack(&dir_s, &primary.addr());
    standby.wait_applied(primary.persist.wal().durable_lsn());

    // split-brain drill: promote while the old primary is still serving
    let (st, _) = http_request(
        standby.server.addr,
        "POST",
        "/api/admin/promote",
        &[("Authorization", AUTH)],
        b"",
    )
    .unwrap();
    assert_eq!(st, 200);

    // the fence POST from promote landed: old primary refuses writes
    wait_until("old primary fenced", std::time::Duration::from_secs(5), || {
        primary.cluster.is_fenced()
    });
    let (st, _) = http_request(
        primary.server.addr,
        "POST",
        "/api/requests",
        &[("Authorization", AUTH), ("Content-Type", "application/json")],
        submit_body().as_bytes(),
    )
    .unwrap();
    assert_eq!(st, 503, "fenced primary 503s writes");
    let (_, body) = http_request(primary.server.addr, "GET", "/api/health", &[], b"").unwrap();
    let health = parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(
        health.get_path(&["replication", "fenced"]).and_then(|v| v.as_bool()),
        Some(true)
    );
    assert_eq!(read_fenced(&dir_p), Some(2), "FENCED marker names the superseding epoch");

    // a write sneaking past REST (direct store handle) is dropped by the
    // fenced WAL and surfaces as a sticky io_error
    primary.store.add_request("rogue", "u", RequestKind::Workflow, Json::Null);
    wait_until("sticky io_error", std::time::Duration::from_secs(5), || {
        primary.persist.wal().io_error().is_some()
    });

    // stale-epoch ship requests are refused (the fenced node is not a
    // valid source), and so are fence requests with non-newer epochs
    let resp = http_request_full(
        primary.addr().as_str(),
        "GET",
        "/api/replication/wal?from_lsn=1",
        &[("Authorization", AUTH), ("X-IDDS-Peer-Epoch", "1")],
        b"",
    )
    .unwrap();
    assert_eq!(resp.status, 409);
    let (st, _) = http_request(
        standby.server.addr,
        "POST",
        "/api/replication/fence",
        &[("Authorization", AUTH), ("Content-Type", "application/json")],
        b"{\"epoch\": 1}",
    )
    .unwrap();
    assert_eq!(st, 409, "stale fence epoch refused by the new primary");

    standby.server.stop();
    standby.replica.stop();
    standby.persist.shutdown();
    primary.kill();
    std::fs::remove_dir_all(&dir_p).ok();
    std::fs::remove_dir_all(&dir_s).ok();
}

#[test]
fn promote_refused_before_first_sync() {
    // A standby that has never pulled still sits at epoch 0; promoting it
    // would mint epoch 1 — a tie with a first-boot primary, which the
    // strictly-newer fence comparison would never fence. The promote must
    // be refused until a pull (or bootstrap) has adopted the cluster epoch.
    let dir_s = tmp_dir("blind-s");
    // port 9 (discard) never answers: the standby can never sync
    let standby = standby_stack(&dir_s, "127.0.0.1:9");
    let (st, body) = http_request(
        standby.server.addr,
        "POST",
        "/api/admin/promote",
        &[("Authorization", AUTH)],
        b"",
    )
    .unwrap();
    assert_eq!(st, 500, "blind promote refused: {body:?}");
    let text = String::from_utf8_lossy(&body).to_string();
    assert!(text.contains("never synced"), "refusal names the cause: {text}");
    assert!(!standby.cluster().is_promoted(), "still a standby");
    assert!(standby.cluster().is_replica(), "pull loop keeps running");
    assert_eq!(read_epoch(&dir_s), 0, "no epoch was minted on disk");

    standby.server.stop();
    standby.replica.stop();
    standby.persist.shutdown();
    std::fs::remove_dir_all(&dir_s).ok();
}

#[test]
fn fence_stops_a_standbys_pull_loop() {
    let dir_p = tmp_dir("sfence-p");
    let dir_s = tmp_dir("sfence-s");
    let mut primary = primary_stack(&dir_p, opts());
    for i in 0..5 {
        primary.client.submit(&format!("c{i}"), "u", RequestKind::Workflow, &two_step()).unwrap();
    }
    primary.quiesce();
    let standby = standby_stack(&dir_s, &primary.addr());
    standby.wait_applied(primary.persist.wal().durable_lsn());

    // a sibling standby won a promotion race elsewhere: its fence lands here
    let (st, _) = http_request(
        standby.server.addr,
        "POST",
        "/api/replication/fence",
        &[("Authorization", AUTH), ("Content-Type", "application/json")],
        b"{\"epoch\": 7}",
    )
    .unwrap();
    assert_eq!(st, 200);
    assert!(standby.cluster().is_fenced());
    assert!(standby.persist.wal().is_fenced(), "local WAL refuses further appends");
    assert_eq!(read_fenced(&dir_s), Some(7), "marker names the superseding epoch");

    // the pull loop exits rather than follow a dead timeline: the pull
    // counter stops moving...
    let pulls = |s: &StandbyStack| {
        s.cluster().health_json().get("pulls").and_then(|v| v.as_u64()).unwrap_or(0)
    };
    wait_until("pull loop exit", std::time::Duration::from_secs(5), || {
        let before = pulls(&standby);
        std::thread::sleep(std::time::Duration::from_millis(50));
        before == pulls(&standby)
    });
    // ...and new primary history no longer moves the applied position
    let applied = standby.cluster().applied_lsn();
    primary.client.submit("late", "u", RequestKind::Workflow, &two_step()).unwrap();
    primary.persist.flush();
    std::thread::sleep(std::time::Duration::from_millis(100));
    assert_eq!(standby.cluster().applied_lsn(), applied, "fenced standby stopped applying");

    standby.server.stop();
    standby.replica.stop();
    standby.persist.shutdown();
    primary.kill();
    std::fs::remove_dir_all(&dir_p).ok();
    std::fs::remove_dir_all(&dir_s).ok();
}

#[test]
fn fresh_standby_bootstraps_from_snapshot_after_prune() {
    let dir_p = tmp_dir("boot-p");
    let dir_s = tmp_dir("boot-s");
    // keep=1 so every base moves the prune horizon to its own cut
    let mut primary = primary_stack(&dir_p, PersistOptions { checkpoint_keep: 1, ..opts() });
    for i in 0..10 {
        primary.client.submit(&format!("a{i}"), "u", RequestKind::Workflow, &two_step()).unwrap();
    }
    primary.persist.checkpoint_full(&primary.store).unwrap();
    for i in 0..10 {
        primary.client.submit(&format!("b{i}"), "u", RequestKind::Workflow, &two_step()).unwrap();
    }
    primary.quiesce();
    primary.persist.checkpoint_full(&primary.store).unwrap();

    // lsn 1 is gone from the primary's WAL now
    let resp = http_request_full(
        primary.addr().as_str(),
        "GET",
        "/api/replication/wal?from_lsn=1",
        &[("Authorization", AUTH), ("X-IDDS-Peer-Epoch", "1")],
        b"",
    )
    .unwrap();
    assert_eq!(resp.status, 410, "pruned history answers Gone");
    assert!(resp.header_u64("X-IDDS-Oldest-LSN").unwrap() > 1);

    // a fresh standby must take the snapshot path and still converge
    let standby = standby_stack(&dir_s, &primary.addr());
    standby.wait_applied(primary.persist.wal().durable_lsn());
    assert_eq!(canon(standby.store.snapshot()), canon(primary.store.snapshot()));
    assert_eq!(standby.store.counts(), primary.store.counts());
    assert!(
        standby.metrics.counter("replication.bootstraps").get() >= 1,
        "the snapshot path was actually taken"
    );

    // and keeps following WAL frames after the bootstrap
    let more = primary.client.submit("late", "u", RequestKind::Workflow, &two_step()).unwrap();
    primary.persist.flush();
    standby.wait_applied(primary.persist.wal().durable_lsn());
    assert_eq!(standby.store.get_request(more).unwrap().status.as_str(), "New");

    standby.server.stop();
    standby.replica.stop();
    standby.persist.shutdown();
    primary.kill();
    std::fs::remove_dir_all(&dir_p).ok();
    std::fs::remove_dir_all(&dir_s).ok();
}

#[test]
fn standby_restart_resumes_from_its_local_wal() {
    let dir_p = tmp_dir("resume-p");
    let dir_s = tmp_dir("resume-s");
    let mut primary = primary_stack(&dir_p, opts());
    for i in 0..8 {
        primary.client.submit(&format!("c{i}"), "u", RequestKind::Workflow, &two_step()).unwrap();
    }
    primary.quiesce();
    let durable = primary.persist.wal().durable_lsn();

    // first standby incarnation catches up, then dies
    let standby = standby_stack(&dir_s, &primary.addr());
    standby.wait_applied(durable);
    standby.server.stop();
    standby.replica.stop();
    standby.persist.flush();
    standby.persist.shutdown();

    // more primary history while the standby is down
    let host = {
        // restart daemons so campaigns can move again
        let executors =
            ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default()));
        let pipeline = Pipeline::new(
            primary.store.clone(),
            primary.broker.clone(),
            Registry::default(),
            executors,
        );
        let (c, m, t, ca, co) = pipeline.daemons();
        let daemons: Vec<Arc<dyn Daemon>> =
            vec![Arc::new(c), Arc::new(m), Arc::new(t), Arc::new(ca), Arc::new(co)];
        AgentHost::start(daemons, std::time::Duration::from_millis(2))
    };
    for i in 0..4 {
        primary.client.submit(&format!("d{i}"), "u", RequestKind::Workflow, &two_step()).unwrap();
    }
    host.stop();
    primary.persist.flush();
    let durable2 = primary.persist.wal().durable_lsn();
    assert!(durable2 > durable);

    // second incarnation: local recovery replays the shipped copy and the
    // pull loop resumes from there — applied starts at the local WAL end,
    // never back at zero (which would mean a redundant re-bootstrap)
    let standby2 = standby_stack(&dir_s, &primary.addr());
    assert!(
        standby2.cluster().applied_lsn() >= durable,
        "resume position comes from the local wal"
    );
    standby2.wait_applied(durable2);
    assert_eq!(canon(standby2.store.snapshot()), canon(primary.store.snapshot()));
    assert_eq!(
        standby2.metrics.counter("replication.bootstraps").get(),
        0,
        "restart must not re-bootstrap"
    );

    standby2.server.stop();
    standby2.replica.stop();
    standby2.persist.shutdown();
    primary.kill();
    std::fs::remove_dir_all(&dir_p).ok();
    std::fs::remove_dir_all(&dir_s).ok();
}
