//! Integration: REST head service over real sockets + daemons in threads —
//! the full client→REST→Clerk→…→Conductor→broker path of paper Fig. 1/2.

use std::sync::Arc;

use idds::broker::Broker;
use idds::config::Config;
use idds::daemons::executors::{ExecutorSet, NoopExecutor};
use idds::daemons::{AgentHost, Daemon, Pipeline};
use idds::metrics::Registry;
use idds::rest::{serve, Client, ServerState};
use idds::store::{RequestKind, RequestStatus, Store};
use idds::util::clock::WallClock;
use idds::util::json::Json;
use idds::workflow::{Condition, WorkKind, WorkTemplate, Workflow};

struct Stack {
    client: Client,
    store: Store,
    broker: Broker,
    _host: AgentHost,
    _server: idds::rest::HttpServer,
}

fn stack() -> Stack {
    let clock = Arc::new(WallClock::new());
    let store = Store::new(clock.clone());
    let broker = Broker::new(clock);
    let metrics = Registry::default();
    let cfg = Config::defaults();
    let executors =
        ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default()));
    let pipeline = Pipeline::new(store.clone(), broker.clone(), metrics.clone(), executors);
    let (c, m, t, ca, co) = pipeline.daemons();
    let daemons: Vec<Arc<dyn Daemon>> = vec![
        Arc::new(c),
        Arc::new(m),
        Arc::new(t),
        Arc::new(ca),
        Arc::new(co),
    ];
    let host = AgentHost::start(daemons, std::time::Duration::from_millis(2));
    let server = serve(
        ServerState::new(store.clone(), broker.clone(), metrics, &cfg),
        &cfg,
    )
    .unwrap();
    let client = Client::new(server.addr, "dev-token");
    Stack {
        client,
        store,
        broker,
        _host: host,
        _server: server,
    }
}

fn two_step() -> Workflow {
    Workflow::new("two-step")
        .add_template(WorkTemplate::new("prep").default(
            "result",
            Json::obj().set("quality", 0.8),
        ))
        .add_template(WorkTemplate::new("main"))
        .add_condition(Condition::always("prep", "main"))
        .entry("prep")
}

#[test]
fn submit_run_finish_over_rest() {
    let s = stack();
    let req = s
        .client
        .submit("campaign", "alice", RequestKind::Workflow, &two_step())
        .unwrap();
    let status = s
        .client
        .wait_terminal(req, std::time::Duration::from_secs(30))
        .unwrap();
    assert_eq!(status, RequestStatus::Finished);
    let summary = s.client.summary(req).unwrap();
    let tfs = summary.get("transforms").unwrap().as_arr().unwrap();
    assert_eq!(tfs.len(), 2);
}

#[test]
fn consumer_receives_conductor_messages_over_rest() {
    let s = stack();
    let sub = s.client.subscribe("idds.work.finished").unwrap();
    let req = s
        .client
        .submit("msg-test", "bob", RequestKind::Workflow, &two_step())
        .unwrap();
    s.client
        .wait_terminal(req, std::time::Duration::from_secs(30))
        .unwrap();
    // the two finished works must each produce one availability message
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    let mut got = Vec::new();
    while got.len() < 2 && std::time::Instant::now() < deadline {
        for d in s.client.poll_messages(sub, 10).unwrap() {
            s.client.ack(sub, d.id).unwrap();
            got.push(d);
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(got.len(), 2);
    assert!(got.iter().all(|d| d.topic == "idds.work.finished"));
    assert!(got
        .iter()
        .all(|d| d.payload.get("failed").unwrap().as_bool() == Some(false)));
}

#[test]
fn bad_token_rejected() {
    let s = stack();
    let bad = Client::new(s._server.addr, "wrong-token");
    assert!(bad.submit("x", "u", RequestKind::Workflow, &two_step()).is_err());
    // store untouched
    assert!(s.store.requests_with_status(RequestStatus::New).is_empty());
}

#[test]
fn concurrent_clients() {
    let s = stack();
    let addr = s._server.addr;
    let handles: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let c = Client::new(addr, "dev-token");
                let req = c
                    .submit(&format!("r{i}"), "u", RequestKind::Workflow, &two_step())
                    .unwrap();
                c.wait_terminal(req, std::time::Duration::from_secs(30)).unwrap()
            })
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap(), RequestStatus::Finished);
    }
    let _ = s.broker.stats();
}
