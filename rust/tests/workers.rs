//! Flagship distributed-executor test: a head service in this process
//! over a real socket, worker processes spawned from the `idds` binary
//! (`idds work --connect ADDR`), and a carousel campaign that survives
//! killing a worker mid-lease.
//!
//! The choreography, start to finish:
//!
//! 1. head starts with Noop delegated to the fleet (RemoteExecutor) and a
//!    short lease timeout; worker A (`flagship-a`) connects;
//! 2. a DataCarousel campaign of slow Noop Works (each holds its lease
//!    open via `delay_ms`) is submitted; once health shows worker A
//!    actually holding leases, A is killed — kill(9), no goodbye;
//! 3. a healthy worker B joins, and A's name rejoins as a new process —
//!    the head gives it the same worker id with a bumped epoch (asserted
//!    via health), which is what invalidates the dead incarnation's leases;
//! 4. the killed worker's leases expire (heartbeats stopped) and the
//!    broker redelivers the Works; the campaign finishes;
//! 5. exactly one `idds.work.finished` message exists per transform — the
//!    at-least-once execution below collapsed to exactly-once completion.

use std::sync::Arc;
use std::time::{Duration, Instant};

use idds::broker::lease::WorkerRegistry;
use idds::broker::Broker;
use idds::config::Config;
use idds::daemons::executors::{ExecutorSet, RemoteExecutor};
use idds::daemons::{AgentHost, Daemon, Pipeline};
use idds::metrics::Registry;
use idds::rest::http::HttpServer;
use idds::rest::{serve, Client, ServerState};
use idds::store::{RequestKind, RequestStatus, Store};
use idds::util::clock::WallClock;
use idds::util::json::Json;
use idds::workflow::{WorkKind, WorkTemplate, Workflow};

const TOKEN: &str = "dev-token";
/// Short enough that a killed worker's leases come back within the test,
/// long enough that live workers heartbeating at 0.2s never lose one.
const LEASE_TIMEOUT_S: f64 = 1.5;

fn wait_until(what: &str, timeout: Duration, mut f: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while !f() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The head: store + broker + worker registry + full daemon pipeline +
/// REST server, with Noop Works delegated to the remote fleet — the
/// in-process equivalent of `idds serve --set workers.remote_kinds=Noop`.
struct Head {
    broker: Broker,
    registry: WorkerRegistry,
    metrics: Registry,
    host: AgentHost,
    server: HttpServer,
    client: Client,
}

fn head() -> Head {
    let clock = Arc::new(WallClock::new());
    let store = Store::new(clock.clone());
    let broker = Broker::new(clock.clone()).with_redelivery_timeout(LEASE_TIMEOUT_S);
    let metrics = Registry::default();
    let registry = WorkerRegistry::new(broker.clone(), clock, metrics.clone());
    let executors = ExecutorSet::default().with(
        WorkKind::Noop,
        Arc::new(RemoteExecutor::new(registry.clone(), WorkKind::Noop)),
    );
    let pipeline = Pipeline::new(store.clone(), broker.clone(), metrics.clone(), executors);
    let (c, m, t, ca, co) = pipeline.daemons();
    let daemons: Vec<Arc<dyn Daemon>> =
        vec![Arc::new(c), Arc::new(m), Arc::new(t), Arc::new(ca), Arc::new(co)];
    let host = AgentHost::start(daemons, Duration::from_millis(2));
    let cfg = Config::defaults();
    let server = serve(
        ServerState::new(store, broker.clone(), metrics.clone(), &cfg)
            .with_workers(registry.clone()),
        &cfg,
    )
    .unwrap();
    let client = Client::new(server.addr, TOKEN);
    Head { broker, registry, metrics, host, server, client }
}

impl Head {
    /// The health row for a worker name, if it has registered.
    fn worker_row(&self, name: &str) -> Option<Json> {
        let fleet = self.registry.health_json();
        fleet.get("workers")?.as_arr()?.iter().find(|w| {
            w.get("name").and_then(|n| n.as_str()) == Some(name)
        }).cloned()
    }
}

/// Spawn an `idds work` process against the head. Fast heartbeats and a
/// small batch keep the test's timings tight.
fn spawn_worker(addr: std::net::SocketAddr, name: &str) -> std::process::Child {
    std::process::Command::new(env!("CARGO_BIN_EXE_idds"))
        .args([
            "work",
            "--connect",
            &addr.to_string(),
            "--name",
            name,
            "--set",
            "workers.heartbeat_s=0.2",
            "--set",
            "workers.lease_batch=2",
        ])
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawning idds work")
}

/// A carousel campaign of slow Noop Works: every template is an entry
/// (all Works claimable at once) and each holds its lease open for a
/// while via the worker's `delay_ms` hook — leases worth killing.
fn campaign(works: usize, delay_ms: f64) -> Workflow {
    let mut wf = Workflow::new("flagship-carousel");
    for i in 0..works {
        let name = format!("stage-{i}");
        wf = wf
            .add_template(
                WorkTemplate::new(&name)
                    .default("delay_ms", Json::Num(delay_ms))
                    .default("result", Json::obj().set("stage", i as f64)),
            )
            .entry(&name);
    }
    wf
}

#[test]
fn carousel_campaign_survives_killing_a_worker_mid_lease() {
    const WORKS: usize = 6;
    let head = head();
    // subscribe before anything can finish: the broker drops publishes
    // with no subscribers, and each carrier completion emits exactly one
    // idds.work.finished message — our duplicate detector
    let finished_sub = head.broker.subscribe("idds.work.finished");

    let mut worker_a = spawn_worker(head.server.addr, "flagship-a");
    let id = head
        .client
        .submit("flagship", "ops", RequestKind::DataCarousel, &campaign(WORKS, 800.0))
        .unwrap();

    // wait for A to actually hold work mid-flight, then kill it: no
    // drain, no deregistration, heartbeats just stop
    wait_until("worker A holding a lease", Duration::from_secs(30), || {
        head.worker_row("flagship-a")
            .and_then(|w| w.get("active_leases").and_then(|v| v.as_u64()))
            .unwrap_or(0)
            > 0
    });
    let epoch_at_kill = head
        .worker_row("flagship-a")
        .and_then(|w| w.get("epoch").and_then(|v| v.as_u64()))
        .unwrap();
    assert_eq!(epoch_at_kill, 1, "first registration is epoch 1");
    worker_a.kill().expect("kill worker A");
    worker_a.wait().expect("reap worker A");

    // a healthy worker joins, and A's name rejoins as a fresh process
    let mut worker_b = spawn_worker(head.server.addr, "flagship-b");
    let mut worker_a2 = spawn_worker(head.server.addr, "flagship-a");
    wait_until("A rejoining with a bumped epoch", Duration::from_secs(30), || {
        head.worker_row("flagship-a")
            .and_then(|w| w.get("epoch").and_then(|v| v.as_u64()))
            == Some(2)
    });

    // the campaign completes: the killed worker's leases expired and the
    // Works redelivered to the survivors
    let status = head.client.wait_terminal(id, Duration::from_secs(120)).unwrap();
    assert_eq!(status, RequestStatus::Finished, "campaign must finish after the kill");
    assert!(
        head.metrics.counter("workers.leases_redelivered").get() >= 1,
        "the killed worker's leases must have been re-leased"
    );

    // exactly one finished message per transform, every one successful,
    // no transform completed twice — at-least-once execution, exactly-once
    // completion
    // ack as we consume: an unacked delivery would itself redeliver after
    // the broker timeout and masquerade as a duplicate completion
    let mut finished = Vec::new();
    let mut drain = |finished: &mut Vec<idds::broker::Delivery>| {
        for d in head.broker.poll(finished_sub, 100) {
            head.broker.ack(finished_sub, d.id);
            finished.push(d);
        }
    };
    wait_until("conductor delivering finished messages", Duration::from_secs(30), || {
        drain(&mut finished);
        finished.len() >= WORKS
    });
    // grace window: a duplicate would trail the real completions
    std::thread::sleep(Duration::from_millis(300));
    drain(&mut finished);
    assert_eq!(finished.len(), WORKS, "one completion per Work, no duplicates");
    let mut transforms: Vec<u64> = finished
        .iter()
        .map(|m| m.payload.get("transform_id").and_then(|v| v.as_u64()).unwrap())
        .collect();
    transforms.sort_unstable();
    transforms.dedup();
    assert_eq!(transforms.len(), WORKS, "every completion is a distinct transform");
    for m in &finished {
        assert_eq!(
            m.payload.get("failed").and_then(|v| v.as_bool()),
            Some(false),
            "no Work may fail: {:?}",
            m.payload
        );
        // the Noop echo made it through the remote round-trip intact
        assert!(
            m.payload.get_path(&["result", "stage"]).and_then(|v| v.as_f64()).is_some(),
            "result payload survived the worker round-trip: {:?}",
            m.payload
        );
    }

    // fleet bookkeeping: two names, A's id reused across the rejoin
    let fleet = head.registry.health_json();
    assert_eq!(fleet.get("registered").and_then(|v| v.as_u64()), Some(2));
    assert_eq!(
        fleet.get("active_leases").and_then(|v| v.as_u64()),
        Some(0),
        "nothing left in flight after the campaign"
    );

    worker_b.kill().ok();
    worker_b.wait().ok();
    worker_a2.kill().ok();
    worker_a2.wait().ok();
    head.host.stop();
    head.server.stop();
}

/// Sanity for the spawn path itself: a worker process registers, drains a
/// quick campaign, and survives the head telling it nothing is queued.
#[test]
fn single_worker_process_completes_a_campaign() {
    let head = head();
    let mut worker = spawn_worker(head.server.addr, "solo");
    let id = head
        .client
        .submit("solo-run", "ops", RequestKind::Workflow, &campaign(3, 0.0))
        .unwrap();
    let status = head.client.wait_terminal(id, Duration::from_secs(60)).unwrap();
    assert_eq!(status, RequestStatus::Finished);
    assert_eq!(head.metrics.counter("workers.completions_accepted").get(), 3);
    worker.kill().ok();
    worker.wait().ok();
    head.host.stop();
    head.server.stop();
}
