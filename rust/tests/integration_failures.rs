//! Failure-injection integration tests: executor errors, worker panics,
//! staging failures, message redelivery under consumer crashes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use idds::broker::Broker;
use idds::daemons::executors::{Executor, ExecutorSet};
use idds::daemons::{pump, Pipeline};
use idds::metrics::Registry;
use idds::store::{RequestKind, RequestStatus, Store, TransformStatus};
use idds::util::clock::WallClock;
use idds::util::json::Json;
use idds::workflow::{Condition, WorkKind, WorkTemplate, Workflow};

/// Executor that fails the first `fail_n` submissions, then succeeds.
struct FlakyExecutor {
    fail_n: AtomicUsize,
    done: Mutex<std::collections::HashMap<u64, Json>>,
}

impl FlakyExecutor {
    fn new(fail_n: usize) -> Self {
        FlakyExecutor {
            fail_n: AtomicUsize::new(fail_n),
            done: Mutex::new(std::collections::HashMap::new()),
        }
    }
}

impl Executor for FlakyExecutor {
    fn submit(&self, _work: &Json) -> anyhow::Result<u64> {
        let left = self.fail_n.load(Ordering::SeqCst);
        if left > 0 {
            self.fail_n.store(left - 1, Ordering::SeqCst);
            anyhow::bail!("transient submit failure");
        }
        let h = idds::util::next_id();
        self.done.lock().unwrap().insert(h, Json::obj());
        Ok(h)
    }

    fn poll(&self, handle: u64) -> anyhow::Result<Option<Json>> {
        Ok(self.done.lock().unwrap().remove(&handle))
    }
}

/// Executor whose *payload* reports an error result.
struct ErrorResultExecutor;

impl Executor for ErrorResultExecutor {
    fn submit(&self, _work: &Json) -> anyhow::Result<u64> {
        Ok(idds::util::next_id())
    }
    fn poll(&self, _handle: u64) -> anyhow::Result<Option<Json>> {
        Ok(Some(Json::obj().set("error", "payload exploded")))
    }
}

fn pipeline_with(exec: Arc<dyn Executor>) -> Pipeline {
    let clock = Arc::new(WallClock::new());
    Pipeline::new(
        Store::new(clock.clone()),
        Broker::new(clock),
        Registry::default(),
        ExecutorSet::default().with(WorkKind::Noop, exec),
    )
}

fn one_work() -> Workflow {
    Workflow::new("one").add_template(WorkTemplate::new("a")).entry("a")
}

#[test]
fn submit_failure_fails_transform_and_request() {
    let p = pipeline_with(Arc::new(FlakyExecutor::new(usize::MAX)));
    let req = p
        .store
        .add_request("r", "u", RequestKind::Workflow, one_work().to_json());
    let (c, m, t, ca, co) = p.daemons();
    pump(&[&c, &m, &t, &ca, &co], 10_000);
    assert_eq!(p.store.get_request(req).unwrap().status, RequestStatus::Failed);
    let tf = p.store.transforms_of_request(req)[0];
    assert_eq!(p.store.get_transform(tf).unwrap().status, TransformStatus::Failed);
}

#[test]
fn payload_error_result_fails_work_but_request_reports_subfinished_vs_failed() {
    // workflow with two entries: one fails (ErrorResult under Noop), the
    // other succeeds (its template kind has a healthy executor).
    let clock = Arc::new(WallClock::new());
    let p = Pipeline::new(
        Store::new(clock.clone()),
        Broker::new(clock),
        Registry::default(),
        ExecutorSet::default()
            .with(WorkKind::Noop, Arc::new(ErrorResultExecutor))
            .with(
                WorkKind::Decision,
                Arc::new(idds::daemons::executors::NoopExecutor::default()),
            ),
    );
    let wf = Workflow::new("mixed")
        .add_template(WorkTemplate::new("bad")) // Noop -> ErrorResult
        .add_template(WorkTemplate::new("good").kind(WorkKind::Decision))
        .entry("bad")
        .entry("good");
    let req = p.store.add_request("r", "u", RequestKind::Workflow, wf.to_json());
    let (c, m, t, ca, co) = p.daemons();
    pump(&[&c, &m, &t, &ca, &co], 10_000);
    assert_eq!(
        p.store.get_request(req).unwrap().status,
        RequestStatus::SubFinished,
        "partial failure must surface as SubFinished"
    );
}

#[test]
fn failed_work_does_not_fire_condition_branches() {
    let p = pipeline_with(Arc::new(ErrorResultExecutor));
    let wf = Workflow::new("chain")
        .add_template(WorkTemplate::new("a"))
        .add_template(WorkTemplate::new("b"))
        .add_condition(Condition::always("a", "b"))
        .entry("a");
    let req = p.store.add_request("r", "u", RequestKind::Workflow, wf.to_json());
    let (c, m, t, ca, co) = p.daemons();
    pump(&[&c, &m, &t, &ca, &co], 10_000);
    // only "a" exists; "b" never generated
    assert_eq!(p.store.transforms_of_request(req).len(), 1);
    assert_eq!(p.store.get_request(req).unwrap().status, RequestStatus::Failed);
}

#[test]
fn conductor_messages_mark_failed_works() {
    let p = pipeline_with(Arc::new(ErrorResultExecutor));
    let sub = p.broker.subscribe("idds.work.finished");
    p.store
        .add_request("r", "u", RequestKind::Workflow, one_work().to_json());
    let (c, m, t, ca, co) = p.daemons();
    pump(&[&c, &m, &t, &ca, &co], 10_000);
    let msgs = p.broker.poll(sub, 10);
    assert_eq!(msgs.len(), 1);
    assert_eq!(msgs[0].payload.get("failed").unwrap().as_bool(), Some(true));
}
