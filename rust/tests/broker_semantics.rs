//! Broker semantics suite — the contract the striping refactor must
//! preserve, written against the pre-refactor single-mutex broker and kept
//! green unchanged through the per-topic-lock rework:
//!
//! * per-subscriber FIFO order,
//! * ack-exactly-once (acks are idempotent, double-acks are no-ops),
//! * redelivery after the timeout with an injected [`SimClock`],
//! * multi-subscriber fan-out counts,
//! * `publish_many`/`ack_many` behave exactly like loops of singles,
//! * a multi-thread cross-topic smoke asserting no delivery is lost or
//!   duplicated when publishers and consumers run concurrently.

use std::collections::HashSet;
use std::sync::Arc;

use idds::broker::{Broker, MsgId};
use idds::util::clock::{SimClock, WallClock};
use idds::util::json::Json;

fn wall_broker() -> Broker {
    Broker::new(Arc::new(WallClock::new()))
}

#[test]
fn per_subscriber_fifo_order_across_chunked_polls() {
    let b = wall_broker();
    let s = b.subscribe("t");
    for i in 0..100u64 {
        b.publish("t", Json::Num(i as f64));
    }
    // draining in uneven chunks must still yield ascending payloads
    let mut seen = Vec::new();
    for chunk in [1usize, 7, 13, 29, 100] {
        for d in b.poll(s, chunk) {
            seen.push(d.payload.as_f64().unwrap() as u64);
            b.ack(s, d.id);
        }
    }
    assert_eq!(seen, (0..100).collect::<Vec<_>>(), "per-subscriber FIFO broken");
    assert_eq!(b.backlog(s), 0);
}

#[test]
fn fifo_is_per_subscriber_not_global() {
    let b = wall_broker();
    let s1 = b.subscribe("t");
    let s2 = b.subscribe("t");
    b.publish_many("t", (0..10).map(|i| Json::Num(i as f64)).collect());
    // s2 drains fully before s1 touches anything; both still see FIFO
    let order2: Vec<f64> = b.poll(s2, 100).iter().filter_map(|d| d.payload.as_f64()).collect();
    let order1: Vec<f64> = b.poll(s1, 100).iter().filter_map(|d| d.payload.as_f64()).collect();
    let want: Vec<f64> = (0..10).map(|i| i as f64).collect();
    assert_eq!(order1, want);
    assert_eq!(order2, want);
}

#[test]
fn ack_exactly_once_and_idempotent() {
    let b = wall_broker();
    let s = b.subscribe("t");
    b.publish("t", Json::Str("x".into()));
    let d = b.poll(s, 10);
    assert_eq!(d.len(), 1);
    assert!(b.ack(s, d[0].id), "first ack lands");
    assert!(!b.ack(s, d[0].id), "second ack is a no-op");
    assert!(!b.ack(s, 999_999_999), "unknown id is a no-op");
    assert_eq!(b.stats().acked, 1, "exactly one ack counted");
    assert_eq!(b.backlog(s), 0);
}

#[test]
fn redelivery_after_timeout_with_injected_clock() {
    let clock = SimClock::new();
    let b = Broker::new(clock.clone()).with_redelivery_timeout(10.0);
    let s = b.subscribe("t");
    b.publish("t", Json::Num(7.0));
    let d1 = b.poll(s, 10);
    assert_eq!(d1.len(), 1);
    assert!(!d1[0].redelivered, "first delivery is fresh");

    // inside the window: silent
    clock.advance_by(9.9);
    assert!(b.poll(s, 10).is_empty(), "no redelivery before the timeout");

    // past the window: same id, flagged redelivered, timer re-arms
    clock.advance_by(0.2);
    let d2 = b.poll(s, 10);
    assert_eq!(d2.len(), 1);
    assert_eq!(d2[0].id, d1[0].id);
    assert!(d2[0].redelivered);

    // the redelivery re-armed the deadline: quiet again, then once more
    clock.advance_by(5.0);
    assert!(b.poll(s, 10).is_empty());
    clock.advance_by(6.0);
    let d3 = b.poll(s, 10);
    assert_eq!(d3.len(), 1);
    assert!(d3[0].redelivered);

    // ack finally stops the cycle
    assert!(b.ack(s, d3[0].id));
    clock.advance_by(100.0);
    assert!(b.poll(s, 10).is_empty());
    assert_eq!(b.stats().redelivered, 2);
}

#[test]
fn fanout_reaches_every_subscriber_exactly_once() {
    let b = wall_broker();
    let subs: Vec<_> = (0..5).map(|_| b.subscribe("fan")).collect();
    let late = b.subscribe("other");
    b.publish_many("fan", (0..20).map(|i| Json::Num(i as f64)).collect());
    for &s in &subs {
        let ds = b.poll(s, 100);
        assert_eq!(ds.len(), 20, "every subscriber sees the whole batch");
        let ids: HashSet<MsgId> = ds.iter().map(|d| d.id).collect();
        assert_eq!(ids.len(), 20, "no duplicate ids within one subscriber");
        assert!(b.poll(s, 100).is_empty(), "a drained queue stays drained");
    }
    assert!(b.poll(late, 100).is_empty(), "other topics are isolated");
    assert_eq!(b.stats().published, 20);
    assert_eq!(b.stats().delivered, 100);
}

#[test]
fn subscriber_joining_after_publish_sees_nothing() {
    let b = wall_broker();
    let early = b.subscribe("t");
    b.publish("t", Json::Num(1.0));
    let late = b.subscribe("t");
    assert_eq!(b.poll(early, 10).len(), 1);
    assert!(b.poll(late, 10).is_empty(), "fan-out is at publish time");
}

/// Drive the same operation sequence through the batch APIs on one broker
/// and through loops of singles on another; every observable (deliveries,
/// backlogs, stats) must agree.
#[test]
fn publish_many_and_ack_many_equal_loops_of_singles() {
    let batched = wall_broker();
    let singles = wall_broker();
    let bs1 = batched.subscribe("t");
    let bs2 = batched.subscribe("t");
    let ss1 = singles.subscribe("t");
    let ss2 = singles.subscribe("t");

    let payloads: Vec<Json> = (0..25).map(|i| Json::Num(i as f64)).collect();
    let depth_batched = batched.publish_many("t", payloads.clone());
    let mut depth_singles = 0;
    for p in payloads {
        depth_singles = singles.publish("t", p);
    }
    assert_eq!(depth_batched, depth_singles, "backpressure depth must agree");

    for (broker, s1, s2) in [(&batched, bs1, bs2), (&singles, ss1, ss2)] {
        // drain s1 with ack_many on one broker shape, per-message acks on
        // the logical level: both must leave identical state
        let ds = broker.poll(s1, 100);
        assert_eq!(ds.len(), 25);
        let ids: Vec<MsgId> = ds.iter().map(|d| d.id).collect();
        assert_eq!(broker.ack_many(s1, &ids), 25);
        assert_eq!(broker.ack_many(s1, &ids), 0, "re-ack of a batch is a no-op");
        assert_eq!(broker.backlog(s1), 0);
        assert_eq!(broker.backlog(s2), 25, "the second subscriber is untouched");
    }
    let (a, b) = (batched.stats(), singles.stats());
    assert_eq!(a.published, b.published);
    assert_eq!(a.delivered, b.delivered);
    assert_eq!(a.acked, b.acked);
    assert_eq!(a.redelivered, b.redelivered);
}

#[test]
fn empty_batches_are_noops() {
    let b = wall_broker();
    let s = b.subscribe("t");
    assert_eq!(b.publish_many("t", Vec::new()), 0);
    assert_eq!(b.ack_many(s, &[]), 0);
    assert_eq!(b.stats().published, 0);
    assert_eq!(b.stats().acked, 0);
}

#[test]
fn backlog_counts_pending_plus_in_flight() {
    let b = wall_broker();
    let s = b.subscribe("t");
    b.publish_many("t", (0..10).map(|i| Json::Num(i as f64)).collect());
    assert_eq!(b.backlog(s), 10, "all pending");
    let ds = b.poll(s, 4);
    assert_eq!(ds.len(), 4);
    assert_eq!(b.backlog(s), 10, "in-flight still counts");
    b.ack_many(s, &ds.iter().map(|d| d.id).collect::<Vec<_>>());
    assert_eq!(b.backlog(s), 6);
}

/// Cross-topic concurrency smoke: P publisher threads per topic × T
/// topics, one consumer thread per topic polling and acking until it has
/// everything. No delivery may be lost or duplicated, on any topic.
#[test]
fn multithreaded_cross_topic_no_loss_no_duplication() {
    const TOPICS: usize = 4;
    const PUBLISHERS_PER_TOPIC: usize = 3;
    const MSGS_PER_PUBLISHER: usize = 200;
    const PER_TOPIC: usize = PUBLISHERS_PER_TOPIC * MSGS_PER_PUBLISHER;

    // a timeout no slow CI machine can hit keeps the accounting exact:
    // every message is delivered fresh exactly once
    let b = wall_broker().with_redelivery_timeout(3600.0);
    let subs: Vec<_> = (0..TOPICS).map(|t| b.subscribe(&format!("topic-{t}"))).collect();

    let mut handles = Vec::new();
    for t in 0..TOPICS {
        for p in 0..PUBLISHERS_PER_TOPIC {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                let topic = format!("topic-{t}");
                for i in 0..MSGS_PER_PUBLISHER {
                    b.publish(&topic, Json::Num((p * MSGS_PER_PUBLISHER + i) as f64));
                }
            }));
        }
    }
    // consumers run concurrently with the publishers
    let mut consumers = Vec::new();
    for (t, &sub) in subs.iter().enumerate() {
        let b = b.clone();
        consumers.push(std::thread::spawn(move || {
            let mut got: Vec<u64> = Vec::new();
            let mut seen: HashSet<MsgId> = HashSet::new();
            let mut spins = 0u32;
            while got.len() < PER_TOPIC {
                let ds = b.poll(sub, 64);
                if ds.is_empty() {
                    spins += 1;
                    assert!(spins < 100_000, "topic {t}: stalled at {} deliveries", got.len());
                    std::thread::yield_now();
                    continue;
                }
                let mut ids = Vec::with_capacity(ds.len());
                for d in ds {
                    assert!(seen.insert(d.id), "topic {t}: duplicate delivery {}", d.id);
                    got.push(d.payload.as_f64().unwrap() as u64);
                    ids.push(d.id);
                }
                assert_eq!(b.ack_many(sub, &ids), ids.len());
            }
            got
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    for (t, c) in consumers.into_iter().enumerate() {
        let mut got = c.join().unwrap();
        assert_eq!(got.len(), PER_TOPIC, "topic {t}: wrong delivery count");
        got.sort_unstable();
        let mut want: Vec<u64> = (0..PER_TOPIC as u64).collect();
        want.sort_unstable();
        assert_eq!(got, want, "topic {t}: lost or duplicated payloads");
    }
    for &sub in &subs {
        assert_eq!(b.backlog(sub), 0, "everything was acked");
    }
    let st = b.stats();
    assert_eq!(st.published, (TOPICS * PER_TOPIC) as u64);
    assert_eq!(st.delivered, (TOPICS * PER_TOPIC) as u64);
    assert_eq!(st.acked, (TOPICS * PER_TOPIC) as u64);
    assert_eq!(st.redelivered, 0);
}
