//! Crash-recovery integration tests for the `persist` subsystem: a
//! property test that `recover(checkpoint + WAL suffix)` equals the live
//! store after random interleavings of batched transitions, a torn-tail
//! test, the full kill-and-restart round trip over REST (populate →
//! checkpoint → more batched writes → drop the process state → recover
//! from the data dir → every table and status index matches, and the
//! daemons resume), compiled-workflow round trips (engine state recovered
//! from checkpoint+WAL lets conditions pending at the kill fire after the
//! restart, without duplicating already-fired fan-out), and broker round
//! trips (kill-and-restart preserves per-subscriber backlogs and un-acked
//! in-flight deliveries, plus a property check that the recovered broker
//! equals the live one over random publish/poll/ack interleavings), and
//! the delta-checkpoint chain: a property test interleaving random
//! base/delta checkpoints with random mutations (recover == live for
//! store *and* broker), a kill-between-deltas restart, a corrupt
//! mid-chain delta falling back to the newest intact base, and the
//! WAL-retention rule that makes that fallback lossless (segments are
//! pruned only to the oldest retained *base* cut, never a delta's).

use std::path::PathBuf;
use std::sync::Arc;

use idds::broker::{Broker, MsgId, SubId};
use idds::config::Config;
use idds::daemons::executors::{ExecutorSet, NoopExecutor};
use idds::daemons::{AgentHost, Daemon, Pipeline};
use idds::metrics::Registry;
use idds::persist::{FsyncMode, Persist, PersistOptions};
use idds::rest::{serve, Client, ServerState};
use idds::store::{
    CollectionKind, ContentStatus, Id, MessageStatus, ProcessingStatus, RequestKind,
    RequestStatus, Store, TransformStatus,
};
use idds::util::clock::{SimClock, WallClock};
use idds::util::json::Json;
use idds::util::propcheck::check;
use idds::workflow::{Condition, WorkKind, WorkTemplate, Workflow};

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "idds-recov-{tag}-{}-{}",
        std::process::id(),
        idds::util::next_id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn opts() -> PersistOptions {
    PersistOptions {
        segment_bytes: 16 * 1024, // small: rotation gets exercised
        fsync: FsyncMode::Group,  // tier1 runs this in release, fsync paths live
        checkpoint_keep: 2,
        flush_idle_ms: 2,
        ..PersistOptions::default()
    }
}

fn opts_nofsync() -> PersistOptions {
    PersistOptions { fsync: FsyncMode::Never, ..opts() }
}

fn store() -> Store {
    Store::new(Arc::new(WallClock::new()))
}

/// Canonical snapshot: every table array sorted by id, so stores built in
/// different insertion orders (live vs replayed) compare equal when their
/// contents are equal.
fn canon(mut snap: Json) -> Json {
    if let Json::Obj(m) = &mut snap {
        for arr in m.values_mut() {
            if let Json::Arr(a) = arr {
                a.sort_by_key(|row| row.get("id").and_then(|v| v.as_u64()).unwrap_or(0));
            }
        }
    }
    snap
}

fn assert_stores_equal(live: &Store, recovered: &Store) {
    assert_eq!(
        canon(live.snapshot()),
        canon(recovered.snapshot()),
        "recovered snapshot differs from live store"
    );
    // status indexes, not just rows
    for st in RequestStatus::ALL {
        assert_eq!(
            live.requests_with_status(*st),
            recovered.requests_with_status(*st),
            "request index {st}"
        );
    }
    for st in TransformStatus::ALL {
        assert_eq!(
            live.transforms_with_status(*st),
            recovered.transforms_with_status(*st),
            "transform index {st}"
        );
    }
    for st in ProcessingStatus::ALL {
        assert_eq!(
            live.processings_with_status(*st),
            recovered.processings_with_status(*st),
            "processing index {st}"
        );
    }
    for st in MessageStatus::ALL {
        assert_eq!(
            live.messages_with_status(*st),
            recovered.messages_with_status(*st),
            "message index {st}"
        );
    }
    assert_eq!(live.counts(), recovered.counts());
}

#[test]
fn wal_only_recovery_restores_everything() {
    let dir = tmp_dir("walonly");
    let s = store();
    let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();

    let rid = s.add_request("camp", "alice", RequestKind::DataCarousel, Json::obj().set("w", 1u64));
    s.update_request_status(rid, RequestStatus::Transforming).unwrap();
    let tid = s.add_transform(rid, "w#0", Json::obj().set("kind", "Noop"));
    s.update_transforms_status(&[tid], TransformStatus::Activated);
    let pid = s.add_processing(tid);
    s.update_processings_status(&[pid], ProcessingStatus::Submitting);
    s.set_processing_wfm_task(pid, 424_242).unwrap();
    let cid = s.add_collection(tid, "in", CollectionKind::Input);
    let ids = s.add_contents(cid, (0..200).map(|i| (format!("f{i}"), 10 + i)));
    s.update_contents_status(&ids[..80], ContentStatus::Staging);
    s.update_contents_status(&ids[..40], ContentStatus::Available);
    s.set_content_ddm_file(ids[0], 777).unwrap();
    s.close_collection(cid).unwrap();
    s.add_message("idds.work.finished", Some(tid), Json::obj().set("n", 1u64));
    s.add_message("idds.work.finished", Some(tid), Json::obj().set("n", 2u64));
    s.claim_messages(1);
    p.shutdown();

    let s2 = store();
    let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
    assert!(report.events_replayed > 0);
    assert_eq!(report.torn_bytes, 0);
    assert_stores_equal(&s, &s2);
    assert_eq!(s2.get_content(ids[0]).unwrap().ddm_file, Some(777));
    assert_eq!(s2.get_processing(pid).unwrap().wfm_task, Some(424_242));
    p2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prop_recovery_equals_live_after_random_batched_interleavings() {
    check("recover(checkpoint + wal suffix) == live store", 10, |rng| {
        let dir = tmp_dir("prop");
        let s = store();
        let (p, _) = Persist::open(&dir, opts_nofsync(), &s, Registry::default()).unwrap();

        let mut requests: Vec<Id> = Vec::new();
        let mut transforms: Vec<Id> = Vec::new();
        let mut processings: Vec<Id> = Vec::new();
        let mut contents: Vec<Id> = Vec::new();
        let mut collections: Vec<Id> = Vec::new();
        let n_ops = 120 + rng.below(120);
        let checkpoint_at = rng.below(n_ops);
        for op_i in 0..n_ops {
            if op_i == checkpoint_at {
                p.checkpoint(&s).map_err(|e| format!("checkpoint failed: {e}"))?;
            }
            match rng.below(12) {
                0 => requests.push(s.add_request(
                    &format!("r{op_i}"),
                    "u",
                    RequestKind::Workflow,
                    Json::Null,
                )),
                1 if !requests.is_empty() => {
                    let k = 1 + rng.below(requests.len() as u64) as usize;
                    let to = *rng.choose(RequestStatus::ALL);
                    s.update_requests_status(&requests[..k], to);
                }
                2 if !requests.is_empty() => {
                    let rid = requests[rng.below(requests.len() as u64) as usize];
                    transforms.push(s.add_transform(rid, &format!("t{op_i}"), Json::Null));
                }
                3 if !transforms.is_empty() => {
                    let k = 1 + rng.below(transforms.len() as u64) as usize;
                    let to = *rng.choose(TransformStatus::ALL);
                    s.update_transforms_status(&transforms[..k], to);
                }
                4 if !transforms.is_empty() => {
                    let tid = transforms[rng.below(transforms.len() as u64) as usize];
                    processings.push(s.add_processing(tid));
                }
                5 if !processings.is_empty() => {
                    let k = 1 + rng.below(processings.len() as u64) as usize;
                    let to = *rng.choose(ProcessingStatus::ALL);
                    s.update_processings_status(&processings[..k], to);
                }
                6 if !transforms.is_empty() => {
                    let tid = transforms[rng.below(transforms.len() as u64) as usize];
                    let cid = s.add_collection(tid, &format!("c{op_i}"), CollectionKind::Input);
                    collections.push(cid);
                    contents.extend(s.add_contents(
                        cid,
                        (0..1 + rng.below(40)).map(|i| (format!("f{op_i}/{i}"), 1u64)),
                    ));
                }
                7 if !contents.is_empty() => {
                    let k = 1 + rng.below(contents.len().min(200) as u64) as usize;
                    let start = rng.below((contents.len() - k) as u64 + 1) as usize;
                    let to = *rng.choose(ContentStatus::ALL);
                    s.update_contents_status(&contents[start..start + k], to);
                }
                8 if !transforms.is_empty() => {
                    let tid = transforms[rng.below(transforms.len() as u64) as usize];
                    let _ = s.update_transform_work(tid, Json::obj().set("i", op_i));
                    let _ = s.bump_transform_retries(tid);
                }
                9 if !processings.is_empty() => {
                    let pid = processings[rng.below(processings.len() as u64) as usize];
                    let _ = s.set_processing_wfm_task(pid, 10_000 + op_i);
                }
                10 => {
                    s.add_message("t", None, Json::Num(op_i as f64));
                    if rng.bool(0.3) {
                        s.claim_messages(1 + rng.below(4) as usize);
                    }
                }
                11 if !collections.is_empty() => {
                    let cid = collections[rng.below(collections.len() as u64) as usize];
                    let _ = s.close_collection(cid);
                }
                _ => {}
            }
        }
        p.shutdown();

        let s2 = store();
        let (p2, _report) = Persist::open(&dir, opts_nofsync(), &s2, Registry::default())
            .map_err(|e| format!("recovery failed: {e}"))?;
        let live = canon(s.snapshot());
        let recovered = canon(s2.snapshot());
        if live != recovered {
            return Err(format!(
                "recovered state diverged after {n_ops} ops (checkpoint at {checkpoint_at})"
            ));
        }
        p2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

#[test]
fn torn_tail_truncated_to_clean_prefix() {
    let dir = tmp_dir("torn");
    let s = store();
    let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
    let ids: Vec<Id> = (0..30)
        .map(|i| s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null))
        .collect();
    s.update_requests_status(&ids, RequestStatus::Transforming);
    p.flush();
    // everything up to here survives; the NEXT event is the one we damage
    let clean_prefix_state = canon(s.snapshot());
    s.update_request_status(ids[0], RequestStatus::Finished).unwrap();
    let expect_full = canon(s.snapshot());
    p.shutdown();

    // crash mid-write: cut 5 bytes out of the last frame of the newest
    // segment — that frame is exactly the single Finished transition
    let wal_dir = dir.join("wal");
    let mut segs: Vec<PathBuf> = std::fs::read_dir(&wal_dir)
        .unwrap()
        .filter_map(|e| {
            let p = e.unwrap().path();
            (p.extension().map(|x| x == "log").unwrap_or(false)
                && std::fs::metadata(&p).unwrap().len() > 16)
                .then_some(p)
        })
        .collect();
    segs.sort();
    let last = segs.pop().expect("a non-empty wal segment");
    let full = std::fs::metadata(&last).unwrap().len();
    std::fs::OpenOptions::new()
        .write(true)
        .open(&last)
        .unwrap()
        .set_len(full - 5)
        .unwrap();

    let s2 = store();
    let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
    assert!(report.torn_bytes > 0, "torn tail must be detected");
    // the clean prefix survived intact, the damaged frame did not
    assert_eq!(canon(s2.snapshot()), clean_prefix_state);
    assert_eq!(
        s2.get_request(ids[0]).unwrap().status,
        RequestStatus::Transforming,
        "the torn Finished transition must be gone"
    );
    // the segment file itself was truncated to the clean prefix
    assert!(std::fs::metadata(&last).unwrap().len() < full - 5);
    // re-apply the lost transition and persist it through the new WAL head
    s2.update_request_status(ids[0], RequestStatus::Finished).unwrap();
    p2.shutdown();

    // recovery after the repair reaches the original state again
    let s3 = store();
    let (p3, report3) = Persist::open(&dir, opts(), &s3, Registry::default()).unwrap();
    assert_eq!(report3.torn_bytes, 0, "torn tail already truncated");
    assert_eq!(canon(s3.snapshot()), expect_full);
    p3.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn recovery_is_stable_across_repeated_restarts() {
    let dir = tmp_dir("stable");
    let s = store();
    let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
    let ids: Vec<Id> = (0..40)
        .map(|i| s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null))
        .collect();
    s.update_requests_status(&ids[..20], RequestStatus::Transforming);
    p.checkpoint(&s).unwrap();
    s.update_requests_status(&ids[..10], RequestStatus::Finished);
    p.shutdown();
    let expect = canon(s.snapshot());

    for round in 0..3 {
        let sr = store();
        let (pr, _) = Persist::open(&dir, opts(), &sr, Registry::default()).unwrap();
        assert_eq!(canon(sr.snapshot()), expect, "round {round} diverged");
        pr.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn broker_backlogs_and_inflight_survive_kill_and_restart() {
    let dir = tmp_dir("bkill");
    let s = store();
    let clock = SimClock::new();
    let b = Broker::new(clock.clone()).with_redelivery_timeout(30.0);
    let (p, _) =
        Persist::open_with_broker(&dir, opts(), &s, Some(&b), Registry::default()).unwrap();

    // two consumers on the conductor topic, one on an unrelated topic,
    // and one that unsubscribes before the kill
    let c1 = b.subscribe("idds.work.finished");
    let c2 = b.subscribe("idds.work.finished");
    let other = b.subscribe("idds.other");
    let quitter = b.subscribe("idds.work.finished");
    b.publish_many("idds.work.finished", (0..10).map(|i| Json::Num(i as f64)).collect());
    b.publish("idds.other", Json::Str("o".into()));
    // c1 takes 4 in flight and acks 2 of them; c2 stays fully backlogged
    let ds = b.poll(c1, 4);
    assert_eq!(b.ack_many(c1, &[ds[0].id, ds[1].id]), 2);
    p.checkpoint(&s).unwrap();
    // post-checkpoint traffic lives only in the WAL suffix
    assert!(b.unsubscribe(quitter));
    b.publish_many("idds.work.finished", (10..13).map(|i| Json::Num(i as f64)).collect());
    b.poll(c2, 1);
    p.shutdown(); // kill

    let s2 = store();
    let clock2 = SimClock::new();
    let b2 = Broker::new(clock2.clone()).with_redelivery_timeout(30.0);
    let (p2, report) =
        Persist::open_with_broker(&dir, opts(), &s2, Some(&b2), Registry::default()).unwrap();
    assert!(report.checkpoint_seq.is_some());
    assert!(report.events_replayed > 0, "the broker WAL suffix must replay");
    assert_eq!(b.snapshot_json(), b2.snapshot_json(), "recovered broker differs from live");

    // queued backlogs per subscriber survive the restart
    assert_eq!(b2.backlog(c1), 11, "9 pending + 2 un-acked in-flight");
    assert_eq!(b2.backlog(c2), 13, "12 pending + 1 in-flight");
    assert_eq!(b2.backlog(other), 1);
    // the suffix unsubscribe replayed: the quitter's checkpointed queue
    // is gone, and it saw none of the suffix publishes
    assert_eq!(b2.backlog(quitter), 0, "unsubscribe in the WAL suffix must replay");
    assert!(b2.poll(quitter, 10).is_empty());

    // pending messages flow immediately and in the original order (c2's
    // message 0 is in flight, so 1..13 are still queued)
    let fresh: Vec<f64> = b2.poll(c2, 100).iter().filter_map(|d| d.payload.as_f64()).collect();
    assert_eq!(fresh, (1..13).map(|i| i as f64).collect::<Vec<_>>());

    // un-acked in-flight stays invisible until the re-armed timeout
    // passes, then redelivers flagged as redelivered
    clock2.advance_by(31.0);
    let ds3 = b2.poll(c1, 100);
    assert_eq!(ds3.len(), 11);
    let mut redelivered: Vec<MsgId> =
        ds3.iter().filter(|d| d.redelivered).map(|d| d.id).collect();
    redelivered.sort_unstable();
    let mut want = vec![ds[2].id, ds[3].id];
    want.sort_unstable();
    assert_eq!(redelivered, want, "exactly the pre-kill un-acked in-flight redelivers");

    // draining and acking everything empties the recovered queues
    let all: Vec<MsgId> = ds3.iter().map(|d| d.id).collect();
    assert_eq!(b2.ack_many(c1, &all), 11);
    assert_eq!(b2.backlog(c1), 0);
    p2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prop_broker_recovery_equals_live_after_random_interleavings() {
    check("recover(checkpoint + wal suffix) == live broker", 10, |rng| {
        let dir = tmp_dir("bprop");
        let s = store();
        let clock = SimClock::new();
        let b = Broker::new(clock.clone()).with_redelivery_timeout(5.0);
        let (p, _) =
            Persist::open_with_broker(&dir, opts_nofsync(), &s, Some(&b), Registry::default())
                .map_err(|e| format!("open failed: {e}"))?;
        let topics = ["alpha", "beta", "gamma"];
        let mut subs: Vec<SubId> = Vec::new();
        let mut unacked: Vec<(SubId, MsgId)> = Vec::new();
        let n_ops = 80 + rng.below(80);
        let checkpoint_at = rng.below(n_ops);
        for op_i in 0..n_ops {
            if op_i == checkpoint_at {
                p.checkpoint(&s).map_err(|e| format!("checkpoint failed: {e}"))?;
            }
            match rng.below(11) {
                0 | 1 if subs.len() < 12 => {
                    subs.push(b.subscribe(rng.choose(&topics)));
                }
                10 if subs.len() > 2 => {
                    // rare consumer churn: dropped queues must also drop
                    // identically on the recovered side (acks of their
                    // old deliveries become no-ops on both)
                    let i = rng.below(subs.len() as u64) as usize;
                    b.unsubscribe(subs.swap_remove(i));
                }
                2..=4 => {
                    let topic = *rng.choose(&topics);
                    let n = 1 + rng.below(5);
                    b.publish_many(
                        topic,
                        (0..n).map(|i| Json::Num((op_i * 100 + i) as f64)).collect(),
                    );
                }
                5..=7 if !subs.is_empty() => {
                    let sub = subs[rng.below(subs.len() as u64) as usize];
                    for d in b.poll(sub, 1 + rng.below(6) as usize) {
                        unacked.push((sub, d.id));
                    }
                }
                8 if !unacked.is_empty() => {
                    let k = 1 + rng.below(unacked.len().min(8) as u64) as usize;
                    for (sub, id) in unacked.drain(..k) {
                        b.ack(sub, id);
                    }
                }
                // time passing makes later polls exercise the redelivery
                // (deadline-renewal) event path too
                9 => clock.advance_by(rng.below(8) as f64),
                _ => {}
            }
        }
        p.shutdown();

        let s2 = store();
        let b2 = Broker::new(SimClock::new()).with_redelivery_timeout(5.0);
        let (p2, _) =
            Persist::open_with_broker(&dir, opts_nofsync(), &s2, Some(&b2), Registry::default())
                .map_err(|e| format!("recovery failed: {e}"))?;
        if b.snapshot_json() != b2.snapshot_json() {
            return Err(format!(
                "broker state diverged after {n_ops} ops (checkpoint at {checkpoint_at})"
            ));
        }
        p2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

fn delta_file(dir: &std::path::Path, seq: u64) -> PathBuf {
    dir.join(format!("checkpoint-{seq:08}.delta.json"))
}

#[test]
fn prop_delta_chain_recovery_equals_live() {
    check("recover(base + delta chain + wal suffix) == live store+broker", 8, |rng| {
        let dir = tmp_dir("dprop");
        let s = store();
        let clock = SimClock::new();
        let b = Broker::new(clock.clone()).with_redelivery_timeout(5.0);
        let (p, _) =
            Persist::open_with_broker(&dir, opts_nofsync(), &s, Some(&b), Registry::default())
                .map_err(|e| format!("open failed: {e}"))?;
        let topics = ["alpha", "beta"];
        let mut subs: Vec<SubId> = Vec::new();
        let mut requests: Vec<Id> = Vec::new();
        let mut transforms: Vec<Id> = Vec::new();
        let mut contents: Vec<Id> = Vec::new();
        let mut unacked: Vec<(SubId, MsgId)> = Vec::new();
        let mut checkpoints = 0u32;
        let n_ops = 100 + rng.below(100);
        for op_i in 0..n_ops {
            if rng.bool(0.08) {
                // checkpoints (base or delta, randomly) interleave the
                // mutations at random points — the delta chain must fold
                // to the same state every base+WAL recovery reaches
                let rep = if rng.bool(0.3) {
                    p.checkpoint_full(&s)
                } else {
                    p.checkpoint_delta(&s)
                };
                rep.map_err(|e| format!("checkpoint failed: {e}"))?;
                checkpoints += 1;
            }
            match rng.below(10) {
                0 => requests.push(s.add_request(
                    &format!("r{op_i}"),
                    "u",
                    RequestKind::Workflow,
                    Json::Null,
                )),
                1 if !requests.is_empty() => {
                    let k = 1 + rng.below(requests.len() as u64) as usize;
                    let to = *rng.choose(RequestStatus::ALL);
                    s.update_requests_status(&requests[..k], to);
                }
                2 if !requests.is_empty() => {
                    let rid = requests[rng.below(requests.len() as u64) as usize];
                    transforms.push(s.add_transform(rid, &format!("t{op_i}"), Json::Null));
                }
                3 if !transforms.is_empty() => {
                    let k = 1 + rng.below(transforms.len() as u64) as usize;
                    let to = *rng.choose(TransformStatus::ALL);
                    s.update_transforms_status(&transforms[..k], to);
                }
                4 if !transforms.is_empty() => {
                    let tid = transforms[rng.below(transforms.len() as u64) as usize];
                    let cid = s.add_collection(tid, &format!("c{op_i}"), CollectionKind::Input);
                    contents.extend(s.add_contents(
                        cid,
                        (0..1 + rng.below(20)).map(|i| (format!("f{op_i}/{i}"), 1u64)),
                    ));
                }
                5 if !contents.is_empty() => {
                    let k = 1 + rng.below(contents.len().min(100) as u64) as usize;
                    let start = rng.below((contents.len() - k) as u64 + 1) as usize;
                    let to = *rng.choose(ContentStatus::ALL);
                    s.update_contents_status(&contents[start..start + k], to);
                }
                6 if subs.len() < 8 => {
                    subs.push(b.subscribe(rng.choose(&topics)));
                }
                7 => {
                    let n = 1 + rng.below(4);
                    b.publish_many(
                        rng.choose(&topics),
                        (0..n).map(|i| Json::Num((op_i * 10 + i) as f64)).collect(),
                    );
                }
                8 if !subs.is_empty() => {
                    let sub = subs[rng.below(subs.len() as u64) as usize];
                    for d in b.poll(sub, 1 + rng.below(4) as usize) {
                        unacked.push((sub, d.id));
                    }
                }
                9 if !unacked.is_empty() => {
                    let k = 1 + rng.below(unacked.len().min(6) as u64) as usize;
                    for (sub, id) in unacked.drain(..k) {
                        b.ack(sub, id);
                    }
                }
                _ => {}
            }
        }
        p.shutdown();

        let s2 = store();
        let b2 = Broker::new(SimClock::new()).with_redelivery_timeout(5.0);
        let (p2, _report) =
            Persist::open_with_broker(&dir, opts_nofsync(), &s2, Some(&b2), Registry::default())
                .map_err(|e| format!("recovery failed: {e}"))?;
        if canon(s.snapshot()) != canon(s2.snapshot()) {
            return Err(format!(
                "store diverged after {n_ops} ops ({checkpoints} checkpoints)"
            ));
        }
        if b.snapshot_json() != b2.snapshot_json() {
            return Err(format!(
                "broker diverged after {n_ops} ops ({checkpoints} checkpoints)"
            ));
        }
        p2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

#[test]
fn kill_between_deltas_restarts_from_chain() {
    let dir = tmp_dir("deltakill");
    let s = store();
    let clock = SimClock::new();
    let b = Broker::new(clock.clone()).with_redelivery_timeout(30.0);
    let (p, _) =
        Persist::open_with_broker(&dir, opts(), &s, Some(&b), Registry::default()).unwrap();
    let c1 = b.subscribe("idds.out");
    let ids: Vec<Id> = (0..30)
        .map(|i| s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null))
        .collect();
    let base = p.checkpoint_full(&s).unwrap();
    assert!(base.full);

    // churn → delta 1 (store rows + broker topic)
    s.update_requests_status(&ids[..10], RequestStatus::Transforming);
    b.publish_many("idds.out", (0..5).map(|i| Json::Num(i as f64)).collect());
    let d1 = p.checkpoint_delta(&s).unwrap();
    assert!(!d1.full);
    assert_eq!(d1.base_seq, base.seq);
    assert_eq!(d1.rows, 10, "delta 1 carries exactly the churned request rows");

    // churn → delta 2
    let ds = b.poll(c1, 2);
    assert!(b.ack(c1, ds[0].id));
    s.update_requests_status(&ids[10..15], RequestStatus::Transforming);
    let d2 = p.checkpoint_delta(&s).unwrap();
    assert_eq!(d2.chain_len, 2);

    // WAL suffix past the chain tail, then kill
    s.update_requests_status(&ids[..5], RequestStatus::Finished);
    b.publish("idds.out", Json::Num(99.0));
    p.shutdown();
    let expect_store = canon(s.snapshot());
    let expect_broker = b.snapshot_json();

    assert!(delta_file(&dir, d1.seq).exists());
    assert!(delta_file(&dir, d2.seq).exists());

    // restart: base + 2 deltas + WAL suffix
    let s2 = store();
    let b2 = Broker::new(SimClock::new()).with_redelivery_timeout(30.0);
    let (p2, report) =
        Persist::open_with_broker(&dir, opts(), &s2, Some(&b2), Registry::default()).unwrap();
    assert_eq!(report.checkpoint_seq, Some(base.seq));
    assert_eq!(report.deltas_folded, 2);
    assert_eq!(report.start_lsn, d2.start_lsn, "replay starts at the chain tail cut");
    assert_eq!(canon(s2.snapshot()), expect_store);
    assert_eq!(b2.snapshot_json(), expect_broker);
    assert_eq!(b2.backlog(c1), 5, "3 pending + 1 un-acked in-flight + 1 suffix publish");
    p2.shutdown();

    // restart again: recovery over an on-disk chain is stable
    let s3 = store();
    let b3 = Broker::new(SimClock::new()).with_redelivery_timeout(30.0);
    let (p3, report3) =
        Persist::open_with_broker(&dir, opts(), &s3, Some(&b3), Registry::default()).unwrap();
    assert_eq!(report3.deltas_folded, 2);
    assert_eq!(canon(s3.snapshot()), expect_store);
    assert_eq!(b3.snapshot_json(), expect_broker);
    p3.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_middle_delta_falls_back_to_newest_base() {
    let dir = tmp_dir("corruptdelta");
    let s = store();
    let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
    let ids: Vec<Id> = (0..20)
        .map(|i| s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null))
        .collect();
    let base = p.checkpoint_full(&s).unwrap();
    s.update_requests_status(&ids[..5], RequestStatus::Transforming);
    let d1 = p.checkpoint_delta(&s).unwrap();
    s.update_requests_status(&ids[..5], RequestStatus::Finished);
    let d2 = p.checkpoint_delta(&s).unwrap();
    s.update_requests_status(&ids[5..8], RequestStatus::Transforming);
    let d3 = p.checkpoint_delta(&s).unwrap();
    assert_eq!(d3.chain_len, 3);
    p.shutdown();
    let expect = canon(s.snapshot());

    // damage the MIDDLE link only
    let victim = delta_file(&dir, d2.seq);
    std::fs::write(&victim, b"{ not a checkpoint").unwrap();

    let s2 = store();
    let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
    assert_eq!(report.checkpoint_seq, Some(base.seq));
    assert_eq!(report.deltas_folded, 0, "a broken chain is discarded wholesale");
    assert_eq!(report.start_lsn, base.start_lsn, "replay restarts at the base cut");
    // nothing invented, nothing lost: WAL retention reaches back to the
    // base cut (deltas never moved the prune horizon), so the suffix
    // reconstructs everything the discarded deltas held
    assert_eq!(canon(s2.snapshot()), expect);
    // the corrupt link was set aside; the stale rest of the chain cannot
    // confuse the next boot
    assert!(!victim.exists());
    assert!(victim.with_extension("json.corrupt").exists());
    assert!(!delta_file(&dir, d1.seq).exists());
    assert!(!delta_file(&dir, d3.seq).exists());
    p2.shutdown();

    // and the next boot reaches the same state again
    let s3 = store();
    let (p3, _) = Persist::open(&dir, opts(), &s3, Registry::default()).unwrap();
    assert_eq!(canon(s3.snapshot()), expect);
    p3.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn wal_retention_covers_base_fallback_after_delta_checkpoints() {
    // regression pin for the retention rule: after delta checkpoints the
    // WAL must still reach back to the *base's* cut (not the newest
    // delta's) — removing every delta must leave a fully recoverable dir
    let dir = tmp_dir("deltaretention");
    let s = store();
    let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
    let ids: Vec<Id> = (0..15)
        .map(|i| s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null))
        .collect();
    p.checkpoint_full(&s).unwrap();
    s.update_requests_status(&ids[..6], RequestStatus::Transforming);
    let d1 = p.checkpoint_delta(&s).unwrap();
    s.update_requests_status(&ids[..3], RequestStatus::Finished);
    let d2 = p.checkpoint_delta(&s).unwrap();
    assert_eq!(
        d1.segments_deleted + d2.segments_deleted,
        0,
        "delta checkpoints must not move the WAL prune horizon"
    );
    p.shutdown();
    let expect = canon(s.snapshot());

    // a hostile fault: the whole chain disappears
    std::fs::remove_file(delta_file(&dir, d1.seq)).unwrap();
    std::fs::remove_file(delta_file(&dir, d2.seq)).unwrap();

    let s2 = store();
    let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
    assert_eq!(report.deltas_folded, 0);
    assert_eq!(
        canon(s2.snapshot()),
        expect,
        "base + WAL alone must reconstruct everything the deltas held"
    );
    p2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

fn two_step() -> Workflow {
    Workflow::new("two-step")
        .add_template(WorkTemplate::new("prep"))
        .add_template(WorkTemplate::new("main"))
        .add_condition(Condition::always("prep", "main"))
        .entry("prep")
}

struct Stack {
    client: Client,
    store: Store,
    persist: Persist,
    host: AgentHost,
    server: idds::rest::HttpServer,
}

fn stack(dir: &std::path::Path) -> Stack {
    let clock = Arc::new(WallClock::new());
    let store = Store::new(clock.clone());
    let (persist, _report) =
        Persist::open(dir, opts(), &store, Registry::default()).unwrap();
    let broker = Broker::new(clock);
    let metrics = Registry::default();
    let cfg = Config::defaults();
    let executors =
        ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default()));
    let pipeline = Pipeline::new(store.clone(), broker.clone(), metrics.clone(), executors);
    let (c, m, t, ca, co) = pipeline.daemons();
    let daemons: Vec<Arc<dyn Daemon>> = vec![
        Arc::new(c),
        Arc::new(m),
        Arc::new(t),
        Arc::new(ca),
        Arc::new(co),
    ];
    let host = AgentHost::start(daemons, std::time::Duration::from_millis(2));
    let server = serve(
        ServerState::new(store.clone(), broker, metrics, &cfg).with_persist(persist.clone()),
        &cfg,
    )
    .unwrap();
    let client = Client::new(server.addr, "dev-token");
    Stack { client, store, persist, host, server }
}

#[test]
fn kill_and_restart_roundtrip_over_rest() {
    let dir = tmp_dir("killrestart");

    // 1. populate via REST and let the daemons run campaigns to completion
    let s = stack(&dir);
    for i in 0..3 {
        let req = s
            .client
            .submit(&format!("camp{i}"), "alice", RequestKind::Workflow, &two_step())
            .unwrap();
        let status = s
            .client
            .wait_terminal(req, std::time::Duration::from_secs(30))
            .unwrap();
        assert_eq!(status, RequestStatus::Finished);
    }

    // 2. checkpoint on demand over REST
    let report = s.client.checkpoint().unwrap();
    assert!(report.get("seq").and_then(|v| v.as_u64()).is_some());
    // health now reports durability state
    let health = s.client.health().unwrap();
    assert!(health.get_path(&["persist", "durable_lsn"]).is_some());
    assert!(health.get_path(&["generations", "requests"]).is_some());

    // quiesce the daemons before the direct-write phase so the pre-kill
    // state is deterministic (a Clerk would pick the new request up)
    let Stack { client, store: live, persist, host, server } = s;
    host.stop();

    // 3. more batched writes AFTER the checkpoint (the WAL suffix)
    let rid = live.add_request("carousel", "bob", RequestKind::DataCarousel, Json::Null);
    let tid = live.add_transform(rid, "stage", Json::Null);
    let cid = live.add_collection(tid, "in-ds", CollectionKind::Input);
    let files = live.add_contents(cid, (0..500).map(|i| (format!("f{i}"), 1_000u64 + i)));
    assert_eq!(live.update_contents_status(&files[..250], ContentStatus::Staging), 250);
    assert_eq!(live.update_contents_status(&files[..100], ContentStatus::Available), 100);
    persist.flush();

    // 4. drop the process state (server, daemons, flusher, store)
    let expect = canon(live.snapshot());
    server.stop();
    persist.shutdown();
    drop(client);

    // 5. recover from the data dir into a brand-new store
    let s2 = store();
    let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
    assert!(report.checkpoint_seq.is_some(), "checkpoint must be found");
    assert!(report.events_replayed > 0, "the WAL suffix must replay");
    assert_stores_equal(&live, &s2);
    assert_eq!(expect, canon(s2.snapshot()));
    assert_eq!(s2.count_contents(cid, ContentStatus::Available), 100);
    assert_eq!(s2.count_contents(cid, ContentStatus::Staging), 150);
    assert_eq!(s2.count_contents(cid, ContentStatus::New), 250);
    assert!(
        s2.requests_generation() > 0,
        "replay must bump generations so change-driven polling re-arms"
    );

    // 6. daemons resume on the recovered store: new work still flows
    let broker = Broker::new(Arc::new(WallClock::new()));
    let metrics = Registry::default();
    let executors =
        ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default()));
    let pipeline = Pipeline::new(s2.clone(), broker, metrics, executors);
    let (c, m, t, ca, co) = pipeline.daemons();
    let req = s2.add_request("post-recovery", "alice", RequestKind::Workflow, two_step().to_json());
    idds::daemons::pump(&[&c, &m, &t, &ca, &co], 1000);
    assert_eq!(s2.get_request(req).unwrap().status, RequestStatus::Finished);

    p2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

fn three_chain() -> Workflow {
    Workflow::new("three-chain")
        .add_template(WorkTemplate::new("a"))
        .add_template(WorkTemplate::new("b"))
        .add_template(WorkTemplate::new("c"))
        .add_condition(Condition::always("a", "b"))
        .add_condition(Condition::always("b", "c"))
        .entry("a")
}

fn noop_pipeline(store: &Store) -> Pipeline {
    Pipeline::new(
        store.clone(),
        Broker::new(Arc::new(WallClock::new())),
        Registry::default(),
        ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default())),
    )
}

#[test]
fn pending_workflow_condition_fires_after_kill_and_restart() {
    let dir = tmp_dir("wfpending");
    let s = store();
    let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
    let req = s.add_request("camp", "alice", RequestKind::Workflow, three_chain().to_json());
    {
        // run everything EXCEPT the Marshaller: 'a' finishes, but its
        // condition branch (a → b) is still pending when the process dies
        let pl = noop_pipeline(&s);
        let (clerk, _marsh, tfr, carrier, conductor) = pl.daemons();
        idds::daemons::pump(&[&clerk, &tfr, &carrier, &conductor], 1000);
    }
    assert_eq!(s.transforms_of_request(req).len(), 1, "only 'a' may exist pre-kill");
    assert_eq!(s.get_request(req).unwrap().status, RequestStatus::Transforming);
    assert!(
        !s.get_request(req).unwrap().engine.is_null(),
        "the Clerk must have persisted engine state"
    );
    p.shutdown(); // kill

    // recover into a brand-new store + pipeline (empty engines map): the
    // engine must be re-interned from the request's definition, resumed
    // from the persisted state, and the pending condition must fire
    let s2 = store();
    let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
    assert!(report.events_replayed > 0);
    assert_eq!(
        s2.get_request(req).unwrap().engine,
        s.get_request(req).unwrap().engine,
        "engine state must survive the WAL round trip"
    );
    let pl2 = noop_pipeline(&s2);
    let (clerk, marsh, tfr, carrier, conductor) = pl2.daemons();
    idds::daemons::pump(&[&clerk, &marsh, &tfr, &carrier, &conductor], 1000);
    let names: Vec<String> = s2
        .transforms_of_request(req)
        .into_iter()
        .map(|t| s2.get_transform(t).unwrap().name)
        .collect();
    assert_eq!(names.len(), 3, "b and c must materialize after the restart: {names:?}");
    assert_eq!(s2.get_request(req).unwrap().status, RequestStatus::Finished);
    p2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn completed_workflow_does_not_refire_after_kill_and_restart() {
    let dir = tmp_dir("wfnorefire");
    let s = store();
    let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
    let req = s.add_request("camp", "alice", RequestKind::Workflow, three_chain().to_json());
    {
        let pl = noop_pipeline(&s);
        let (clerk, marsh, tfr, carrier, conductor) = pl.daemons();
        idds::daemons::pump(&[&clerk, &marsh, &tfr, &carrier, &conductor], 1000);
    }
    assert_eq!(s.get_request(req).unwrap().status, RequestStatus::Finished);
    assert_eq!(s.transforms_of_request(req).len(), 3);
    p.shutdown(); // kill

    // after recovery a fresh Marshaller re-walks every terminal transform
    // (its in-memory marshalled set died with the process); the recovered
    // completed-instance set must make that walk a no-op
    let s2 = store();
    let (p2, _) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
    let pl2 = noop_pipeline(&s2);
    let (clerk, marsh, tfr, carrier, conductor) = pl2.daemons();
    idds::daemons::pump(&[&clerk, &marsh, &tfr, &carrier, &conductor], 1000);
    assert_eq!(
        s2.transforms_of_request(req).len(),
        3,
        "re-marshalling a finished request must not duplicate fan-out"
    );
    assert_eq!(s2.get_request(req).unwrap().status, RequestStatus::Finished);
    for tid in s2.transforms_of_request(req) {
        assert_eq!(s2.get_transform(tid).unwrap().status, TransformStatus::Finished);
    }
    p2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
