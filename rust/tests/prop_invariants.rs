//! Property tests over the coordinator invariants (propcheck harness):
//! store transition legality, workflow-engine conservation, carousel
//! conservation, broker at-least-once, JSON round-trip.

use std::sync::Arc;

use idds::broker::Broker;
use idds::carousel::{run_campaign, CampaignSpec, CarouselConfig, Granularity};
use idds::store::{
    ContentStatus, ProcessingStatus, RequestKind, RequestStatus, Store, TransformStatus,
};
use idds::util::clock::{SimClock, WallClock};
use idds::util::json::Json;
use idds::util::propcheck::check;
use idds::util::rng::Rng;
use idds::workflow::{Condition, Engine, Predicate, WorkTemplate, Workflow};

fn rand_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.bool(0.5)),
        2 => Json::Num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
        3 => {
            let n = rng.below(12) as usize;
            Json::Str(
                (0..n)
                    .map(|_| char::from_u32(rng.range(32, 0x2FA0) as u32).unwrap_or('x'))
                    .collect(),
            )
        }
        4 => Json::Arr((0..rng.below(5)).map(|_| rand_json(rng, depth - 1)).collect()),
        _ => {
            let mut o = Json::obj();
            for i in 0..rng.below(5) {
                o = o.set(&format!("k{i}"), rand_json(rng, depth - 1));
            }
            o
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    check("json parse(serialize(x)) == x", 300, |rng| {
        let j = rand_json(rng, 3);
        let text = j.to_string();
        let back = idds::util::json::parse(&text)
            .map_err(|e| format!("parse failed: {e} on {text}"))?;
        if back != j {
            return Err(format!("mismatch: {j} vs {back}"));
        }
        Ok(())
    });
}

#[test]
fn prop_store_status_transitions_always_legal() {
    check("random status walks never corrupt indexes", 50, |rng| {
        let store = Store::new(Arc::new(WallClock::new()));
        let rid = store.add_request("r", "u", RequestKind::Workflow, Json::Null);
        let tid = store.add_transform(rid, "t", Json::Null);
        let pid = store.add_processing(tid);
        for _ in 0..60 {
            match rng.below(3) {
                0 => {
                    let to = *rng.choose(RequestStatus::ALL);
                    let _ = store.update_request_status(rid, to);
                }
                1 => {
                    let to = *rng.choose(TransformStatus::ALL);
                    let _ = store.update_transform_status(tid, to);
                }
                _ => {
                    let to = *rng.choose(ProcessingStatus::ALL);
                    let _ = store.update_processing_status(pid, to);
                }
            }
        }
        // index consistency: the record's status set contains exactly it
        let req = store.get_request(rid).unwrap();
        let ids = store.requests_with_status(req.status);
        if !ids.contains(&rid) {
            return Err(format!("request index lost id (status {})", req.status));
        }
        for s in RequestStatus::ALL {
            if *s != req.status && store.requests_with_status(*s).contains(&rid) {
                return Err(format!("request in two indexes: {s} and {}", req.status));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_content_counters_match_reality() {
    check("per-collection status counters are exact", 30, |rng| {
        let store = Store::new(Arc::new(WallClock::new()));
        let rid = store.add_request("r", "u", RequestKind::DataCarousel, Json::Null);
        let tid = store.add_transform(rid, "t", Json::Null);
        let cid = store.add_collection(tid, "in", idds::store::CollectionKind::Input);
        let n = 50 + rng.below(200) as usize;
        let ids = store.add_contents(cid, (0..n).map(|i| (format!("f{i}"), 1u64)));
        for _ in 0..100 {
            let k = 1 + rng.below(ids.len() as u64 / 2) as usize;
            let start = rng.below((ids.len() - k) as u64 + 1) as usize;
            let to = *rng.choose(ContentStatus::ALL);
            store.update_contents_status(&ids[start..start + k], to);
        }
        // counters must equal a full scan
        let mut scan = std::collections::HashMap::new();
        for id in &ids {
            *scan.entry(store.get_content(*id).unwrap().status).or_insert(0usize) += 1;
        }
        for s in ContentStatus::ALL {
            let counted = store.count_contents(cid, *s);
            let scanned = scan.get(s).copied().unwrap_or(0);
            if counted != scanned {
                return Err(format!("status {s}: counter {counted} != scan {scanned}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_engine_conserves_works() {
    check("every generated Work is unique and capped", 50, |rng| {
        let cap = 1 + rng.below(20) as u32;
        let wf = Workflow::new("p")
            .add_template(WorkTemplate::new("a").max_instances(cap))
            .add_template(WorkTemplate::new("b").max_instances(cap))
            .add_condition(Condition::always("a", "b"))
            .add_condition(Condition::when("b", "a", Predicate::truthy("again")))
            .entry("a");
        let mut e = Engine::new(wf).unwrap();
        let mut frontier = e.start();
        let mut seen = std::collections::HashSet::new();
        let mut steps = 0;
        while let Some(w) = frontier.pop() {
            if !seen.insert(w.instance) {
                return Err(format!("duplicate work instance {}", w.instance));
            }
            steps += 1;
            if steps > 10_000 {
                return Err("engine did not terminate".into());
            }
            let result = Json::obj().set("again", rng.bool(0.7));
            frontier.extend(e.on_complete(&w, &result).map_err(|e| e.to_string())?);
        }
        if e.instance_count("a") > cap || e.instance_count("b") > cap {
            return Err("cycle bound exceeded".into());
        }
        Ok(())
    });
}

#[test]
fn prop_carousel_conservation() {
    check("fine carousel: every file staged+processed exactly once", 8, |rng| {
        let spec = CampaignSpec {
            datasets: 1 + rng.below(3) as usize,
            files_per_dataset: 20 + rng.below(80) as usize,
            mean_file_mb: rng.range_f64(100.0, 4000.0),
            cartridges_per_dataset: 1 + rng.below(4) as u32,
            seed: rng.next_u64(),
        };
        let cfg = CarouselConfig {
            granularity: Granularity::Fine,
            staging_window: 4 + rng.below(60) as usize,
            tape_drives: 1 + rng.below(6) as usize,
            sites: 1 + rng.below(4) as u32,
            slots_per_site: 4 + rng.below(30) as usize,
            files_per_job: 1 + rng.below(3) as usize,
            ..Default::default()
        };
        let r = run_campaign(&cfg, &spec);
        let files = spec.datasets * spec.files_per_dataset;
        if r.files != files {
            return Err(format!("files {} != {}", r.files, files));
        }
        if r.exhausted_jobs != 0 {
            return Err(format!("{} exhausted jobs in fine mode", r.exhausted_jobs));
        }
        if r.failed_attempts != 0 {
            return Err(format!("{} failed attempts in fine mode", r.failed_attempts));
        }
        if r.total_attempts as usize != r.jobs {
            return Err(format!(
                "attempts {} != jobs {} (must be exactly one per job)",
                r.total_attempts, r.jobs
            ));
        }
        // staged everything exactly once: last staged_files sample == files
        let staged = r.timeline.series("staged_files");
        let last = staged.last().map(|(_, v)| *v as usize).unwrap_or(0);
        if last != files {
            return Err(format!("staged {last} != {files}"));
        }
        Ok(())
    });
}

#[test]
fn prop_broker_at_least_once() {
    check("every published message is delivered (ack or redeliver)", 30, |rng| {
        let clock = SimClock::new();
        let b = Broker::new(clock.clone()).with_redelivery_timeout(5.0);
        let sub = b.subscribe("t");
        let n = 1 + rng.below(100) as usize;
        for i in 0..n {
            b.publish("t", Json::Num(i as f64));
        }
        let mut acked = std::collections::HashSet::new();
        let mut rounds = 0;
        while acked.len() < n {
            rounds += 1;
            if rounds > 1000 {
                return Err(format!("only {}/{} acked", acked.len(), n));
            }
            for d in b.poll(sub, 10) {
                // randomly drop (simulating consumer crash before ack)
                if rng.bool(0.7) {
                    b.ack(sub, d.id);
                    acked.insert(
                        d.payload.as_f64().map(|f| f as u64).unwrap_or(u64::MAX),
                    );
                }
            }
            clock.advance_by(6.0); // expire unacked
        }
        if acked.len() != n {
            return Err("lost messages".into());
        }
        Ok(())
    });
}
