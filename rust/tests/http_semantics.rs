//! HTTP semantics pinning suite (see ISSUE 9 / DESIGN.md "REST server").
//!
//! Written against the *blocking* thread-per-connection server and
//! required to pass unchanged against its nonblocking epoll replacement:
//! wire-level keep-alive framing, error statuses, timeout behavior, and
//! route reachability are the contract; the transport underneath is
//! swappable. Tests drive raw `TcpStream`s (byte dribbles, half-closes,
//! pipelined writes) because the `Client` abstraction would hide exactly
//! the framing bugs this suite exists to pin.
//!
//! The stress section at the bottom (idle-connection scaling, admission
//! control) targets the nonblocking server and is additive — everything
//! above it is byte-identical to the pre-rework commit.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use idds::broker::Broker;
use idds::config::Config;
use idds::metrics::Registry;
use idds::rest::http::{http_request, HttpServer, Response, ServerOptions, MAX_BODY};
use idds::rest::{serve, Client, ServerState};
use idds::store::{RequestKind, Store};
use idds::util::clock::WallClock;
use idds::util::json::{parse, Json};
use idds::workflow::{WorkTemplate, Workflow};

// ---------------------------------------------------------------------
// raw-socket helpers
// ---------------------------------------------------------------------

/// One keep-alive connection driven at the byte level: writes go out raw,
/// responses are parsed by Content-Length framing like a real client.
struct RawConn {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

struct RawResponse {
    status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl RawResponse {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

impl RawConn {
    fn connect(addr: SocketAddr) -> RawConn {
        let s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        s.set_nodelay(true).unwrap();
        RawConn {
            r: BufReader::new(s.try_clone().unwrap()),
            w: s,
        }
    }

    fn send(&mut self, bytes: &[u8]) {
        self.w.write_all(bytes).expect("send");
        self.w.flush().unwrap();
    }

    /// Parse one response off the wire; `None` on clean EOF before a
    /// status line (i.e. the server closed the connection).
    fn read_response(&mut self) -> Option<RawResponse> {
        let mut status_line = String::new();
        if self.r.read_line(&mut status_line).expect("status line") == 0 {
            return None;
        }
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .unwrap_or_else(|| panic!("bad status line {status_line:?}"))
            .parse()
            .expect("status code");
        let mut headers = Vec::new();
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            assert_ne!(self.r.read_line(&mut h).expect("header line"), 0, "eof in headers");
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            let (k, v) = h.split_once(':').expect("header colon");
            let (k, v) = (k.trim().to_string(), v.trim().to_string());
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.parse().expect("content-length");
            }
            headers.push((k, v));
        }
        let mut body = vec![0u8; content_length];
        self.r.read_exact(&mut body).expect("body");
        Some(RawResponse {
            status,
            headers,
            body,
        })
    }
}

/// Serialize a request with Content-Length framing (keep-alive unless a
/// `Connection` header is passed explicitly).
fn req_bytes(method: &str, path: &str, headers: &[(&str, &str)], body: &[u8]) -> Vec<u8> {
    let mut out = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n",
        body.len()
    );
    for (k, v) in headers {
        out.push_str(&format!("{k}: {v}\r\n"));
    }
    out.push_str("\r\n");
    let mut out = out.into_bytes();
    out.extend_from_slice(body);
    out
}

/// Echo server: responds with the parsed method/path/body so the tests
/// can detect any mis-framing or cross-connection mix-up.
fn echo_server(opts: ServerOptions) -> HttpServer {
    HttpServer::serve_with_options("127.0.0.1:0", opts, |req| {
        let body = String::from_utf8_lossy(&req.body).into_owned();
        Response::json(
            200,
            Json::obj()
                .set("method", req.method.as_str())
                .set("path", req.path.as_str())
                .set("body", body.as_str())
                .set("len", req.body.len()),
        )
    })
    .expect("bind echo server")
}

fn echo_json(resp: &RawResponse) -> Json {
    parse(std::str::from_utf8(&resp.body).expect("utf8 body")).expect("json body")
}

// ---------------------------------------------------------------------
// pinned wire semantics
// ---------------------------------------------------------------------

#[test]
fn keep_alive_reuse_across_sequential_requests() {
    let s = echo_server(ServerOptions::default());
    let mut c = RawConn::connect(s.addr);

    c.send(&req_bytes("GET", "/first", &[], b""));
    let r1 = c.read_response().expect("first response");
    assert_eq!(r1.status, 200);
    assert_eq!(r1.header("connection"), Some("keep-alive"));
    assert_eq!(echo_json(&r1).get("path").unwrap().as_str(), Some("/first"));

    c.send(&req_bytes("POST", "/second", &[], b"payload-2"));
    let r2 = c.read_response().expect("second response on same conn");
    assert_eq!(r2.status, 200);
    let j = echo_json(&r2);
    assert_eq!(j.get("path").unwrap().as_str(), Some("/second"));
    assert_eq!(j.get("body").unwrap().as_str(), Some("payload-2"));
    s.stop();
}

#[test]
fn connection_close_is_honored() {
    let s = echo_server(ServerOptions::default());
    let mut c = RawConn::connect(s.addr);
    c.send(&req_bytes("GET", "/bye", &[("Connection", "close")], b""));
    let r = c.read_response().expect("response");
    assert_eq!(r.status, 200);
    assert_eq!(r.header("connection"), Some("close"));
    assert!(c.read_response().is_none(), "server must close after Connection: close");
    s.stop();
}

#[test]
fn oversized_declared_body_gets_413() {
    let s = echo_server(ServerOptions::default());
    let mut c = RawConn::connect(s.addr);
    // declare a body past MAX_BODY but never send it: the server must
    // reject on the declaration alone, without waiting for the bytes
    c.send(
        format!(
            "POST /big HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY + 1
        )
        .as_bytes(),
    );
    let r = c.read_response().expect("413 response");
    assert_eq!(r.status, 413);
    assert!(c.read_response().is_none(), "connection closes after 413");
    s.stop();
}

#[test]
fn malformed_request_line_gets_400_and_listener_survives() {
    let s = echo_server(ServerOptions::default());
    let mut bad = RawConn::connect(s.addr);
    bad.send(b"GARBAGE\r\n\r\n");
    let r = bad.read_response().expect("400 response");
    assert_eq!(r.status, 400);
    assert!(bad.read_response().is_none(), "connection closes after 400");

    // the listener is unharmed: a fresh connection works
    let mut ok = RawConn::connect(s.addr);
    ok.send(&req_bytes("GET", "/after", &[], b""));
    assert_eq!(ok.read_response().expect("listener alive").status, 200);
    s.stop();
}

#[test]
fn malformed_content_length_gets_400() {
    let s = echo_server(ServerOptions::default());
    let mut c = RawConn::connect(s.addr);
    c.send(b"POST /x HTTP/1.1\r\nHost: test\r\nContent-Length: banana\r\n\r\n");
    let r = c.read_response().expect("400 response");
    assert_eq!(r.status, 400);
    s.stop();
}

#[test]
fn slow_header_client_times_out_without_pinning_others() {
    let s = echo_server(ServerOptions {
        workers: 2,
        header_timeout: Duration::from_millis(300),
        ..ServerOptions::default()
    });
    // stall mid-request-line and never finish
    let mut slow = RawConn::connect(s.addr);
    slow.send(b"GET /slow HT");
    let t0 = Instant::now();

    // an unrelated client gets served promptly despite the stalled conn
    let mut busy = RawConn::connect(s.addr);
    busy.send(&req_bytes("GET", "/busy", &[], b""));
    assert_eq!(busy.read_response().expect("busy response").status, 200);
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "busy client waited {:?} behind a stalled header",
        t0.elapsed()
    );

    // the stalled conn is answered with an error and closed within the
    // header deadline window (the exact status is transport-era specific:
    // the blocking server says 400, the event loop 408)
    let r = slow.read_response().expect("timeout response");
    assert!(r.status >= 400, "expected an error status, got {}", r.status);
    assert!(slow.read_response().is_none(), "server closes timed-out conn");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "header timeout did not fire in time ({:?})",
        t0.elapsed()
    );
    s.stop();
}

#[test]
fn content_length_mismatch_short_body_gets_400() {
    let s = echo_server(ServerOptions::default());
    let mut c = RawConn::connect(s.addr);
    // declare 10 bytes, deliver 5, then half-close: the server sees EOF
    // mid-body and must answer 400 on the still-open write side
    c.send(b"POST /y HTTP/1.1\r\nHost: test\r\nContent-Length: 10\r\n\r\nhello");
    c.w.shutdown(Shutdown::Write).unwrap();
    let r = c.read_response().expect("400 response");
    assert_eq!(r.status, 400);
    assert!(c.read_response().is_none());
    s.stop();
}

#[test]
fn content_length_excess_bytes_parse_as_garbage_next_request() {
    let s = echo_server(ServerOptions::default());
    let mut c = RawConn::connect(s.addr);
    // 5 declared body bytes followed by trailing garbage in the same
    // segment: the garbage must be framed as the *next* request (and
    // rejected), never folded into the first body
    let mut bytes = req_bytes("POST", "/exact", &[], b"hello");
    bytes.extend_from_slice(b"XYZ\r\n\r\n");
    c.send(&bytes);
    let r1 = c.read_response().expect("first response");
    assert_eq!(r1.status, 200);
    let j = echo_json(&r1);
    assert_eq!(j.get("body").unwrap().as_str(), Some("hello"));
    assert_eq!(j.get("len").unwrap().as_u64(), Some(5));
    let r2 = c.read_response().expect("garbage framed as second request");
    assert_eq!(r2.status, 400);
    assert!(c.read_response().is_none());
    s.stop();
}

#[test]
fn pipelined_requests_in_one_write_get_ordered_responses() {
    let s = echo_server(ServerOptions::default());
    let mut c = RawConn::connect(s.addr);
    let mut bytes = req_bytes("GET", "/pipe1", &[], b"");
    bytes.extend_from_slice(&req_bytes("POST", "/pipe2", &[], b"second"));
    c.send(&bytes);
    let r1 = c.read_response().expect("pipelined response 1");
    assert_eq!(echo_json(&r1).get("path").unwrap().as_str(), Some("/pipe1"));
    let r2 = c.read_response().expect("pipelined response 2");
    let j = echo_json(&r2);
    assert_eq!(j.get("path").unwrap().as_str(), Some("/pipe2"));
    assert_eq!(j.get("body").unwrap().as_str(), Some("second"));
    s.stop();
}

#[test]
fn byte_dribble_mid_header_is_not_misframed() {
    let s = echo_server(ServerOptions::default());
    let mut c = RawConn::connect(s.addr);
    // two keep-alive requests delivered a few bytes per TCP segment —
    // header names, the blank line, and the body all get split across
    // reads; the parser must reassemble without mis-framing
    for (path, body) in [("/dribble-a", "dribble-body-one"), ("/dribble-b", "x")] {
        let bytes = req_bytes("POST", path, &[("X-Dribble", "yes")], body.as_bytes());
        for chunk in bytes.chunks(3) {
            c.send(chunk);
            std::thread::sleep(Duration::from_millis(2));
        }
        let r = c.read_response().expect("dribbled response");
        assert_eq!(r.status, 200);
        let j = echo_json(&r);
        assert_eq!(j.get("path").unwrap().as_str(), Some(path));
        assert_eq!(j.get("body").unwrap().as_str(), Some(body));
    }
    s.stop();
}

#[test]
fn concurrent_connections_see_no_crosstalk() {
    let s = echo_server(ServerOptions {
        workers: 8,
        ..ServerOptions::default()
    });
    let addr = s.addr;
    let handles: Vec<_> = (0..8)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = RawConn::connect(addr);
                for i in 0..6 {
                    let body = format!("thread-{t}-req-{i}-{}", "z".repeat(t * 17 + i));
                    let path = format!("/t{t}/r{i}");
                    c.send(&req_bytes("POST", &path, &[], body.as_bytes()));
                    let r = c.read_response().expect("response");
                    assert_eq!(r.status, 200);
                    let j = echo_json(&r);
                    assert_eq!(j.get("path").unwrap().as_str(), Some(path.as_str()));
                    assert_eq!(j.get("body").unwrap().as_str(), Some(body.as_str()));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    s.stop();
}

// ---------------------------------------------------------------------
// route reachability: the full REST head behind the real transport
// ---------------------------------------------------------------------

fn full_stack() -> (HttpServer, Client) {
    let clock = Arc::new(WallClock::new());
    let store = Store::new(clock.clone());
    let broker = Broker::new(clock);
    let metrics = Registry::default();
    let cfg = Config::defaults();
    let server = serve(ServerState::new(store, broker, metrics, &cfg), &cfg).expect("serve");
    let client = Client::new(server.addr, "dev-token");
    (server, client)
}

fn authed(addr: SocketAddr, method: &str, path: &str, body: &[u8]) -> (u16, Vec<u8>) {
    http_request(
        addr,
        method,
        path,
        &[
            ("Authorization", "Bearer dev-token"),
            ("Content-Type", "application/json"),
        ],
        body,
    )
    .expect("request")
}

#[test]
fn every_route_stays_reachable_through_the_real_transport() {
    let (server, client) = full_stack();
    let addr = server.addr;

    // request lifecycle via the Client
    let wf = Workflow::new("pin").add_template(WorkTemplate::new("only")).entry("only");
    let id = client.submit("pin-campaign", "pin-user", RequestKind::Workflow, &wf).unwrap();
    client.request_status(id).unwrap();
    let summary = client.summary(id).unwrap();
    assert!(summary.get("transforms").is_some());
    assert!(client.cancel(id).unwrap());

    // messaging via the Client
    let sub = client.subscribe("idds.out").unwrap();
    assert!(client.poll_messages(sub, 8).unwrap().is_empty());
    assert!(!client.ack(sub, 999_999).unwrap(), "bogus ack is a no-op");
    assert!(client.unsubscribe(sub).unwrap());

    // health carries the rest section
    let health = client.health().unwrap();
    assert!(health.get("rest").is_some());

    // raw-status routes
    assert_eq!(authed(addr, "GET", "/api/requests?status=New", b"").0, 200);
    assert_eq!(authed(addr, "GET", "/api/metrics", b"").0, 200);
    assert_eq!(authed(addr, "GET", "/api/metrics?format=prometheus", b"").0, 200);
    assert_eq!(authed(addr, "GET", "/api/traces", b"").0, 200);
    assert_eq!(authed(addr, "GET", "/api/nope", b"").0, 404);
    // no persistence configured on this stack
    assert_eq!(authed(addr, "POST", "/api/admin/checkpoint", b"").0, 503);
    assert_eq!(authed(addr, "GET", "/api/replication/wal?from_lsn=0", b"").0, 503);
    assert_eq!(authed(addr, "GET", "/api/replication/snapshot", b"").0, 503);
    // not a replica; epoch 0 is never newer; no worker registry attached
    assert_eq!(authed(addr, "POST", "/api/admin/promote", b"").0, 400);
    assert_eq!(authed(addr, "POST", "/api/replication/fence", br#"{"epoch": 0}"#).0, 409);
    assert_eq!(
        authed(addr, "POST", "/api/workers", br#"{"name": "w", "kinds": ["Noop"]}"#).0,
        503
    );
    // auth is enforced on the wire
    let (unauth, _) = http_request(addr, "GET", "/api/health", &[], b"").unwrap();
    assert_eq!(unauth, 401);

    server.stop();
}

// ---------------------------------------------------------------------
// stress: the nonblocking server under connection and dispatch pressure
// (additive; everything above is the pre-rework pin)
// ---------------------------------------------------------------------

/// Soft `RLIMIT_NOFILE` via raw FFI (the tree is dependency-free, like
/// the server's own epoll shim). Falls back to a conservative 1024 if
/// the syscall fails.
fn nofile_soft() -> u64 {
    #[repr(C)]
    struct RLimit {
        rlim_cur: u64,
        rlim_max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
    }
    #[cfg(target_os = "linux")]
    const RLIMIT_NOFILE: i32 = 7;
    #[cfg(not(target_os = "linux"))]
    const RLIMIT_NOFILE: i32 = 8;
    let mut r = RLimit {
        rlim_cur: 0,
        rlim_max: 0,
    };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut r) } == 0 {
        r.rlim_cur
    } else {
        1024
    }
}

#[test]
fn idle_keepalive_fleet_does_not_starve_busy_clients() {
    // each RawConn costs ~3 fds (client stream + BufReader clone + the
    // server side); leave 600 for the harness, and cap the fleet so the
    // test stays fast on machines with huge fd limits
    let fleet = (nofile_soft().saturating_sub(600) / 4).min(1500) as usize;
    assert!(fleet >= 64, "fd limit too low for a meaningful fleet ({fleet})");

    let metrics = Registry::default();
    let s = echo_server(ServerOptions {
        workers: 4,
        max_connections: fleet + 64,
        metrics: metrics.clone(),
        ..ServerOptions::default()
    });

    // park `fleet` keep-alive connections, each proven live by one
    // round-trip so the server has really accepted and served it
    let mut parked = Vec::with_capacity(fleet);
    for i in 0..fleet {
        let mut c = RawConn::connect(s.addr);
        c.send(&req_bytes("GET", &format!("/park/{i}"), &[], b""));
        assert_eq!(c.read_response().expect("park response").status, 200);
        parked.push(c);
    }
    assert!(
        metrics.gauge("rest.conn.open").get() >= fleet as i64,
        "open-connection gauge below fleet size"
    );

    // a busy client must see prompt service with the whole fleet parked:
    // idle sockets cost the loop nothing until they become readable
    let mut busy = RawConn::connect(s.addr);
    for i in 0..50 {
        let t0 = Instant::now();
        let path = format!("/busy/{i}");
        busy.send(&req_bytes("GET", &path, &[], b""));
        let r = busy.read_response().expect("busy response");
        assert_eq!(r.status, 200);
        assert_eq!(echo_json(&r).get("path").unwrap().as_str(), Some(path.as_str()));
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "request {i} took {:?} behind {fleet} idle connections",
            t0.elapsed()
        );
    }
    drop(parked);
    s.stop();
}

#[test]
fn overload_sheds_with_503_retry_after_and_recovers() {
    let metrics = Registry::default();
    let s = echo_server(ServerOptions {
        workers: 2,
        max_connections: 32,
        metrics: metrics.clone(),
        ..ServerOptions::default()
    });

    // fill the table: one round-trip each guarantees all 32 are accepted
    // before the overflow connection arrives
    let mut held = Vec::new();
    for _ in 0..32 {
        let mut c = RawConn::connect(s.addr);
        c.send(&req_bytes("GET", "/hold", &[], b""));
        assert_eq!(c.read_response().expect("hold response").status, 200);
        held.push(c);
    }

    // the 33rd is shed: 503 + Retry-After, then closed — never queued
    let mut extra = RawConn::connect(s.addr);
    extra.send(&req_bytes("GET", "/extra", &[], b""));
    let r = extra.read_response().expect("shed response");
    assert_eq!(r.status, 503);
    assert_eq!(r.header("retry-after"), Some("1"), "shed 503 must carry Retry-After");
    assert!(extra.read_response().is_none(), "shed connection is closed");
    assert!(metrics.counter("rest.conn.shed").get() >= 1);

    // release one slot and the server recovers: a fresh connection gets
    // served as soon as the loop notices the close
    drop(held.pop());
    let t0 = Instant::now();
    loop {
        let mut c = RawConn::connect(s.addr);
        c.send(&req_bytes("GET", "/recovered", &[], b""));
        match c.read_response() {
            Some(r) if r.status == 200 => break,
            Some(r) => assert_eq!(r.status, 503, "unexpected status {}", r.status),
            None => {}
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "server did not recover a shed slot within 5s"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    s.stop();
}

#[test]
fn inflight_cap_rejects_excess_with_retry_after() {
    let metrics = Registry::default();
    let opts = ServerOptions {
        workers: 8,
        max_inflight: 4,
        metrics: metrics.clone(),
        ..ServerOptions::default()
    };
    // slow handler: holds a dispatch slot long enough for the barrier'd
    // burst below to overrun the cap deterministically
    let s = HttpServer::serve_with_options("127.0.0.1:0", opts, |req| {
        std::thread::sleep(Duration::from_millis(400));
        Response::json(200, Json::obj().set("path", req.path.as_str()))
    })
    .expect("bind slow server");

    let addr = s.addr;
    let barrier = Arc::new(std::sync::Barrier::new(8));
    let handles: Vec<_> = (0..8)
        .map(|i| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut c = RawConn::connect(addr);
                barrier.wait();
                c.send(&req_bytes("GET", &format!("/burst/{i}"), &[], b""));
                let r = c.read_response().expect("burst response");
                if r.status == 503 {
                    assert_eq!(
                        r.header("retry-after"),
                        Some("1"),
                        "inflight 503 must carry Retry-After"
                    );
                    // the rejection keeps the connection usable
                    assert_eq!(r.header("connection"), Some("keep-alive"));
                }
                r.status
            })
        })
        .collect();
    let statuses: Vec<u16> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let ok = statuses.iter().filter(|&&st| st == 200).count();
    let shed = statuses.iter().filter(|&&st| st == 503).count();
    assert_eq!(ok + shed, 8, "unexpected statuses: {statuses:?}");
    assert!(ok >= 4, "cap must still admit up to max_inflight ({statuses:?})");
    assert!(shed >= 1, "burst past the cap must see a 503 ({statuses:?})");
    assert!(metrics.counter("rest.conn.rejected_inflight").get() >= 1);
    s.stop();
}
