//! Event bus + SSE push delivery integration tests, over real sockets:
//!
//! * the catch-up → live-tail seam: a watcher arriving mid-write-storm
//!   sees every LSN exactly once — no gap, no duplicate — even though its
//!   history comes from WAL segments and its tail from the in-memory bus;
//! * bounded subscriber queues: a reader that falls too far behind is cut
//!   off with a terminal `overflow` event carrying the last delivered
//!   LSN, and resuming from `last_lsn + 1` restores a dense stream;
//! * a slow (unread) subscriber never stalls a fast one — publishers
//!   drop, they do not block;
//! * filter correctness: a table filter selects every op on that table
//!   and nothing else; an op filter selects exactly that op;
//! * pruned history answers `410 Gone`, and a fresh live tail still works.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use idds::broker::Broker;
use idds::config::Config;
use idds::metrics::Registry;
use idds::persist::{BusPersister, EventBus, FsyncMode, Persist, PersistOptions};
use idds::rest::{serve, Client, ServerState};
use idds::store::{RequestKind, Store};
use idds::util::clock::WallClock;
use idds::util::json::Json;
use idds::workflow::{WorkTemplate, Workflow};

const TOKEN: &str = "dev-token";

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "idds-events-{tag}-{}-{}",
        std::process::id(),
        idds::util::next_id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn one_step() -> Workflow {
    Workflow::new("w").add_template(WorkTemplate::new("a")).entry("a")
}

/// A head stack with the event bus armed and the daemons parked, so the
/// only WAL traffic is what each test writes — LSNs are predictable.
struct Stack {
    client: Client,
    persist: Option<Persist>,
    store: Store,
    _server: idds::rest::HttpServer,
    dir: Option<PathBuf>,
}

impl Drop for Stack {
    fn drop(&mut self) {
        self._server.stop();
        if let Some(p) = &self.persist {
            p.shutdown();
        }
        if let Some(d) = &self.dir {
            std::fs::remove_dir_all(d).ok();
        }
    }
}

/// Durable stack: events publish from the WAL group-commit flusher, and
/// `GET /api/events?from_lsn=` catch-up reads real segments.
fn durable_stack(dir: &Path, queue: usize, segment_bytes: u64) -> Stack {
    let clock = Arc::new(WallClock::new());
    let store = Store::new(clock.clone());
    let broker = Broker::new(clock);
    let metrics = Registry::default();
    let bus = EventBus::new(&metrics);
    let popts = PersistOptions {
        segment_bytes,
        fsync: FsyncMode::Never,
        flush_idle_ms: 2,
        ..PersistOptions::default()
    };
    let (persist, _) =
        Persist::open_with_broker(dir, popts, &store, Some(&broker), metrics.clone()).unwrap();
    persist.wal().set_bus(bus.clone());
    let mut cfg = Config::defaults();
    cfg.put("events.queue", Json::Num(queue as f64));
    let server = serve(
        ServerState::new(store.clone(), broker, metrics, &cfg)
            .with_persist(persist.clone())
            .with_bus(bus),
        &cfg,
    )
    .unwrap();
    let client = Client::new(server.addr, TOKEN);
    Stack { client, persist: Some(persist), store, _server: server, dir: Some(dir.to_path_buf()) }
}

/// In-memory stack: the store/broker apply paths publish directly.
fn memory_stack(queue: usize) -> Stack {
    let clock = Arc::new(WallClock::new());
    let store = Store::new(clock.clone());
    let broker = Broker::new(clock);
    let metrics = Registry::default();
    let bus = EventBus::new(&metrics);
    store.set_persister(Arc::new(BusPersister::new(bus.clone())));
    broker.set_persister(Arc::new(BusPersister::new(bus.clone())));
    let mut cfg = Config::defaults();
    cfg.put("events.queue", Json::Num(queue as f64));
    let server = serve(
        ServerState::new(store.clone(), broker, metrics, &cfg).with_bus(bus),
        &cfg,
    )
    .unwrap();
    let client = Client::new(server.addr, TOKEN);
    Stack { client, persist: None, store, _server: server, dir: None }
}

/// Collect events until `done` says stop (or the deadline passes; the
/// assertion then happens at the caller on whatever was collected).
fn collect_until(
    watch: &mut idds::rest::WatchEvents,
    timeout: Duration,
    mut done: impl FnMut(&[idds::rest::SseEvent]) -> bool,
) -> Vec<idds::rest::SseEvent> {
    let deadline = Instant::now() + timeout;
    let mut got = Vec::new();
    while !done(&got) {
        let now = Instant::now();
        if now >= deadline || watch.ended() {
            break;
        }
        if let Some(ev) = watch.next_within(deadline - now).unwrap() {
            got.push(ev);
        }
    }
    got
}

#[test]
fn seam_has_no_gap_and_no_duplicate_under_concurrent_writers() {
    let dir = tmp_dir("seam");
    let s = durable_stack(&dir, 1024, 1 << 20);
    const WRITERS: u64 = 4;
    const PER: u64 = 25;
    const TOTAL: u64 = WRITERS * PER;

    // half the storm lands before the watch opens (exercises WAL
    // catch-up), the other half races the live tail
    let addr = s._server.addr;
    let mut handles = Vec::new();
    for w in 0..WRITERS / 2 {
        handles.push(std::thread::spawn(move || {
            let c = Client::new(addr, TOKEN);
            for i in 0..PER {
                c.submit(&format!("a{w}-{i}"), "u", RequestKind::Workflow, &one_step()).unwrap();
            }
        }));
    }
    for h in handles.drain(..) {
        h.join().unwrap();
    }

    let mut watch = s.client.watch_events(Some(1), None).unwrap();
    for w in 0..WRITERS / 2 {
        handles.push(std::thread::spawn(move || {
            let c = Client::new(addr, TOKEN);
            for i in 0..PER {
                c.submit(&format!("b{w}-{i}"), "u", RequestKind::Workflow, &one_step()).unwrap();
            }
        }));
    }
    let got = collect_until(&mut watch, Duration::from_secs(30), |g| g.len() as u64 >= TOTAL);
    for h in handles {
        h.join().unwrap();
    }

    let lsns: Vec<u64> = got.iter().map(|e| e.lsn).collect();
    let expect: Vec<u64> = (1..=TOTAL).collect();
    assert_eq!(
        lsns, expect,
        "the catch-up → live seam must deliver every LSN exactly once, in order"
    );
    assert!(got.iter().all(|e| e.op == "add_request"));
}

#[test]
fn overflow_is_terminal_and_resume_restores_a_dense_stream() {
    let dir = tmp_dir("overflow");
    let s = durable_stack(&dir, 4, 1 << 20);
    const TOTAL: u64 = 60;

    let mut watch = s.client.watch_events(None, None).unwrap();
    // one primer event proves the subscription is live before the flood
    s.client.submit("primer", "u", RequestKind::Workflow, &one_step()).unwrap();
    let first = collect_until(&mut watch, Duration::from_secs(10), |g| !g.is_empty());
    assert_eq!(first.len(), 1);
    assert_eq!(first[0].lsn, 1);

    // flood without reading: the 4-slot queue must overflow
    for i in 1..TOTAL {
        s.client.submit(&format!("f{i}"), "u", RequestKind::Workflow, &one_step()).unwrap();
    }
    let mut pre: Vec<u64> = vec![1];
    let mut resume_from = 0u64;
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        assert!(Instant::now() < deadline, "terminal overflow event never arrived");
        let Some(ev) = watch.next_within(Duration::from_secs(5)).unwrap() else {
            assert!(!watch.ended(), "stream closed without a terminal overflow event");
            continue;
        };
        if ev.op == "overflow" {
            resume_from = ev.data.get("last_lsn").and_then(|v| v.as_u64()).unwrap() + 1;
            break;
        }
        pre.push(ev.lsn);
    }
    // the frames delivered before the cut are exactly 1..resume_from
    assert_eq!(pre, (1..resume_from).collect::<Vec<u64>>());
    assert!(resume_from <= TOTAL, "overflow must have dropped something");
    // after the terminal event the server closes the stream
    let tail = watch.next_within(Duration::from_secs(5)).unwrap();
    assert!(tail.is_none() && watch.ended(), "overflow is terminal");

    // resuming at last_lsn + 1 replays the dropped suffix from the WAL
    let mut resumed = s.client.watch_events(Some(resume_from), None).unwrap();
    let rest = collect_until(&mut resumed, Duration::from_secs(20), |g| {
        g.last().is_some_and(|e| e.lsn >= TOTAL)
    });
    let all: BTreeSet<u64> = pre.iter().copied().chain(rest.iter().map(|e| e.lsn)).collect();
    assert_eq!(
        all,
        (1..=TOTAL).collect::<BTreeSet<u64>>(),
        "pre-overflow + resumed events must cover every LSN exactly once"
    );
}

#[test]
fn slow_subscriber_does_not_stall_a_fast_one() {
    let dir = tmp_dir("slowfast");
    let s = durable_stack(&dir, 1024, 1 << 20);
    const TOTAL: u64 = 40;

    // the slow watcher connects and then never reads its socket
    let mut slow = s.client.watch_events(None, None).unwrap();
    let mut fast = s.client.watch_events(None, None).unwrap();
    for i in 0..TOTAL {
        s.client.submit(&format!("s{i}"), "u", RequestKind::Workflow, &one_step()).unwrap();
    }
    let got = collect_until(&mut fast, Duration::from_secs(20), |g| g.len() as u64 >= TOTAL);
    assert_eq!(
        got.iter().map(|e| e.lsn).collect::<Vec<u64>>(),
        (1..=TOTAL).collect::<Vec<u64>>(),
        "the fast subscriber's feed is complete while the slow one sits unread"
    );
    // the slow one lost nothing either — it was merely buffered (socket +
    // queue), not dropped, because it stayed within its queue bound
    let lag = collect_until(&mut slow, Duration::from_secs(20), |g| g.len() as u64 >= TOTAL);
    assert_eq!(lag.len() as u64, TOTAL);
}

#[test]
fn filters_select_by_table_and_by_op() {
    let s = memory_stack(1024);

    // op filter: exactly the request_status transitions, nothing else
    let mut by_op = s.client.watch_events(None, Some("request_status")).unwrap();
    // table filter: every op touching the requests table, nothing else
    let mut by_table = s.client.watch_events(None, Some("requests")).unwrap();

    let ids: Vec<u64> = (0..3)
        .map(|i| {
            s.client.submit(&format!("r{i}"), "u", RequestKind::Workflow, &one_step()).unwrap()
        })
        .collect();
    s.client.cancel(ids[0]).unwrap();
    s.client.cancel(ids[1]).unwrap();
    // broker traffic must be invisible to both watchers
    s.client.subscribe("idds.some.topic").unwrap();

    let ops = collect_until(&mut by_op, Duration::from_secs(10), |g| g.len() >= 2);
    assert_eq!(ops.len(), 2);
    assert!(ops.iter().all(|e| e.op == "request_status"));

    let table = collect_until(&mut by_table, Duration::from_secs(10), |g| g.len() >= 5);
    assert_eq!(table.len(), 5, "3 submits + 2 cancels all touch the requests table");
    assert_eq!(table.iter().filter(|e| e.op == "add_request").count(), 3);
    assert_eq!(table.iter().filter(|e| e.op == "request_status").count(), 2);
    // a short grace: the broker_subscribe event must never arrive
    assert!(by_table.next_within(Duration::from_millis(200)).unwrap().is_none());
    assert!(by_op.next_within(Duration::from_millis(200)).unwrap().is_none());

    // bogus filters are rejected up front
    let err = s.client.watch_events(None, Some("nonsense")).unwrap_err();
    assert!(format!("{err:#}").contains("400"), "unknown filter is a 400: {err:#}");
}

#[test]
fn pruned_history_is_410_and_a_fresh_tail_still_works() {
    let dir = tmp_dir("prune");
    // tiny segments so checkpoints actually delete history
    let s = durable_stack(&dir, 1024, 2048);
    for i in 0..120 {
        s.client.submit(&format!("p{i}"), "u", RequestKind::Workflow, &one_step()).unwrap();
    }
    let p = s.persist.as_ref().unwrap();
    p.flush();
    let report = p.checkpoint(&s.store).unwrap();
    assert!(
        report.segments_deleted > 0,
        "checkpoint must prune closed segments for this test to mean anything"
    );

    let err = s.client.watch_events(Some(1), None).unwrap_err();
    assert!(
        format!("{err:#}").contains("410"),
        "asking for pruned history answers 410 Gone: {err:#}"
    );

    // a live tail (no from_lsn) is unaffected by pruning
    let mut watch = s.client.watch_events(None, None).unwrap();
    s.client.submit("after-prune", "u", RequestKind::Workflow, &one_step()).unwrap();
    let got = collect_until(&mut watch, Duration::from_secs(10), |g| !g.is_empty());
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].op, "add_request");
    assert_eq!(got[0].data.get("name").and_then(|v| v.as_str()), Some("after-prune"));
}

#[test]
fn wait_request_is_push_driven_end_to_end() {
    // full stack WITH daemons: submit → pipeline completes → wait_request
    // returns on the pushed request_status event, not a poll tick
    use idds::daemons::executors::{ExecutorSet, NoopExecutor};
    use idds::daemons::{AgentHost, Daemon, Pipeline};
    let clock = Arc::new(WallClock::new());
    let store = Store::new(clock.clone());
    let broker = Broker::new(clock);
    let metrics = Registry::default();
    let bus = EventBus::new(&metrics);
    store.set_persister(Arc::new(BusPersister::new(bus.clone())));
    broker.set_persister(Arc::new(BusPersister::new(bus.clone())));
    let executors = ExecutorSet::default()
        .with(idds::workflow::WorkKind::Noop, Arc::new(NoopExecutor::default()));
    let pipeline = Pipeline::new(store.clone(), broker.clone(), metrics.clone(), executors)
        .with_bus(bus.clone());
    let (c, m, t, ca, co) = pipeline.daemons();
    let daemons: Vec<Arc<dyn Daemon>> =
        vec![Arc::new(c), Arc::new(m), Arc::new(t), Arc::new(ca), Arc::new(co)];
    let host = AgentHost::start_with_bus(
        daemons,
        Duration::from_millis(2),
        Duration::from_millis(200),
        Some(&bus),
    );
    let cfg = Config::defaults();
    let server = serve(
        ServerState::new(store, broker, metrics, &cfg).with_bus(bus),
        &cfg,
    )
    .unwrap();
    let client = Client::new(server.addr, TOKEN);

    let req = client.submit("push", "u", RequestKind::Workflow, &one_step()).unwrap();
    let status = client.wait_request(req, Duration::from_secs(30)).unwrap();
    assert!(status.is_terminal());

    host.stop();
    server.stop();
}
