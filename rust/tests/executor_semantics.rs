//! Pre-rework executor semantics suite (ISSUE 8, satellite 1).
//!
//! Written against the *in-process* `ExecutorSet` before the distributed
//! worker rework and required to pass unchanged after it: these tests pin
//! the submit/poll/poll_many contracts every `Executor` implementation —
//! local or remote — must keep, plus the Carrier's tick-batched use of
//! `poll_many`. If the rework changes any observable behavior here, the
//! rework is wrong, not the test.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use idds::broker::Broker;
use idds::daemons::executors::{Executor, ExecutorSet, NoopExecutor, RuntimeExecutor};
use idds::daemons::{pump, Pipeline};
use idds::metrics::Registry;
use idds::runtime::{default_artifacts_dir, EngineHandle};
use idds::store::{RequestKind, RequestStatus, Store, TransformStatus};
use idds::util::clock::WallClock;
use idds::util::json::Json;
use idds::workflow::{WorkKind, WorkTemplate, Workflow};

fn echo_work(x: f64) -> Json {
    Json::obj().set("params", Json::obj().set("result", Json::obj().set("x", x)))
}

// ---------------------------------------------------------------------------
// submit / poll contracts
// ---------------------------------------------------------------------------

#[test]
fn noop_submit_then_poll_echoes_params_result() {
    let e = NoopExecutor::default();
    let h = e.submit(&echo_work(7.0)).unwrap();
    let r = e.poll(h).unwrap().expect("noop completes by the first poll");
    assert_eq!(r.get("x").unwrap().as_f64(), Some(7.0));
}

#[test]
fn noop_result_defaults_to_empty_object_without_params_result() {
    let e = NoopExecutor::default();
    let h = e.submit(&Json::obj()).unwrap();
    let r = e.poll(h).unwrap().unwrap();
    assert!(matches!(r, Json::Obj(ref m) if m.is_empty()), "{r:?}");
}

#[test]
fn poll_consumes_the_handle() {
    // A completed handle is delivered exactly once; the second poll sees
    // nothing. The Carrier relies on this: it transitions the processing
    // on the delivering poll and never re-observes the result.
    let e = NoopExecutor::default();
    let h = e.submit(&echo_work(1.0)).unwrap();
    assert!(e.poll(h).unwrap().is_some());
    assert!(e.poll(h).unwrap().is_none(), "result must be consumed");
}

#[test]
fn noop_unknown_handle_is_none_not_error() {
    let e = NoopExecutor::default();
    assert!(e.poll(123_456_789).unwrap().is_none());
}

#[test]
fn distinct_submissions_get_distinct_handles() {
    let e = NoopExecutor::default();
    let mut handles = std::collections::HashSet::new();
    for i in 0..100 {
        assert!(handles.insert(e.submit(&echo_work(i as f64)).unwrap()));
    }
}

// ---------------------------------------------------------------------------
// poll_many contract
// ---------------------------------------------------------------------------

#[test]
fn poll_many_matches_per_handle_poll_order_and_results() {
    let e = NoopExecutor::default();
    let h1 = e.submit(&echo_work(1.0)).unwrap();
    let h2 = e.submit(&echo_work(2.0)).unwrap();
    let out = e.poll_many(&[h1, h2, 999]);
    assert_eq!(out.len(), 3);
    assert_eq!(out[0].0, h1);
    assert_eq!(out[1].0, h2);
    assert_eq!(out[2].0, 999);
    assert_eq!(out[0].1.as_ref().unwrap().as_ref().unwrap().get("x").unwrap().as_f64(), Some(1.0));
    assert_eq!(out[1].1.as_ref().unwrap().as_ref().unwrap().get("x").unwrap().as_f64(), Some(2.0));
    assert!(out[2].1.as_ref().unwrap().is_none());
}

/// An executor using only the *default* `poll_many` (the per-handle loop)
/// must agree with an explicit override — the Carrier treats them
/// interchangeably.
struct DefaultPollMany(NoopExecutor);

impl Executor for DefaultPollMany {
    fn submit(&self, work: &Json) -> anyhow::Result<u64> {
        self.0.submit(work)
    }
    fn poll(&self, handle: u64) -> anyhow::Result<Option<Json>> {
        self.0.poll(handle)
    }
    // poll_many: trait default
}

#[test]
fn default_poll_many_agrees_with_override() {
    let d = DefaultPollMany(NoopExecutor::default());
    let h1 = d.submit(&echo_work(3.0)).unwrap();
    let h2 = d.submit(&echo_work(4.0)).unwrap();
    let out = d.poll_many(&[h1, h2]);
    assert_eq!(out.len(), 2);
    assert_eq!(out[0].1.as_ref().unwrap().as_ref().unwrap().get("x").unwrap().as_f64(), Some(3.0));
    assert_eq!(out[1].1.as_ref().unwrap().as_ref().unwrap().get("x").unwrap().as_f64(), Some(4.0));
}

// ---------------------------------------------------------------------------
// ExecutorSet dispatch
// ---------------------------------------------------------------------------

#[test]
fn executor_set_dispatches_by_kind_string() {
    let set = ExecutorSet::default()
        .with(WorkKind::Noop, Arc::new(NoopExecutor::default()))
        .with(WorkKind::Decision, Arc::new(NoopExecutor::default()));
    assert!(set.get("Noop").is_some());
    assert!(set.get("Decision").is_some());
    assert!(set.get("HpoTraining").is_none());
    assert!(set.get("nonsense").is_none());
}

// ---------------------------------------------------------------------------
// Runtime pool completion observed by polling
// ---------------------------------------------------------------------------

#[test]
fn runtime_pool_completion_observed_by_polling() {
    // Needs the AOT artifacts; skip (loudly) when they are absent so the
    // suite still runs in artifact-less containers.
    let engine = match EngineHandle::start(&default_artifacts_dir()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP runtime_pool_completion_observed_by_polling: {e:#}");
            return;
        }
    };
    let exec = RuntimeExecutor::new(engine, 2);
    let work = Json::obj().set("kind", "HpoTraining").set(
        "params",
        Json::obj()
            .set("log_lr", -2.0)
            .set("momentum", 0.9)
            .set("log_l2", -4.0)
            .set("log_clip", 0.0)
            .set("seed", 42u64),
    );
    let h = exec.submit(&work).unwrap();
    // Completion is only ever observed by polling — spin until the pool
    // worker finishes.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    let result = loop {
        match exec.poll(h).unwrap() {
            Some(r) => break r,
            None => {
                assert!(std::time::Instant::now() < deadline, "training never completed");
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        }
    };
    assert!(result.get("error").map(Json::is_null).unwrap_or(true), "{result:?}");
    assert!(result.get("val_loss").and_then(Json::as_f64).is_some(), "{result:?}");
    // consumed after delivery, and now unknown → hard error for Runtime
    assert!(exec.poll(h).is_err(), "runtime executor forgets delivered handles");
}

#[test]
fn runtime_rejects_unknown_kind_via_failed_result() {
    let engine = match EngineHandle::start(&default_artifacts_dir()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP runtime_rejects_unknown_kind_via_failed_result: {e:#}");
            return;
        }
    };
    let exec = RuntimeExecutor::new(engine, 1);
    let h = exec.submit(&Json::obj().set("kind", "Noop")).unwrap();
    let r = exec.poll(h).unwrap().expect("failure is reported as a result");
    assert!(!r.get("error").map(Json::is_null).unwrap_or(true), "{r:?}");
}

// ---------------------------------------------------------------------------
// Carrier tick batching via poll_many
// ---------------------------------------------------------------------------

/// Counts calls into an inner executor and can hold completions back so
/// in-flight handles pile up across Carrier ticks.
struct CountingExecutor {
    inner: NoopExecutor,
    released: AtomicBool,
    submits: AtomicUsize,
    polls: AtomicUsize,
    poll_manys: AtomicUsize,
    batch_sizes: Mutex<Vec<usize>>,
}

impl CountingExecutor {
    fn new() -> Self {
        CountingExecutor {
            inner: NoopExecutor::default(),
            released: AtomicBool::new(false),
            submits: AtomicUsize::new(0),
            polls: AtomicUsize::new(0),
            poll_manys: AtomicUsize::new(0),
            batch_sizes: Mutex::new(Vec::new()),
        }
    }
}

impl Executor for CountingExecutor {
    fn submit(&self, work: &Json) -> anyhow::Result<u64> {
        self.submits.fetch_add(1, Ordering::SeqCst);
        self.inner.submit(work)
    }

    fn poll(&self, handle: u64) -> anyhow::Result<Option<Json>> {
        self.polls.fetch_add(1, Ordering::SeqCst);
        if !self.released.load(Ordering::SeqCst) {
            return Ok(None);
        }
        self.inner.poll(handle)
    }

    fn poll_many(&self, handles: &[u64]) -> Vec<(u64, anyhow::Result<Option<Json>>)> {
        self.poll_manys.fetch_add(1, Ordering::SeqCst);
        self.batch_sizes.lock().unwrap().push(handles.len());
        if !self.released.load(Ordering::SeqCst) {
            return handles.iter().map(|&h| (h, Ok(None))).collect();
        }
        self.inner.poll_many(handles)
    }
}

#[test]
fn carrier_polls_in_flight_handles_as_one_batch_per_tick() {
    const WORKS: usize = 8;
    let exec = Arc::new(CountingExecutor::new());
    let clock = Arc::new(WallClock::new());
    let p = Pipeline::new(
        Store::new(clock.clone()),
        Broker::new(clock),
        Registry::default(),
        ExecutorSet::default().with(WorkKind::Noop, exec.clone() as Arc<dyn Executor>),
    );
    let mut wf = Workflow::new("fan");
    for i in 0..WORKS {
        wf = wf.add_template(WorkTemplate::new(&format!("w{i}"))).entry(&format!("w{i}"));
    }
    let req = p.store.add_request("r", "u", RequestKind::Workflow, wf.to_json());
    let (clerk, marsh, tfr, carrier, conductor) = p.daemons();

    // Phase 1: completions held back. Everything gets submitted; the
    // Carrier keeps polling but nothing finishes, so every tick sees the
    // full in-flight set.
    pump(&[&clerk, &marsh, &tfr, &carrier], 50);
    assert_eq!(exec.submits.load(Ordering::SeqCst), WORKS, "all works submitted");
    assert_eq!(exec.polls.load(Ordering::SeqCst), 0, "Carrier must never use per-handle poll");
    let calls_held = exec.poll_manys.load(Ordering::SeqCst);
    assert!(calls_held >= 1);
    {
        let sizes = exec.batch_sizes.lock().unwrap();
        assert!(
            sizes.iter().any(|&s| s == WORKS),
            "a steady-state tick batches all {WORKS} in-flight handles into one poll_many: {sizes:?}"
        );
        // Batching invariant: one poll_many per kind per tick, never one
        // call per handle. Total handles polled across calls must exceed
        // the call count by the batching factor.
        let polled: usize = sizes.iter().sum();
        assert!(
            polled >= sizes.len() * WORKS / 2,
            "per-tick batches collapsed to per-handle calls: {sizes:?}"
        );
    }

    // Phase 2: release completions and run to quiescence.
    exec.released.store(true, Ordering::SeqCst);
    pump(&[&clerk, &marsh, &tfr, &carrier, &conductor], 1000);
    assert_eq!(p.store.get_request(req).unwrap().status, RequestStatus::Finished);
    for tf in p.store.transforms_of_request(req) {
        assert_eq!(p.store.get_transform(tf).unwrap().status, TransformStatus::Finished);
    }
    assert_eq!(exec.polls.load(Ordering::SeqCst), 0);
}

#[test]
fn carrier_routes_each_kind_to_its_executor_and_finishes() {
    // Two kinds, two executors, one workflow — results land on the right
    // transforms and the request finishes. (Decision works are routed to a
    // NoopExecutor here: dispatch is by kind string only.)
    let noop = Arc::new(CountingExecutor::new());
    noop.released.store(true, Ordering::SeqCst);
    let dec = Arc::new(CountingExecutor::new());
    dec.released.store(true, Ordering::SeqCst);
    let clock = Arc::new(WallClock::new());
    let p = Pipeline::new(
        Store::new(clock.clone()),
        Broker::new(clock),
        Registry::default(),
        ExecutorSet::default()
            .with(WorkKind::Noop, noop.clone() as Arc<dyn Executor>)
            .with(WorkKind::Decision, dec.clone() as Arc<dyn Executor>),
    );
    let wf = Workflow::new("mixed")
        .add_template(WorkTemplate::new("n"))
        .add_template(WorkTemplate::new("d").kind(WorkKind::Decision))
        .entry("n")
        .entry("d");
    let req = p.store.add_request("r", "u", RequestKind::Workflow, wf.to_json());
    let (clerk, marsh, tfr, carrier, conductor) = p.daemons();
    pump(&[&clerk, &marsh, &tfr, &carrier, &conductor], 1000);
    assert_eq!(p.store.get_request(req).unwrap().status, RequestStatus::Finished);
    assert_eq!(noop.submits.load(Ordering::SeqCst), 1);
    assert_eq!(dec.submits.load(Ordering::SeqCst), 1);
}
