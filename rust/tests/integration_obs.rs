//! Observability integration: one trace id spans `Client::submit` → REST
//! handler → Clerk intake across real sockets (tag-stitched through the
//! store), and a standby's replication pull carries its trace context in
//! `X-IDDS-Trace` so the primary's request + ship spans land in the same
//! trace — both retrievable through `GET /api/traces/<id>`.
//!
//! Both "processes" share this test binary's global trace ring, so the
//! cross-process stitch is observable from either head's traces endpoint.

use std::path::PathBuf;
use std::sync::Arc;

use idds::broker::Broker;
use idds::config::Config;
use idds::daemons::executors::{ExecutorSet, NoopExecutor};
use idds::daemons::{AgentHost, Daemon, Pipeline};
use idds::metrics::Registry;
use idds::obs;
use idds::persist::replicate::write_epoch;
use idds::persist::{ClusterState, FsyncMode, Persist, PersistOptions, Replica, ReplicationOptions};
use idds::rest::http::http_request;
use idds::rest::{serve, Client, ServerState};
use idds::store::{RequestKind, RequestStatus, Store};
use idds::util::clock::WallClock;
use idds::util::json::{parse, Json};
use idds::workflow::{WorkKind, WorkTemplate, Workflow};

const TOKEN: &str = "dev-token";
const AUTH: &str = "Bearer dev-token";

fn one_step() -> Workflow {
    Workflow::new("one-step").add_template(WorkTemplate::new("a")).entry("a")
}

/// Collect every span name in a `roots` tree, depth-first.
fn names_in(node: &Json, out: &mut Vec<String>) {
    if let Some(n) = node.get("name").and_then(|v| v.as_str()) {
        out.push(n.to_string());
    }
    if let Some(kids) = node.get("children").and_then(|v| v.as_arr()) {
        for k in kids {
            names_in(k, out);
        }
    }
}

fn fetch_trace_names(addr: std::net::SocketAddr, trace_hex: &str) -> Vec<String> {
    let (st, body) = http_request(
        addr,
        "GET",
        &format!("/api/traces/{trace_hex}"),
        &[("Authorization", AUTH)],
        b"",
    )
    .unwrap();
    assert_eq!(st, 200, "trace {trace_hex} must be retrievable");
    let j = parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let mut names = Vec::new();
    for root in j.get("roots").unwrap().as_arr().unwrap() {
        names_in(root, &mut names);
    }
    names
}

#[test]
fn one_trace_spans_client_rest_and_daemon() {
    let clock = Arc::new(WallClock::new());
    let store = Store::new(clock.clone());
    let broker = Broker::new(clock);
    let metrics = Registry::default();
    let cfg = Config::defaults();
    let executors =
        ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default()));
    let pipeline = Pipeline::new(store.clone(), broker.clone(), metrics.clone(), executors);
    let (c, m, t, ca, co) = pipeline.daemons();
    let daemons: Vec<Arc<dyn Daemon>> =
        vec![Arc::new(c), Arc::new(m), Arc::new(t), Arc::new(ca), Arc::new(co)];
    let _host = AgentHost::start(daemons, std::time::Duration::from_millis(2));
    let server = serve(ServerState::new(store, broker, metrics, &cfg), &cfg).unwrap();
    let client = Client::new(server.addr, TOKEN);

    // serve() armed the tracer from config; everything the client does
    // inside this root span joins its trace
    let sp = obs::span("test.campaign");
    let trace_id = sp.ctx().trace_id;
    assert_ne!(trace_id, 0, "rest::serve must arm tracing from config defaults");
    let req = client.submit("obs-campaign", "alice", RequestKind::Workflow, &one_step()).unwrap();
    let status = client.wait_terminal(req, std::time::Duration::from_secs(30)).unwrap();
    assert_eq!(status, RequestStatus::Finished);
    drop(sp);

    let names = fetch_trace_names(server.addr, &format!("{trace_id:016x}"));
    assert!(
        names.iter().any(|n| n.starts_with("client.POST")),
        "client submit span missing: {names:?}"
    );
    assert!(
        names.iter().any(|n| n == "rest.POST.api.requests"),
        "server request span missing (header propagation broke): {names:?}"
    );
    assert!(
        names.iter().any(|n| n == "daemon.clerk.request"),
        "clerk intake span missing (request-id tag stitch broke): {names:?}"
    );
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "idds-obs-{tag}-{}-{}",
        std::process::id(),
        idds::util::next_id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn replication_pull_trace_contains_primary_ship_span() {
    let pdir = tmp_dir("primary");
    let sdir = tmp_dir("standby");
    let popts = PersistOptions {
        segment_bytes: 16 * 1024,
        fsync: FsyncMode::Never,
        flush_idle_ms: 2,
        ..PersistOptions::default()
    };
    let cfg = Config::defaults();

    // primary: store + WAL + REST (no daemons — raw submits make frames)
    let clock = Arc::new(WallClock::new());
    let store = Store::new(clock.clone());
    let broker = Broker::new(clock);
    let metrics = Registry::default();
    let (persist, _) =
        Persist::open_with_broker(&pdir, popts.clone(), &store, Some(&broker), metrics.clone())
            .unwrap();
    write_epoch(&pdir, 1).unwrap();
    let cluster = ClusterState::primary(Some(pdir.clone()), 1);
    let server = serve(
        ServerState::new(store.clone(), broker.clone(), metrics, &cfg)
            .with_persist(persist.clone())
            .with_cluster(Arc::clone(&cluster)),
        &cfg,
    )
    .unwrap();
    let client = Client::new(server.addr, TOKEN);
    for i in 0..10 {
        client.submit(&format!("c{i}"), "u", RequestKind::Workflow, &one_step()).unwrap();
    }
    persist.flush();
    let durable = persist.wal().durable_lsn();

    // standby: pull loop only
    let sclock = Arc::new(WallClock::new());
    let sstore = Store::new(sclock.clone());
    let sbroker = Broker::new(sclock);
    let smetrics = Registry::default();
    let (spersist, _) =
        Persist::open_replica(&sdir, popts, &sstore, &sbroker, smetrics.clone()).unwrap();
    let scluster = ClusterState::replica(sdir.clone(), &server.addr.to_string(), 0);
    let ropts = ReplicationOptions { poll_interval_ms: 2, batch_bytes: 8 * 1024, retry_ms: 10 };
    let replica = Replica::start(
        sstore,
        sbroker,
        spersist.clone(),
        scluster,
        TOKEN,
        ropts,
        smetrics,
    )
    .unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
    while replica.cluster().applied_lsn() < durable {
        assert!(std::time::Instant::now() < deadline, "standby never caught up");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }

    // find a frame-carrying pull in the recent traces (idle polls cancel
    // their spans, so every retained pull did real work)
    let (st, body) = http_request(
        server.addr,
        "GET",
        "/api/traces?limit=64",
        &[("Authorization", AUTH)],
        b"",
    )
    .unwrap();
    assert_eq!(st, 200);
    let j = parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let pull = j
        .get("recent")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|s| s.get("root").and_then(|v| v.as_str()) == Some("replication.pull"))
        .expect("a replication.pull trace in the recent list")
        .clone();
    let trace_hex = pull.get("trace_id").unwrap().as_str().unwrap().to_string();
    let names = fetch_trace_names(server.addr, &trace_hex);
    assert!(names.iter().any(|n| n == "replication.pull"), "{names:?}");
    assert!(
        names.iter().any(|n| n == "rest.GET.api.replication.wal"),
        "primary request span must join the pull trace: {names:?}"
    );
    assert!(
        names.iter().any(|n| n == "replication.ship"),
        "ship span must join the pull trace: {names:?}"
    );

    replica.stop();
    server.stop();
    spersist.shutdown();
    persist.shutdown();
    std::fs::remove_dir_all(&pdir).ok();
    std::fs::remove_dir_all(&sdir).ok();
}
