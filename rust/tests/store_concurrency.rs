//! Tests for the store's striped-lock hot paths: batched transitions keep
//! transition validation and index consistency, the sorted BTreeSet status
//! indexes match the old sort-per-poll output, and a multi-thread smoke
//! test hammers sharded writes + status polls and re-checks every
//! index/row relation afterwards.

use std::sync::Arc;

use idds::store::{
    CollectionKind, ContentStatus, Id, ProcessingStatus, RequestKind, RequestStatus, Store,
    TransformStatus,
};
use idds::util::clock::WallClock;
use idds::util::json::Json;
use idds::util::rng::Rng;

fn store() -> Store {
    Store::new(Arc::new(WallClock::new()))
}

/// Every id must sit in exactly the status set matching its record.
fn assert_request_indexes_consistent(s: &Store, ids: &[Id]) {
    for &id in ids {
        let rec = s.get_request(id).unwrap();
        for st in RequestStatus::ALL {
            let in_set = s.requests_with_status(*st).contains(&id);
            assert_eq!(
                in_set,
                *st == rec.status,
                "request {id} (status {}) membership wrong for set {st}",
                rec.status
            );
        }
    }
}

#[test]
fn batched_transitions_enforce_validation() {
    let s = store();
    let fresh: Vec<Id> = (0..10)
        .map(|i| s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null))
        .collect();
    // drive half of them terminal so the batch below mixes legal and
    // illegal members
    s.update_requests_status(&fresh[..5], RequestStatus::Transforming);
    assert_eq!(s.update_requests_status(&fresh[..5], RequestStatus::Finished), 5);
    // batch over everything: only the 5 still-New requests may move
    let moved = s.update_requests_status(&fresh, RequestStatus::Transforming);
    assert_eq!(moved, 5, "terminal members must be skipped");
    for &id in &fresh[..5] {
        assert_eq!(s.get_request(id).unwrap().status, RequestStatus::Finished);
    }
    for &id in &fresh[5..] {
        assert_eq!(s.get_request(id).unwrap().status, RequestStatus::Transforming);
    }
    // unknown ids are skipped, not errors
    assert_eq!(s.update_requests_status(&[999_999_999], RequestStatus::Failed), 0);
    assert_request_indexes_consistent(&s, &fresh);
}

#[test]
fn batched_transform_transitions_match_single_api() {
    let s = store();
    let rid = s.add_request("r", "u", RequestKind::Workflow, Json::Null);
    let tfs: Vec<Id> = (0..20)
        .map(|i| s.add_transform(rid, &format!("w{i}"), Json::Null))
        .collect();
    assert_eq!(s.update_transforms_status(&tfs, TransformStatus::Activated), 20);
    assert_eq!(s.update_transforms_status(&tfs, TransformStatus::Running), 20);
    // illegal for all: Running -> Activated
    assert_eq!(s.update_transforms_status(&tfs, TransformStatus::Activated), 0);
    for &tf in &tfs {
        assert_eq!(s.get_transform(tf).unwrap().status, TransformStatus::Running);
    }
    assert_eq!(s.transforms_with_status(TransformStatus::Running).len(), 20);
    assert!(s.transforms_with_status(TransformStatus::Activated).is_empty());
}

#[test]
fn sorted_index_matches_legacy_sorted_output() {
    let s = store();
    let mut rng = Rng::new(42);
    let ids: Vec<Id> = (0..500)
        .map(|i| s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null))
        .collect();
    // random single-id walks to scramble set membership
    for _ in 0..2000 {
        let id = ids[rng.below(ids.len() as u64) as usize];
        let to = *rng.choose(RequestStatus::ALL);
        let _ = s.update_request_status(id, to);
    }
    for st in RequestStatus::ALL {
        let got = s.requests_with_status(*st);
        // the old implementation collected a HashSet and sort_unstable'd;
        // the BTreeSet index must produce the identical ascending list
        let mut expect: Vec<Id> = ids
            .iter()
            .copied()
            .filter(|id| s.get_request(*id).unwrap().status == *st)
            .collect();
        expect.sort_unstable();
        assert_eq!(got, expect, "status {st}");
        let mut sorted_check = got.clone();
        sorted_check.sort_unstable();
        assert_eq!(got, sorted_check, "index listing must be ascending");
        // limit variant: exact prefix
        for limit in [0usize, 1, 7, got.len(), got.len() + 3] {
            assert_eq!(
                s.requests_with_status_limit(*st, limit),
                got[..limit.min(got.len())].to_vec()
            );
        }
    }
}

#[test]
fn contents_sorted_listing_and_counters_agree() {
    let s = store();
    let rid = s.add_request("r", "u", RequestKind::DataCarousel, Json::Null);
    let tid = s.add_transform(rid, "w", Json::Null);
    let cid = s.add_collection(tid, "in", CollectionKind::Input);
    let ids = s.add_contents(cid, (0..300).map(|i| (format!("f{i}"), 1u64)));
    let mut rng = Rng::new(7);
    for _ in 0..200 {
        let k = 1 + rng.below(80) as usize;
        let start = rng.below((ids.len() - k) as u64 + 1) as usize;
        let to = *rng.choose(ContentStatus::ALL);
        s.update_contents_status(&ids[start..start + k], to);
    }
    for st in ContentStatus::ALL {
        let listed = s.contents_with_status(cid, *st);
        let mut sorted_check = listed.clone();
        sorted_check.sort_unstable();
        assert_eq!(listed, sorted_check, "contents listing must be ascending");
        assert_eq!(listed.len(), s.count_contents(cid, *st));
        for &id in &listed {
            assert_eq!(s.get_content(id).unwrap().status, *st);
        }
    }
    let total: usize = ContentStatus::ALL
        .iter()
        .map(|st| s.count_contents(cid, *st))
        .sum();
    assert_eq!(total, ids.len(), "every row in exactly one status set");
}

#[test]
fn multithread_smoke_sharded_writes_and_polls() {
    let s = store();
    let rid = s.add_request("r", "u", RequestKind::DataCarousel, Json::Null);
    let tid = s.add_transform(rid, "w", Json::Null);
    // 4 collections, 4 writer threads with OVERLAPPING id sets plus 2
    // poller threads; afterwards every index/row relation must hold.
    let colls: Vec<(Id, Vec<Id>)> = (0..4)
        .map(|c| {
            let cid = s.add_collection(tid, &format!("in{c}"), CollectionKind::Input);
            let ids = s.add_contents(cid, (0..2000).map(|i| (format!("f{c}/{i}"), 1u64)));
            (cid, ids)
        })
        .collect();
    let pids: Vec<Id> = (0..1000).map(|_| s.add_processing(tid)).collect();
    std::thread::scope(|scope| {
        for w in 0..4 {
            let s = s.clone();
            let colls = &colls;
            let pids = &pids;
            scope.spawn(move || {
                let mut rng = Rng::new(1000 + w as u64);
                for _ in 0..200 {
                    // contents: random chunk of a random collection toward
                    // a random status (illegal moves skipped by design)
                    let (_, ids) = &colls[rng.below(4) as usize];
                    let k = 1 + rng.below(400) as usize;
                    let start = rng.below((ids.len() - k) as u64 + 1) as usize;
                    let to = *rng.choose(ContentStatus::ALL);
                    s.update_contents_status(&ids[start..start + k], to);
                    // processings: batched walk on an overlapping window
                    let pk = 1 + rng.below(200) as usize;
                    let pstart = rng.below((pids.len() - pk) as u64 + 1) as usize;
                    let pto = *rng.choose(ProcessingStatus::ALL);
                    s.update_processings_status(&pids[pstart..pstart + pk], pto);
                }
            });
        }
        for r in 0..2 {
            let s = s.clone();
            let colls = &colls;
            scope.spawn(move || {
                for _ in 0..400 {
                    for (cid, _) in colls.iter() {
                        for st in ContentStatus::ALL {
                            std::hint::black_box(s.count_contents(*cid, *st));
                        }
                    }
                    std::hint::black_box(
                        s.processings_with_status_limit(ProcessingStatus::Running, 64).len(),
                    );
                    if r == 0 {
                        std::hint::black_box(
                            s.processings_with_status(ProcessingStatus::Finished).len(),
                        );
                    }
                }
            });
        }
    });
    // full consistency audit: rows vs indexes, everywhere
    for (cid, ids) in &colls {
        let mut total = 0;
        for st in ContentStatus::ALL {
            let listed = s.contents_with_status(*cid, *st);
            assert_eq!(listed.len(), s.count_contents(*cid, *st));
            for &id in &listed {
                assert_eq!(
                    s.get_content(id).unwrap().status,
                    *st,
                    "content {id} row/index disagree"
                );
            }
            total += listed.len();
        }
        assert_eq!(total, ids.len(), "collection {cid}: row lost or duplicated");
    }
    let mut ptotal = 0;
    for st in ProcessingStatus::ALL {
        let listed = s.processings_with_status(*st);
        for &pid in &listed {
            assert_eq!(
                s.get_processing(pid).unwrap().status,
                *st,
                "processing {pid} row/index disagree"
            );
        }
        ptotal += listed.len();
    }
    assert_eq!(ptotal, pids.len(), "processing lost or duplicated across sets");
}

#[test]
fn claim_messages_claims_each_exactly_once_across_threads() {
    let s = store();
    let n = 500usize;
    for i in 0..n {
        s.add_message("t", None, Json::Num(i as f64));
    }
    let claimed: Vec<Vec<Id>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let s = s.clone();
                scope.spawn(move || {
                    let mut mine = Vec::new();
                    loop {
                        let batch = s.claim_messages(32);
                        if batch.is_empty() {
                            break;
                        }
                        mine.extend(batch.iter().map(|m| m.id));
                    }
                    mine
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut all: Vec<Id> = claimed.into_iter().flatten().collect();
    let before_dedup = all.len();
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len(), before_dedup, "a message was claimed twice");
    assert_eq!(all.len(), n, "a message was never claimed");
    assert!(s.messages_with_status(idds::store::MessageStatus::New).is_empty());
}

#[test]
fn generation_counters_gate_like_daemons_do() {
    let s = store();
    let rid = s.add_request("r", "u", RequestKind::Workflow, Json::Null);
    let g = s.requests_generation();
    // a tick's worth of reads: generation stays put
    s.requests_with_status(RequestStatus::New);
    s.requests_with_status_limit(RequestStatus::New, 10);
    let _ = s.get_request(rid);
    assert_eq!(s.requests_generation(), g);
    // a no-op batch does not bump either
    assert_eq!(s.update_requests_status(&[], RequestStatus::Failed), 0);
    assert_eq!(s.update_requests_status(&[rid], RequestStatus::Finished), 0); // illegal, skipped
    assert_eq!(s.requests_generation(), g);
    // a real move bumps exactly this table
    let tg = s.transforms_generation();
    assert_eq!(s.update_requests_status(&[rid], RequestStatus::Transforming), 1);
    assert!(s.requests_generation() > g);
    assert_eq!(s.transforms_generation(), tg);
}
