//! FIG3/DG bench: the directed-graph engine, before/after the interned
//! compiled-workflow rework.
//!
//! Sections:
//! * engine microbenches (chain walk, gated cycle, serialization);
//! * **resolve before/after** — parse+build a full `Workflow` per request
//!   (the old Clerk path, which then kept that clone alive per engine) vs
//!   resolving through the interned registry to a shared compilation;
//! * **on_complete before/after** — the old full-condition-list linear
//!   scan (reproduced below verbatim as the baseline) vs the per-source
//!   out-edge index, at 10/100/1000 templates;
//! * the full daemon pipeline running pure-orchestration workflows.
//!
//! Emits `BENCH_workflow.json` (override the path with
//! `BENCH_WORKFLOW_JSON=...`; `scripts/bench.sh` points it at the repo
//! root). `BENCH_QUICK=1` shrinks iteration counts for smoke runs.
//!
//!     cargo bench --bench bench_workflow

use std::collections::BTreeMap;
use std::sync::Arc;

use idds::broker::Broker;
use idds::daemons::executors::{ExecutorSet, NoopExecutor};
use idds::daemons::{pump, Pipeline};
use idds::metrics::Registry;
use idds::store::{RequestKind, Store};
use idds::util::bench::{section, Bencher};
use idds::util::clock::WallClock;
use idds::util::json::Json;
use idds::workflow::{
    bind_params, Condition, Engine, Predicate, Work, WorkTemplate, Workflow, WorkflowRegistry,
};

fn chain_workflow(len: usize) -> Workflow {
    let mut wf = Workflow::new(&format!("chain{len}"));
    for i in 0..len {
        wf = wf.add_template(WorkTemplate::new(&format!("s{i}")));
        if i > 0 {
            wf = wf.add_condition(Condition::always(&format!("s{}", i - 1), &format!("s{i}")));
        }
    }
    wf.entry("s0")
}

fn first_work() -> Work {
    Work {
        instance: 1,
        template: "s0".into(),
        params: BTreeMap::new(),
        iteration: 0,
    }
}

/// The pre-index evaluation path, kept as the bench baseline: filter the
/// FULL condition list by source (cloning the matches, as the old engine
/// did), evaluate predicates, bind params, apply the instance cap.
fn linear_on_complete(
    wf: &Workflow,
    instances: &mut BTreeMap<String, u32>,
    work: &Work,
    result: &Json,
) -> usize {
    let conds: Vec<Condition> = wf
        .conditions
        .iter()
        .filter(|c| c.source == work.template)
        .cloned()
        .collect();
    let mut fired = 0;
    for c in conds {
        if c.predicate.eval(result).unwrap() {
            let params = bind_params(&c.bindings, &work.params, result).unwrap();
            let tpl = wf.templates.get(&c.target).unwrap();
            let count = instances.entry(c.target.clone()).or_insert(0);
            if *count < tpl.max_instances {
                *count += 1;
                std::hint::black_box(&params);
                fired += 1;
            }
        }
    }
    fired
}

fn main() {
    let mut b = Bencher::from_env();
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);

    section("engine microbenches");
    let wf = chain_workflow(64);
    let (chain64, _) = WorkflowRegistry::global().intern(&wf).unwrap();
    b.bench("engine start+walk 64-step chain", || {
        let mut e = Engine::from_compiled(Arc::clone(&chain64));
        let mut frontier = e.start();
        let mut n = 0;
        while let Some(w) = frontier.pop() {
            n += 1;
            frontier.extend(e.on_complete(&w, &Json::obj()).unwrap());
        }
        assert_eq!(n, 64);
    });

    let cyc = Workflow::new("cyc")
        .add_template(WorkTemplate::new("a").max_instances(1000))
        .add_condition(Condition::when("a", "a", Predicate::lt("loss", 0.5)))
        .entry("a");
    let (cyc_c, _) = WorkflowRegistry::global().intern(&cyc).unwrap();
    b.bench("cyclic engine: 1000 gated iterations", || {
        let mut e = Engine::from_compiled(Arc::clone(&cyc_c));
        let mut frontier = e.start();
        let result = Json::obj().set("loss", 0.1);
        let mut n = 0;
        while let Some(w) = frontier.pop() {
            n += 1;
            frontier.extend(e.on_complete(&w, &result).unwrap());
        }
        assert_eq!(n, 1000);
    });

    let big = chain_workflow(128);
    b.bench("workflow json serialize+parse (128 templates)", || {
        let text = big.to_json().to_string();
        let j = idds::util::json::parse(&text).unwrap();
        Workflow::from_json(&j).unwrap()
    });

    section("resolve: clone-per-request vs interned registry (100 templates)");
    let chain100_json = chain_workflow(100).to_json();
    let resolve_before = b.bench("resolve before: parse+build full Workflow", || {
        // the old Clerk path: every request deserialized its own Workflow
        // and the engine kept that full copy alive
        Workflow::from_json(&chain100_json).unwrap()
    });
    // warm the registry once so the timed path is the steady-state hit
    WorkflowRegistry::global().intern_json(&chain100_json).unwrap();
    let resolve_after = b.bench("resolve after: registry hit + engine", || {
        let (compiled, hit) = WorkflowRegistry::global().intern_json(&chain100_json).unwrap();
        assert!(hit);
        Engine::from_compiled(compiled)
    });

    section("on_complete: linear condition scan vs out-edge index");
    let mut on_complete_pairs: Vec<(usize, f64, f64)> = Vec::new();
    for &n in &[10usize, 100, 1000] {
        let wf = chain_workflow(n);
        let (compiled, _) = WorkflowRegistry::global().intern(&wf).unwrap();
        let result = Json::obj();
        // completing the first template: the linear scan walks all n-1
        // conditions, the index reads exactly one out-edge list
        let before = b.bench_with_setup(
            &format!("on_complete before: linear scan, {n} templates"),
            BTreeMap::new,
            |counts| linear_on_complete(&wf, counts, &first_work(), &result),
        );
        let after = b.bench_with_setup(
            &format!("on_complete after: indexed, {n} templates"),
            || Engine::from_compiled(Arc::clone(&compiled)),
            |e| e.on_complete(&first_work(), &result).unwrap().len(),
        );
        on_complete_pairs.push((n, before.mean_ns, after.mean_ns));
    }

    section("daemon pipeline end-to-end (Noop works)");
    b.bench("pipeline: 32-step chain request to Finished", || {
        let clock = Arc::new(WallClock::new());
        let p = Pipeline::new(
            Store::new(clock.clone()),
            Broker::new(clock),
            Registry::default(),
            ExecutorSet::default()
                .with(idds::workflow::WorkKind::Noop, Arc::new(NoopExecutor::default())),
        );
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, chain_workflow(32).to_json());
        let (c, m, t, ca, co) = p.daemons();
        pump(&[&c, &m, &t, &ca, &co], 100_000);
        assert!(p.store.get_request(req).unwrap().status.is_terminal());
    });

    let mut before_after = Json::obj().set(
        "resolve",
        Json::obj()
            .set("before_ns", resolve_before.mean_ns)
            .set("after_ns", resolve_after.mean_ns)
            .set("speedup", resolve_before.mean_ns / resolve_after.mean_ns.max(1.0)),
    );
    for (n, before_ns, after_ns) in &on_complete_pairs {
        before_after = before_after.set(
            &format!("on_complete_{n}"),
            Json::obj()
                .set("before_ns", *before_ns)
                .set("after_ns", *after_ns)
                .set("speedup", before_ns / after_ns.max(1.0)),
        );
    }
    let registry = WorkflowRegistry::global();
    let summary = Json::obj()
        .set("bench", "bench_workflow")
        .set("quick", quick)
        .set(
            "results",
            Json::Arr(b.results().iter().map(|r| r.to_json()).collect()),
        )
        .set(
            "derived",
            Json::obj().set("before_after", before_after).set(
                "registry",
                Json::obj()
                    .set("interned", registry.len())
                    .set("hits", registry.hit_count())
                    .set("misses", registry.miss_count()),
            ),
        );
    let path = std::env::var("BENCH_WORKFLOW_JSON")
        .unwrap_or_else(|_| "BENCH_workflow.json".to_string());
    match std::fs::write(&path, summary.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
