//! FIG3/DG bench: the directed-graph engine. Condition-evaluation
//! throughput, cyclic-workflow iteration cost, serialization round-trip,
//! and the full daemon pipeline running pure-orchestration workflows.
//!
//!     cargo bench --bench bench_workflow

use std::sync::Arc;

use idds::broker::Broker;
use idds::daemons::executors::{ExecutorSet, NoopExecutor};
use idds::daemons::{pump, Pipeline};
use idds::metrics::Registry;
use idds::store::{RequestKind, Store};
use idds::util::bench::{section, Bencher};
use idds::util::clock::WallClock;
use idds::util::json::Json;
use idds::workflow::{Condition, Engine, Predicate, WorkTemplate, Workflow};

fn chain_workflow(len: usize) -> Workflow {
    let mut wf = Workflow::new("chain");
    for i in 0..len {
        wf = wf.add_template(WorkTemplate::new(&format!("s{i}")));
        if i > 0 {
            wf = wf.add_condition(Condition::always(&format!("s{}", i - 1), &format!("s{i}")));
        }
    }
    wf.entry("s0")
}

fn main() {
    let mut b = Bencher::from_env();

    section("engine microbenches");
    let wf = chain_workflow(64);
    b.bench("engine start+walk 64-step chain", || {
        let mut e = Engine::new(wf.clone()).unwrap();
        let mut frontier = e.start();
        let mut n = 0;
        while let Some(w) = frontier.pop() {
            n += 1;
            frontier.extend(e.on_complete(&w, &Json::obj()).unwrap());
        }
        assert_eq!(n, 64);
    });

    let cyc = Workflow::new("cyc")
        .add_template(WorkTemplate::new("a").max_instances(1000))
        .add_condition(Condition::when("a", "a", Predicate::lt("loss", 0.5)))
        .entry("a");
    b.bench("cyclic engine: 1000 gated iterations", || {
        let mut e = Engine::new(cyc.clone()).unwrap();
        let mut frontier = e.start();
        let result = Json::obj().set("loss", 0.1);
        let mut n = 0;
        while let Some(w) = frontier.pop() {
            n += 1;
            frontier.extend(e.on_complete(&w, &result).unwrap());
        }
        assert_eq!(n, 1000);
    });

    let big = chain_workflow(128);
    b.bench("workflow json serialize+parse (128 templates)", || {
        let text = big.to_json().to_string();
        let j = idds::util::json::parse(&text).unwrap();
        Workflow::from_json(&j).unwrap()
    });

    section("daemon pipeline end-to-end (Noop works)");
    b.bench("pipeline: 32-step chain request to Finished", || {
        let clock = Arc::new(WallClock::new());
        let p = Pipeline::new(
            Store::new(clock.clone()),
            Broker::new(clock),
            Registry::default(),
            ExecutorSet::default().with(idds::workflow::WorkKind::Noop, Arc::new(NoopExecutor::default())),
        );
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, chain_workflow(32).to_json());
        let (c, m, t, ca, co) = p.daemons();
        pump(&[&c, &m, &t, &ca, &co], 100_000);
        assert!(p.store.get_request(req).unwrap().status.is_terminal());
    });
}
