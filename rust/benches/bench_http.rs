//! PERF-HTTP bench: the nonblocking REST transport — keep-alive
//! round-trip latency on one connection, aggregate req/sec as the
//! client fleet grows past the handler-pool size, and tail latency for
//! a busy client while hundreds of idle keep-alive connections are
//! parked on the loop (the 10k-connection posture in miniature).
//!
//!     cargo bench --bench bench_http
//!
//! Emits `BENCH_http.json` (override the path with `BENCH_HTTP_JSON=...`;
//! `scripts/bench.sh` points it at the repo root). The `derived` section
//! carries req/sec per fleet size and the busy-client p50/p99 with the
//! idle fleet held open.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use idds::rest::http::{HttpServer, Response, ServerOptions};
use idds::util::bench::{fmt_ns, section, Bencher};
use idds::util::json::Json;

/// Minimal keep-alive client: one request on the wire at a time,
/// responses parsed by Content-Length framing.
struct Conn {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl Conn {
    fn connect(addr: SocketAddr) -> Conn {
        let s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        s.set_nodelay(true).unwrap();
        Conn {
            r: BufReader::new(s.try_clone().unwrap()),
            w: s,
        }
    }

    fn roundtrip(&mut self, path: &str) -> u16 {
        let req = format!("GET {path} HTTP/1.1\r\nHost: bench\r\nContent-Length: 0\r\n\r\n");
        self.w.write_all(req.as_bytes()).expect("send");
        let mut status_line = String::new();
        assert_ne!(self.r.read_line(&mut status_line).expect("status"), 0, "server closed");
        let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            self.r.read_line(&mut h).expect("header");
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    content_length = v.trim().parse().unwrap();
                }
            }
        }
        let mut body = vec![0u8; content_length];
        self.r.read_exact(&mut body).expect("body");
        status
    }
}

fn server(workers: usize, max_connections: usize) -> HttpServer {
    let opts = ServerOptions {
        workers,
        max_connections,
        ..ServerOptions::default()
    };
    HttpServer::serve_with_options("127.0.0.1:0", opts, |req| {
        Response::json(200, Json::obj().set("path", req.path.as_str()))
    })
    .expect("bind bench server")
}

/// Aggregate req/sec: `conns` threads, each with one keep-alive
/// connection, each issuing `per` sequential requests.
fn fleet_rps(addr: SocketAddr, conns: usize, per: usize) -> f64 {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..conns)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Conn::connect(addr);
                for i in 0..per {
                    assert_eq!(c.roundtrip(&format!("/f/{t}/{i}")), 200);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    (conns * per) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let mut b = Bencher::from_env();
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);

    let s = server(8, 10_240);
    let addr = s.addr;

    section("single keep-alive connection round-trip");
    let mut solo = Conn::connect(addr);
    let rt = b.bench("http_roundtrip_1conn", || {
        assert_eq!(solo.roundtrip("/solo"), 200)
    });
    println!("  {} per request", fmt_ns(rt.mean_ns));
    drop(solo);

    section("aggregate req/sec as the connection fleet grows");
    let fleets: &[usize] = if quick { &[1, 16, 64] } else { &[1, 64, 512] };
    let per = if quick { 50 } else { 200 };
    let mut rps = Vec::new();
    for &conns in fleets {
        let v = fleet_rps(addr, conns, per);
        println!("  {conns:4} conns x {per} reqs: {v:10.0} req/sec");
        rps.push((conns, v));
    }

    section("busy-client tail latency behind an idle keep-alive fleet");
    let idle_n = if quick { 64 } else { 512 };
    let mut idle = Vec::with_capacity(idle_n);
    for i in 0..idle_n {
        let mut c = Conn::connect(addr);
        assert_eq!(c.roundtrip(&format!("/idle/{i}")), 200);
        idle.push(c); // parked: never spoken to again
    }
    let probes = if quick { 200 } else { 2_000 };
    let mut lat_us: Vec<f64> = Vec::with_capacity(probes);
    let mut busy = Conn::connect(addr);
    for i in 0..probes {
        let t0 = Instant::now();
        assert_eq!(busy.roundtrip(&format!("/busy/{i}")), 200);
        lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lat_us[probes / 2];
    let p99 = lat_us[(probes * 99) / 100 - 1];
    println!("  {probes} probes behind {idle_n} idle conns: p50 {p50:.1} µs, p99 {p99:.1} µs");
    drop(idle);

    let summary = Json::obj()
        .set("bench", "bench_http")
        .set("quick", quick)
        .set(
            "results",
            Json::Arr(b.results().iter().map(|r| r.to_json()).collect()),
        )
        .set(
            "derived",
            Json::obj()
                .set("roundtrip_1conn_ns", rt.mean_ns)
                .set(
                    "fleet_rps",
                    Json::Arr(
                        rps.iter()
                            .map(|(c, v)| Json::obj().set("conns", *c as u64).set("rps", *v))
                            .collect(),
                    ),
                )
                .set("idle_fleet", idle_n as u64)
                .set("busy_p50_us_behind_idle_fleet", p50)
                .set("busy_p99_us_behind_idle_fleet", p99),
        );
    let path = std::env::var("BENCH_HTTP_JSON").unwrap_or_else(|_| "BENCH_http.json".to_string());
    match std::fs::write(&path, summary.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    s.stop();
}
