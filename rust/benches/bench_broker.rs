//! PERF-BROKER bench: before/after for the broker striping rework, plus
//! the durability tax on the publish path.
//!
//!     cargo bench --bench bench_broker
//!
//! * **single mutex vs striped** — a trimmed replica of the pre-rework
//!   broker (one `Mutex<Inner>` guarding every topic and queue) against
//!   the real per-topic-lock broker, with N publisher threads each owning
//!   a topic. On the single mutex the threads serialize; with striping
//!   they do not, which is the whole point of the rework.
//! * **durable vs non-durable publish** — the same publish workload with
//!   the WAL persister attached (group commit, no fsync) vs detached.
//!
//! Emits `BENCH_broker.json` (override the path with `BENCH_BROKER_JSON`;
//! `scripts/bench.sh` points it at the repo root). The `derived` section
//! carries the cross-topic speedup so "publishers on different topics no
//! longer serialize" is machine-checkable.

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use idds::broker::Broker;
use idds::metrics::Registry;
use idds::persist::{FsyncMode, Persist, PersistOptions};
use idds::store::Store;
use idds::util::bench::{section, BenchResult, Bencher};
use idds::util::clock::WallClock;
use idds::util::json::Json;

/// Trimmed replica of the pre-striping broker: every operation takes the
/// one mutex. Semantics match the old hot path (publish fan-out, FIFO
/// poll, ack) minus redelivery bookkeeping, which favours the baseline —
/// the measured gap is therefore a lower bound on the real one.
mod single_mutex {
    use super::*;

    #[derive(Default)]
    struct SubQueue {
        pending: VecDeque<(u64, Json)>,
        in_flight: HashMap<u64, Json>,
    }

    #[derive(Default)]
    struct Inner {
        topics: HashMap<String, Vec<u64>>,
        queues: HashMap<u64, SubQueue>,
    }

    #[derive(Clone, Default)]
    pub struct SingleMutexBroker {
        inner: Arc<Mutex<Inner>>,
    }

    impl SingleMutexBroker {
        pub fn subscribe(&self, topic: &str) -> u64 {
            let id = idds::util::next_id();
            let mut inner = self.inner.lock().unwrap();
            inner.topics.entry(topic.to_string()).or_default().push(id);
            inner.queues.insert(id, SubQueue::default());
            id
        }

        pub fn publish_many(&self, topic: &str, payloads: Vec<Json>) -> usize {
            let mut inner = self.inner.lock().unwrap();
            let subs = inner.topics.get(topic).cloned().unwrap_or_default();
            let msgs: Vec<(u64, Json)> =
                payloads.into_iter().map(|p| (idds::util::next_id(), p)).collect();
            let mut depth = 0;
            for sub in subs {
                if let Some(q) = inner.queues.get_mut(&sub) {
                    for m in &msgs {
                        q.pending.push_back(m.clone());
                    }
                    depth = depth.max(q.pending.len());
                }
            }
            depth
        }

        pub fn poll(&self, sub: u64, max: usize) -> Vec<u64> {
            let mut inner = self.inner.lock().unwrap();
            let mut out = Vec::new();
            if let Some(q) = inner.queues.get_mut(&sub) {
                while out.len() < max {
                    let Some((id, payload)) = q.pending.pop_front() else { break };
                    q.in_flight.insert(id, payload);
                    out.push(id);
                }
            }
            out
        }

        pub fn ack_many(&self, sub: u64, ids: &[u64]) -> usize {
            let mut inner = self.inner.lock().unwrap();
            let mut n = 0;
            if let Some(q) = inner.queues.get_mut(&sub) {
                for id in ids {
                    if q.in_flight.remove(id).is_some() {
                        n += 1;
                    }
                }
            }
            n
        }
    }
}

use single_mutex::SingleMutexBroker;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "idds-bench-broker-{tag}-{}-{}",
        std::process::id(),
        idds::util::next_id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// One full producer/consumer round on `topics` topics: every topic gets
/// its own publisher thread (batches of `batch`) and its own consumer
/// thread (poll + ack until drained). `publish`/`consume` abstract over
/// the two broker shapes.
fn cross_topic_round(
    topics: usize,
    msgs_per_topic: usize,
    batch: usize,
    publish: impl Fn(usize, Vec<Json>) + Send + Sync + 'static + Clone,
    consume: impl Fn(usize) -> usize + Send + Sync + 'static + Clone,
) {
    let mut handles = Vec::new();
    for t in 0..topics {
        let publish = publish.clone();
        handles.push(std::thread::spawn(move || {
            let mut sent = 0;
            while sent < msgs_per_topic {
                let n = batch.min(msgs_per_topic - sent);
                publish(t, (0..n).map(|i| Json::Num((sent + i) as f64)).collect());
                sent += n;
            }
        }));
        let consume = consume.clone();
        handles.push(std::thread::spawn(move || {
            let mut got = 0;
            while got < msgs_per_topic {
                let n = consume(t);
                if n == 0 {
                    std::thread::yield_now();
                }
                got += n;
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

fn main() {
    let mut b = Bencher::from_env();
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);

    let topics: usize = 8;
    let msgs_per_topic: usize = if quick { 2_000 } else { 20_000 };
    let batch: usize = 64;

    section(&format!(
        "cross-topic contention: {topics} publisher+consumer pairs, {msgs_per_topic} msgs/topic"
    ));
    // before: every topic hammers the same mutex
    let single = b.bench_with_setup(
        "single-mutex broker (pre-rework replica)",
        || {
            let br = SingleMutexBroker::default();
            let subs: Vec<u64> = (0..topics).map(|t| br.subscribe(&format!("t{t}"))).collect();
            (br, subs)
        },
        |(br, subs)| {
            let (p, c) = (br.clone(), br.clone());
            let subs = subs.clone();
            cross_topic_round(
                topics,
                msgs_per_topic,
                batch,
                move |t, payloads| {
                    p.publish_many(&format!("t{t}"), payloads);
                },
                move |t| {
                    let ids = c.poll(subs[t], 64);
                    c.ack_many(subs[t], &ids);
                    ids.len()
                },
            );
        },
    );
    // after: per-topic locks — the same workload, no shared lock
    let striped = b.bench_with_setup(
        "striped broker (per-topic locks)",
        || {
            let br = Broker::new(Arc::new(WallClock::new())).with_redelivery_timeout(3600.0);
            let subs: Vec<u64> = (0..topics).map(|t| br.subscribe(&format!("t{t}"))).collect();
            (br, subs)
        },
        |(br, subs)| {
            let (p, c) = (br.clone(), br.clone());
            let subs = subs.clone();
            cross_topic_round(
                topics,
                msgs_per_topic,
                batch,
                move |t, payloads| {
                    p.publish_many(&format!("t{t}"), payloads);
                },
                move |t| {
                    let ds = c.poll(subs[t], 64);
                    let ids: Vec<u64> = ds.iter().map(|d| d.id).collect();
                    c.ack_many(subs[t], &ids);
                    ids.len()
                },
            );
        },
    );
    let total = (topics * msgs_per_topic) as f64;
    let single_mps = total / (single.mean_ns / 1e9);
    let striped_mps = total / (striped.mean_ns / 1e9);
    let speedup = striped_mps / single_mps.max(1e-9);
    println!(
        "\nsingle mutex: {single_mps:.0} msg/s   striped: {striped_mps:.0} msg/s   \
         cross-topic speedup: {speedup:.1}x"
    );

    section("single-topic parity (striping must not tax the uncontended path)");
    let one_topic = {
        let br = Broker::new(Arc::new(WallClock::new())).with_redelivery_timeout(3600.0);
        let sub = br.subscribe("t");
        b.bench("striped broker, 1 topic publish+poll+ack 1k", move || {
            br.publish_many("t", (0..1000).map(|i| Json::Num(i as f64)).collect());
            let ds = br.poll(sub, 1000);
            let ids: Vec<u64> = ds.iter().map(|d| d.id).collect();
            br.ack_many(sub, &ids)
        })
    };

    section("durable vs non-durable publish (group commit, fsync off)");
    let n_durable: usize = if quick { 1_000 } else { 10_000 };
    let plain = {
        let br = Broker::new(Arc::new(WallClock::new())).with_redelivery_timeout(3600.0);
        let sub = br.subscribe("t");
        let mut drained = 0usize;
        let r = b.bench(&format!("publish_many x{n_durable}, no WAL"), || {
            for _ in 0..(n_durable / 100) {
                br.publish_many("t", (0..100).map(|i| Json::Num(i as f64)).collect());
            }
            // drain so queues do not grow across iterations
            loop {
                let ds = br.poll(sub, 4096);
                if ds.is_empty() {
                    break;
                }
                drained += ds.len();
                br.ack_many(sub, &ds.iter().map(|d| d.id).collect::<Vec<_>>());
            }
        });
        assert!(drained > 0);
        r
    };
    let durable = {
        let dir = tmp_dir("durable");
        let store = Store::new(Arc::new(WallClock::new()));
        let br = Broker::new(Arc::new(WallClock::new())).with_redelivery_timeout(3600.0);
        let opts = PersistOptions {
            segment_bytes: 256 * 1024 * 1024,
            fsync: FsyncMode::Never,
            checkpoint_keep: 2,
            flush_idle_ms: 5,
            ..PersistOptions::default()
        };
        let (persist, _) =
            Persist::open_with_broker(&dir, opts, &store, Some(&br), Registry::default()).unwrap();
        let sub = br.subscribe("t");
        let mut drained = 0usize;
        let r = b.bench(&format!("publish_many x{n_durable}, WAL attached"), || {
            for _ in 0..(n_durable / 100) {
                br.publish_many("t", (0..100).map(|i| Json::Num(i as f64)).collect());
            }
            loop {
                let ds = br.poll(sub, 4096);
                if ds.is_empty() {
                    break;
                }
                drained += ds.len();
                br.ack_many(sub, &ds.iter().map(|d| d.id).collect::<Vec<_>>());
            }
            persist.flush();
        });
        assert!(drained > 0);
        persist.shutdown();
        std::fs::remove_dir_all(&dir).ok();
        r
    };
    let durable_overhead = durable.mean_ns / plain.mean_ns.max(1e-9);
    println!("\ndurable publish overhead: {durable_overhead:.2}x over non-durable");

    let to_json = |r: &BenchResult| r.to_json();
    let summary = Json::obj()
        .set("bench", "bench_broker")
        .set("quick", quick)
        .set("results", Json::Arr(b.results().iter().map(to_json).collect()))
        .set(
            "derived",
            Json::obj()
                .set("cross_topic_publishers", topics as u64)
                .set("single_mutex_msgs_per_sec", single_mps)
                .set("striped_msgs_per_sec", striped_mps)
                .set("cross_topic_speedup", speedup)
                .set("single_topic_roundtrip_ns", one_topic.mean_ns)
                .set("durable_publish_overhead", durable_overhead),
        );
    let path =
        std::env::var("BENCH_BROKER_JSON").unwrap_or_else(|_| "BENCH_broker.json".to_string());
    match std::fs::write(&path, summary.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
