//! ESS bench (paper section 1 motivation): delivery granularity — WAN
//! traffic for whole-file staging vs event-range streaming across job
//! selectivities, locating the crossover, plus cache-size sensitivity.
//!
//!     cargo bench --bench bench_ess

use idds::ess::{generate_trace, selectivity_sweep, simulate, Delivery, EssConfig};
use idds::util::bench::{section, Bencher};

fn main() {
    let mut b = Bencher::from_env();
    let cfg = EssConfig::default();

    section("ESS: WAN bytes vs job selectivity (2000 jobs, 50 GB edge cache)");
    println!(
        "{:<14} {:>16} {:>16} {:>10}",
        "selectivity", "whole-file GB", "event-range GB", "winner"
    );
    let rows = selectivity_sweep(
        &cfg,
        2000,
        &[0.005, 0.01, 0.02, 0.05, 0.1, 0.25, 0.5, 0.75, 1.0],
        7,
    );
    for (sel, wf, er) in rows {
        println!(
            "{sel:<14} {:>16.1} {:>16.1} {:>10}",
            wf as f64 / 1e9,
            er as f64 / 1e9,
            if er < wf { "ranged" } else { "whole" }
        );
    }

    section("ESS: cache-size sensitivity (selectivity 0.1)");
    println!(
        "{:<14} {:>14} {:>14} {:>12} {:>12}",
        "cache GB", "wf WAN GB", "er WAN GB", "wf hit %", "er hit %"
    );
    for cache_gb in [10u64, 25, 50, 100, 200] {
        let mut c = cfg.clone();
        c.cache_bytes = cache_gb * 1_000_000_000;
        let trace = generate_trace(&c, 2000, 0.1, 7);
        let wf = simulate(&c, Delivery::WholeFile, &trace);
        let er = simulate(&c, Delivery::EventRanges, &trace);
        println!(
            "{cache_gb:<14} {:>14.1} {:>14.1} {:>12.1} {:>12.1}",
            wf.wan_bytes as f64 / 1e9,
            er.wan_bytes as f64 / 1e9,
            wf.hit_ratio * 100.0,
            er.hit_ratio * 100.0
        );
    }

    section("simulator throughput");
    let trace = generate_trace(&cfg, 10_000, 0.1, 7);
    b.bench("ESS 10k-job trace (ranged)", || {
        simulate(&cfg, Delivery::EventRanges, &trace).wan_bytes
    });
}
