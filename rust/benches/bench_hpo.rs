//! FIG6/HPO bench: the HPO service evaluation.
//! * convergence: Bayesian (AOT GP+EI artifacts) vs random search on the
//!   AOT training payload — best-loss-after-k-evals table;
//! * fleet utilization: async pull (iDDS) vs synchronous rounds over a
//!   heterogeneous worker fleet (DES);
//! * proposal/evaluation latency on the PJRT runtime.
//!
//!     cargo bench --bench bench_hpo

use idds::hpo::sched::{sample_durations, simulate, Policy};
use idds::hpo::{payload_space, BayesOpt, Evaluated, Strategy};
use idds::runtime::{default_artifacts_dir, EngineHandle};
use idds::util::bench::{section, Bencher};
use idds::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::from_env();
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing; run `make artifacts` first");
        std::process::exit(1);
    }
    let engine = EngineHandle::start(&dir)?;
    let opt = BayesOpt::new(engine, payload_space())?;

    section("FIG6a convergence: best val-loss after k evaluations");
    let n = 12;
    let seeds = [11u64, 17, 23];
    let mut curves: Vec<(Strategy, Vec<f64>)> = Vec::new();
    for strat in [Strategy::Random, Strategy::Bayesian] {
        let mut acc = vec![0.0; n];
        for &s in &seeds {
            let r = opt.run(strat, n, s)?;
            for (i, v) in r.best_curve.iter().enumerate() {
                acc[i] += v / seeds.len() as f64;
            }
        }
        curves.push((strat, acc));
    }
    println!("{:<6} {:>12} {:>12}", "k", "Random", "Bayesian");
    for i in 0..n {
        println!("{:<6} {:>12.4} {:>12.4}", i + 1, curves[0].1[i], curves[1].1[i]);
    }
    println!(
        "=> final: random {:.4} vs bayesian {:.4}",
        curves[0].1[n - 1],
        curves[1].1[n - 1]
    );

    section("FIG6b fleet utilization: async (iDDS) vs sequential rounds");
    println!(
        "{:<10} {:>8} {:>18} {:>18} {:>12}",
        "workers", "points", "seq util %", "async util %", "speedup"
    );
    for workers in [8, 16, 32, 64] {
        let d = sample_durations(512, 900.0, 3);
        let s = simulate(Policy::SequentialRounds, &d, workers);
        let a = simulate(Policy::AsyncPull, &d, workers);
        println!(
            "{workers:<10} {:>8} {:>18.1} {:>18.1} {:>11.2}x",
            d.len(),
            s.utilization * 100.0,
            a.utilization * 100.0,
            s.makespan_s / a.makespan_s
        );
    }

    section("runtime latency (PJRT hot path)");
    let mut rng = Rng::new(1);
    let history: Vec<Evaluated> = (0..16)
        .map(|i| Evaluated {
            x: (0..4).map(|_| rng.f64()).collect(),
            loss: 1.0 / (i + 1) as f64,
        })
        .collect();
    b.bench("gp_propose (64 obs cap, 256 cand)", || {
        opt.propose(&history, &mut rng).unwrap()
    });
    let x = vec![0.5; 4];
    b.bench("mlp_train payload (50 steps)", || opt.evaluate(&x, 1).unwrap());
    Ok(())
}
