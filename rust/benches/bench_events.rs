//! PERF-EVENTS bench: event-bus wakeup latency vs interval polling, and
//! publish fan-out cost as the subscriber population grows.
//!
//!     cargo bench --bench bench_events
//!
//! Emits `BENCH_events.json` (override the path with `BENCH_EVENTS_JSON=...`;
//! `scripts/bench.sh` points it at the repo root). The `derived` section
//! carries the signal-vs-poll latency ratio — the number that justifies
//! replacing the daemons' fixed poll loops with bus wakeups.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use idds::metrics::Registry;
use idds::persist::{EventBus, PersistEvent};
use idds::store::RequestKind;
use idds::util::bench::{section, Bencher};
use idds::util::json::Json;

fn ev(i: u64) -> PersistEvent {
    PersistEvent::AddRequest {
        id: i,
        name: format!("r{i}"),
        requester: "u".into(),
        kind: RequestKind::Workflow,
        workflow: Json::Null,
        at: 0.0,
    }
}

/// Round-trip latency from `publish` to a consumer blocked in
/// `WakeSignal::wait_past` observing it, averaged over `rounds`.
fn signal_latency(rounds: u32) -> Duration {
    let bus = EventBus::new(&Registry::default());
    let signal = bus.watch(idds::persist::bus::T_ALL);
    let stop = Arc::new(AtomicBool::new(false));
    let woken_at = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let consumer = {
        let signal = Arc::clone(&signal);
        let stop = Arc::clone(&stop);
        let woken_at = Arc::clone(&woken_at);
        std::thread::spawn(move || {
            let mut seen = signal.epoch();
            while !stop.load(Ordering::Acquire) {
                let (now, woke) = signal.wait_past(seen, Duration::from_millis(250));
                seen = now;
                if woke {
                    woken_at.store(t0.elapsed().as_nanos() as u64, Ordering::Release);
                }
            }
        })
    };
    let mut total = Duration::ZERO;
    for i in 0..rounds {
        woken_at.store(0, Ordering::Release);
        std::thread::sleep(Duration::from_millis(2)); // consumer reaches wait_past
        let published = t0.elapsed().as_nanos() as u64;
        bus.publish(&[(u64::from(i) + 1, ev(u64::from(i) + 1))]);
        loop {
            let woke = woken_at.load(Ordering::Acquire);
            if woke > published {
                total += Duration::from_nanos(woke - published);
                break;
            }
            std::hint::spin_loop();
        }
    }
    stop.store(true, Ordering::Release);
    signal.notify();
    consumer.join().unwrap();
    total / rounds
}

/// The same round-trip when the consumer polls a flag on a fixed
/// interval instead of blocking on the signal — the pre-bus daemon loop.
fn poll_latency(rounds: u32, interval: Duration) -> Duration {
    let flag = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let woken_at = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let consumer = {
        let flag = Arc::clone(&flag);
        let stop = Arc::clone(&stop);
        let woken_at = Arc::clone(&woken_at);
        std::thread::spawn(move || {
            let mut seen = 0u64;
            while !stop.load(Ordering::Acquire) {
                std::thread::sleep(interval);
                let now = flag.load(Ordering::Acquire);
                if now > seen {
                    seen = now;
                    woken_at.store(t0.elapsed().as_nanos() as u64, Ordering::Release);
                }
            }
        })
    };
    let mut total = Duration::ZERO;
    for i in 0..rounds {
        woken_at.store(0, Ordering::Release);
        let published = t0.elapsed().as_nanos() as u64;
        flag.store(u64::from(i) + 1, Ordering::Release);
        loop {
            let woke = woken_at.load(Ordering::Acquire);
            if woke > published {
                total += Duration::from_nanos(woke - published);
                break;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
    }
    stop.store(true, Ordering::Release);
    consumer.join().unwrap();
    total / rounds
}

fn main() {
    let mut b = Bencher::from_env();
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);

    section("wakeup latency: bus signal vs 50ms interval poll");
    let rounds: u32 = if quick { 20 } else { 100 };
    let sig = signal_latency(rounds);
    let poll = poll_latency(rounds, Duration::from_millis(50));
    let ratio = poll.as_secs_f64() / sig.as_secs_f64().max(1e-9);
    println!(
        "signal wakeup: {:.1}us   50ms-poll wakeup: {:.1}ms   ratio: {ratio:.0}x",
        sig.as_secs_f64() * 1e6,
        poll.as_secs_f64() * 1e3,
    );

    section("publish fan-out (per-batch cost as subscribers grow)");
    let batch: u64 = if quick { 64 } else { 256 };
    let events: Vec<(u64, PersistEvent)> = (1..=batch).map(|i| (i, ev(i))).collect();
    let mut fanout = Vec::new();
    for subs in [1usize, 64, 512] {
        let n = if quick { subs.min(64) } else { subs };
        let bus = EventBus::new(&Registry::default());
        // queues hold one full batch; each round drains them so the
        // overflow path never skews the publish cost being measured
        let keep: Vec<_> = (0..n)
            .map(|_| bus.subscribe(idds::persist::bus::T_ALL, None, batch as usize * 2))
            .collect();
        let r = b.bench(&format!("publish+drain {batch}-event batch, {n} subscribers"), || {
            bus.publish(&events);
            let mut drained = 0usize;
            for s in &keep {
                drained += s.drain(usize::MAX).0.len();
            }
            drained
        });
        let per_event_ns = r.mean_ns / batch as f64;
        fanout.push((n, per_event_ns));
        drop(keep);
    }
    for (n, ns) in &fanout {
        println!("{n:>4} subscribers: {ns:.0} ns/event published");
    }

    let summary = Json::obj()
        .set("bench", "bench_events")
        .set("quick", quick)
        .set(
            "results",
            Json::Arr(b.results().iter().map(|r| r.to_json()).collect()),
        )
        .set(
            "derived",
            Json::obj()
                .set("signal_wakeup_us", sig.as_secs_f64() * 1e6)
                .set("poll_50ms_wakeup_ms", poll.as_secs_f64() * 1e3)
                .set("wakeup_latency_ratio", ratio)
                .set(
                    "fanout_ns_per_event",
                    Json::Arr(
                        fanout
                            .iter()
                            .map(|(n, ns)| {
                                Json::obj().set("subscribers", *n as u64).set("ns_per_event", *ns)
                            })
                            .collect(),
                    ),
                ),
        );
    let path =
        std::env::var("BENCH_EVENTS_JSON").unwrap_or_else(|_| "BENCH_events.json".to_string());
    match std::fs::write(&path, summary.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
