//! FIG4 / FIG5 / CLAIM-DISK / CLAIM-TTFP bench: regenerate the paper's
//! carousel evaluation. Prints the attempt table (Fig. 4), the campaign
//! series summary (Fig. 5), the disk-footprint and time-to-first-
//! processing comparisons, plus a parameter sweep over staging-window
//! sizes (ablation of the iDDS fine-grained window).
//!
//!     cargo bench --bench bench_carousel

use idds::carousel::{compare_modes, run_campaign, CarouselConfig, Granularity};
use idds::simulation::Scenario;
use idds::util::bench::{section, Bencher};

fn main() {
    let mut b = Bencher::from_env();

    let scenarios =
        [Scenario::Smoke, Scenario::Reprocessing, Scenario::SmallFiles, Scenario::BigFiles];
    for scen in scenarios {
        section(&format!("FIG4/FIG5 scenario {scen:?}"));
        let spec = scen.campaign();
        let (coarse, fine) = compare_modes(&scen.config(Granularity::Fine), &spec);
        println!(
            "{:<28} {:>14} {:>14} {:>9}",
            "metric", "without iDDS", "with iDDS", "ratio"
        );
        let rows: Vec<(&str, f64, f64)> = vec![
            ("total job attempts", coarse.total_attempts as f64, fine.total_attempts as f64),
            ("failed attempts", coarse.failed_attempts as f64, fine.failed_attempts as f64),
            (
                "peak disk GB",
                coarse.peak_disk_bytes as f64 / 1e9,
                fine.peak_disk_bytes as f64 / 1e9,
            ),
            ("mean disk GB", coarse.mean_disk_bytes / 1e9, fine.mean_disk_bytes / 1e9),
            (
                "time-to-first-proc s",
                coarse.time_to_first_processing_s,
                fine.time_to_first_processing_s,
            ),
            ("makespan s", coarse.makespan_s, fine.makespan_s),
            ("tape mounts", coarse.tape_mounts as f64, fine.tape_mounts as f64),
        ];
        for (name, c, f) in rows {
            println!(
                "{name:<28} {c:>14.1} {f:>14.1} {:>8.2}x",
                if f.abs() > 1e-12 { c / f } else { f64::NAN }
            );
        }
        println!("\nFig.4 attempt histogram (attempts -> jobs):");
        println!("  without iDDS: {:?}", coarse.attempt_histogram);
        println!("  with    iDDS: {:?}", fine.attempt_histogram);
        println!("Fig.5 series lengths: staged {}, processed {}, disk {}",
            fine.timeline.series("staged_files").len(),
            fine.timeline.series("processed_jobs").len(),
            fine.timeline.series("disk_bytes").len());
    }

    section("ablation: staging window (fine mode, Reprocessing)");
    let spec = Scenario::Reprocessing.campaign();
    println!("{:<10} {:>12} {:>14} {:>12}", "window", "peak GB", "makespan s", "ttfp s");
    for window in [8, 32, 64, 128, 512] {
        let cfg = CarouselConfig {
            granularity: Granularity::Fine,
            staging_window: window,
            ..Default::default()
        };
        let r = run_campaign(&cfg, &spec);
        println!(
            "{window:<10} {:>12.1} {:>14.0} {:>12.0}",
            r.peak_disk_bytes as f64 / 1e9,
            r.makespan_s,
            r.time_to_first_processing_s
        );
    }

    section("simulator throughput");
    let spec = Scenario::Smoke.campaign();
    let cfg = Scenario::Smoke.config(Granularity::Fine);
    b.bench("carousel smoke campaign (200 files e2e)", || run_campaign(&cfg, &spec));
}
