//! PERF-OBS bench: the cost of the tracing subsystem, on and off.
//!
//!     cargo bench --bench bench_obs
//!
//! * **disarmed span** — `obs::span()` with the tracer disarmed. This is
//!   the tax every instrumented call site pays in a production process
//!   that has tracing switched off: one relaxed atomic load and an
//!   immediate return. It must be indistinguishable from noise.
//! * **armed span + ring push** — span create + drop with the tracer
//!   armed, i.e. id allocation, thread-local swap, clock reads, and the
//!   completed-span ring push.
//! * **hot path, tracing on vs off** — a real workload (striped broker
//!   publish/poll/ack, which now carries `broker.publish` and
//!   `broker.deliver` spans) run both ways, so the end-to-end overhead of
//!   arming the tracer is machine-checkable.
//!
//! Emits `BENCH_obs.json` (override the path with `BENCH_OBS_JSON`;
//! `scripts/bench.sh` points it at the repo root).

use std::sync::Arc;

use idds::broker::Broker;
use idds::obs;
use idds::util::bench::{section, BenchResult, Bencher};
use idds::util::clock::WallClock;
use idds::util::json::Json;

fn main() {
    let mut b = Bencher::from_env();
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);

    let spans_per_iter: usize = if quick { 10_000 } else { 100_000 };

    section(&format!("span create+drop x{spans_per_iter} (micro)"));
    obs::arm(false);
    let disarmed = b.bench("disarmed span (armed check only)", || {
        for _ in 0..spans_per_iter {
            let sp = obs::span("bench.noop");
            std::hint::black_box(&sp);
        }
    });
    obs::arm(true);
    let armed = b.bench("armed span (ids + clock + ring push)", || {
        for _ in 0..spans_per_iter {
            let mut sp = obs::span("bench.noop");
            sp.attr("i", 1u64);
            std::hint::black_box(&sp);
        }
    });
    let disarmed_ns = disarmed.mean_ns / spans_per_iter as f64;
    let armed_ns = armed.mean_ns / spans_per_iter as f64;
    println!("\ndisarmed: {disarmed_ns:.1} ns/span   armed: {armed_ns:.1} ns/span");

    section("hot path: broker publish/poll/ack 10k msgs, tracing off vs on");
    let n_msgs: usize = if quick { 1_000 } else { 10_000 };
    let round = |br: &Broker, sub: u64| {
        for _ in 0..(n_msgs / 100) {
            br.publish_many("t", (0..100).map(|i| Json::Num(i as f64)).collect());
        }
        loop {
            let ds = br.poll(sub, 4096);
            if ds.is_empty() {
                break;
            }
            br.ack_many(sub, &ds.iter().map(|d| d.id).collect::<Vec<_>>());
        }
    };
    obs::arm(false);
    let off = {
        let br = Broker::new(Arc::new(WallClock::new())).with_redelivery_timeout(3600.0);
        let sub = br.subscribe("t");
        b.bench(&format!("broker round x{n_msgs}, tracing off"), move || round(&br, sub))
    };
    obs::arm(true);
    let on = {
        let br = Broker::new(Arc::new(WallClock::new())).with_redelivery_timeout(3600.0);
        let sub = br.subscribe("t");
        b.bench(&format!("broker round x{n_msgs}, tracing on"), move || round(&br, sub))
    };
    // leave the process as tests expect it: disarmed unless configured
    obs::arm(false);
    let hot_overhead = on.mean_ns / off.mean_ns.max(1e-9);
    println!("\nhot-path overhead with tracing armed: {hot_overhead:.3}x");

    let to_json = |r: &BenchResult| r.to_json();
    let summary = Json::obj()
        .set("bench", "bench_obs")
        .set("quick", quick)
        .set("results", Json::Arr(b.results().iter().map(to_json).collect()))
        .set(
            "derived",
            Json::obj()
                .set("disarmed_span_ns", disarmed_ns)
                .set("armed_span_ns", armed_ns)
                .set("armed_over_disarmed", armed_ns / disarmed_ns.max(1e-9))
                .set("hot_path_tracing_overhead", hot_overhead),
        );
    let path = std::env::var("BENCH_OBS_JSON").unwrap_or_else(|_| "BENCH_obs.json".to_string());
    match std::fs::write(&path, summary.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
