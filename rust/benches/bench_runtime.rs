//! PERF-RT bench: AOT artifact execution latency/throughput on the PJRT
//! hot path — the numbers behind EXPERIMENTS.md §Perf (L1/L2).
//!
//!     cargo bench --bench bench_runtime

use idds::runtime::{default_artifacts_dir, Engine};
use idds::util::bench::{section, Bencher};
use idds::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing; run `make artifacts` first");
        std::process::exit(1);
    }
    let mut b = Bencher::from_env();

    section("artifact compile (startup cost, once per process)");
    b.warmup = 0;
    let t0 = std::time::Instant::now();
    let engine = Engine::load(&dir)?;
    println!("Engine::load (3 artifacts): {:?}", t0.elapsed());
    b.warmup = 3;

    section("execution latency");
    let spec = engine.spec("gp_propose").unwrap().clone();
    let n_obs = spec.consts["n_obs"] as usize;
    let dim = spec.consts["dim"] as usize;
    let n_cand = spec.consts["n_cand"] as usize;
    let mut rng = Rng::new(3);
    let mut v = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.f64() as f32).collect() };
    let x_obs = v(n_obs * dim);
    let y_obs = v(n_obs);
    let mask = vec![1.0f32; n_obs];
    let x_cand = v(n_cand * dim);
    let params = [0.0f32, 0.0, (1e-4f32).ln(), 0.01];
    b.bench("gp_propose artifact", || {
        engine.gp_propose(&x_obs, &y_obs, &mask, &x_cand, &params).unwrap()
    });

    let mspec = engine.spec("mlp_train").unwrap().clone();
    let (tn, vn, id, hd) = (
        mspec.consts["train_n"] as usize,
        mspec.consts["val_n"] as usize,
        mspec.consts["in_dim"] as usize,
        mspec.consts["hidden"] as usize,
    );
    let xtr = v(tn * id);
    let ytr = v(tn);
    let xval = v(vn * id);
    let yval = v(vn);
    let w1 = v(id * hd);
    let b1 = vec![0.0f32; hd];
    let w2 = v(hd);
    let b2 = vec![0.0f32; 1];
    let hp = [(0.05f32).ln(), 0.9, (1e-6f32).ln(), (5.0f32).ln()];
    b.bench("mlp_train artifact (50 SGD steps)", || {
        engine
            .mlp_train(&hp, &xtr, &ytr, &xval, &yval, &w1, &b1, &w2, &b2)
            .unwrap()
    });

    let stats = vec![0.5f32; 8];
    let weights = vec![1.0f32; 8];
    b.bench("al_decision artifact", || {
        engine.al_decision(&stats, &weights, 0.0, 0.5).unwrap()
    });
    Ok(())
}
