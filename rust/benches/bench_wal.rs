//! PERF-WAL bench: durability-subsystem throughput — per-event-fsync
//! appends vs group-committed appends, and cold crash recovery over a
//! 100k-event log.
//!
//!     cargo bench --bench bench_wal
//!
//! Emits `BENCH_wal.json` (override the path with `BENCH_WAL_JSON=...`;
//! `scripts/bench.sh` points it at the repo root). The `derived` section
//! carries events/sec figures and the group-commit speedup so the
//! "group commit ≥ 5× per-event fsync" acceptance bar is machine-checkable.

use std::path::PathBuf;
use std::sync::Arc;

use idds::metrics::Registry;
use idds::persist::{FsyncMode, Persist, PersistOptions};
use idds::store::{RequestKind, RequestStatus, Store};
use idds::util::bench::{section, Bencher};
use idds::util::clock::WallClock;
use idds::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "idds-bench-wal-{tag}-{}-{}",
        std::process::id(),
        idds::util::next_id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn opts(fsync: FsyncMode) -> PersistOptions {
    PersistOptions {
        segment_bytes: 64 * 1024 * 1024,
        fsync,
        checkpoint_keep: 2,
        flush_idle_ms: 5,
        ..PersistOptions::default()
    }
}

fn fresh(fsync: FsyncMode, tag: &str) -> (Store, Persist, PathBuf) {
    let dir = tmp_dir(tag);
    let store = Store::new(Arc::new(WallClock::new()));
    let (persist, _) = Persist::open(&dir, opts(fsync), &store, Registry::default()).unwrap();
    (store, persist, dir)
}

fn main() {
    let mut b = Bencher::from_env();
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);

    // per-event-fsync baseline: every append waits for its own fsync
    let per_event_n: usize = if quick { 4 } else { 16 };
    // group commit: a burst of appends, one flush at the end
    let group_n: usize = if quick { 512 } else { 4096 };

    section("append: per-event fsync baseline vs group commit");
    let per_event = {
        let mut dirs = Vec::new();
        let r = b.bench_with_setup(
            &format!("append+fsync per event x{per_event_n}"),
            || {
                let (store, persist, dir) = fresh(FsyncMode::Group, "per-event");
                (store, persist, dir)
            },
            |(store, persist, dir)| {
                for i in 0..per_event_n {
                    store.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
                    persist.flush(); // one write+fsync per event
                }
                dirs.push(dir.clone());
            },
        );
        for d in dirs {
            std::fs::remove_dir_all(&d).ok();
        }
        r
    };
    let group = {
        let mut dirs = Vec::new();
        let r = b.bench_with_setup(
            &format!("group-committed append x{group_n} + 1 flush"),
            || fresh(FsyncMode::Group, "group"),
            |(store, persist, dir)| {
                for i in 0..group_n {
                    store.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
                }
                persist.flush(); // the flusher coalesced these already
                dirs.push(dir.clone());
            },
        );
        for d in dirs {
            std::fs::remove_dir_all(&d).ok();
        }
        r
    };
    let per_event_evps = per_event_n as f64 / (per_event.mean_ns / 1e9);
    let group_evps = group_n as f64 / (group.mean_ns / 1e9);
    let speedup = group_evps / per_event_evps.max(1e-9);
    println!(
        "\nper-event fsync: {per_event_evps:.0} ev/s   group commit: {group_evps:.0} ev/s   speedup: {speedup:.1}x"
    );

    section("cold recovery (checkpoint-free WAL replay)");
    // build one log: N/2 inserts + N/2 single-row transitions = N events
    let recovery_events: usize = if quick { 10_000 } else { 100_000 };
    let log_dir = tmp_dir("recovery");
    {
        let store = Store::new(Arc::new(WallClock::new()));
        let (persist, _) =
            Persist::open(&log_dir, opts(FsyncMode::Never), &store, Registry::default()).unwrap();
        let ids: Vec<u64> = (0..recovery_events / 2)
            .map(|i| store.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null))
            .collect();
        for id in &ids {
            store.update_request_status(*id, RequestStatus::Transforming).unwrap();
        }
        persist.shutdown();
    }
    let recovery = b.bench_with_setup(
        &format!("cold recovery of {recovery_events}-event log"),
        || Store::new(Arc::new(WallClock::new())),
        |store| {
            let (persist, report) =
                Persist::open(&log_dir, opts(FsyncMode::Never), store, Registry::default())
                    .unwrap();
            assert!(report.events_replayed >= recovery_events as u64);
            persist.shutdown();
        },
    );
    std::fs::remove_dir_all(&log_dir).ok();

    section("checkpoint write (50k-row store)");
    {
        let (store, persist, dir) = fresh(FsyncMode::Group, "ckpt");
        for i in 0..(if quick { 2_000 } else { 50_000 }) {
            store.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
        }
        // full base on purpose: the auto policy would write (empty) deltas
        // after the first round — bench_checkpoint covers the delta path
        b.bench("checkpoint snapshot+fsync", || {
            persist.checkpoint_full(&store).unwrap().bytes
        });
        persist.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    let summary = Json::obj()
        .set("bench", "bench_wal")
        .set("quick", quick)
        .set(
            "results",
            Json::Arr(b.results().iter().map(|r| r.to_json()).collect()),
        )
        .set(
            "derived",
            Json::obj()
                .set("per_event_fsync_events_per_sec", per_event_evps)
                .set("group_commit_events_per_sec", group_evps)
                .set("group_commit_speedup", speedup)
                .set("cold_recovery_events", recovery_events)
                .set("cold_recovery_ms", recovery.mean_ns / 1e6),
        );
    let path = std::env::var("BENCH_WAL_JSON").unwrap_or_else(|_| "BENCH_wal.json".to_string());
    match std::fs::write(&path, summary.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
