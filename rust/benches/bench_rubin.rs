//! RUBIN bench: 100k-job DAG generation, Work mapping, and the bulk-vs-
//! incremental release comparison at several scales (paper section 3.3.1).
//!
//!     cargo bench --bench bench_rubin

use idds::rubin::{generate_dag, map_to_works, schedule, Release};
use idds::util::bench::{section, Bencher};

fn main() {
    let mut b = Bencher::from_env();

    section("RUBIN scale: mapping latency");
    for &jobs in &[10_000usize, 100_000] {
        b.bench(&format!("generate+map {jobs} jobs"), || {
            let dag = generate_dag(jobs, 20, 4, 9);
            map_to_works(&dag).len()
        });
    }

    section("RUBIN release policy (makespan / release lag)");
    println!(
        "{:<10} {:>14} {:>14} {:>14} {:>14}",
        "jobs", "bulk span s", "inc span s", "bulk lag s", "inc lag s"
    );
    for &jobs in &[10_000usize, 50_000, 100_000] {
        let dag = generate_dag(jobs, 20, 4, 9);
        let bulk = schedule(&dag, 512, Release::Bulk);
        let inc = schedule(&dag, 512, Release::Incremental);
        println!(
            "{jobs:<10} {:>14.0} {:>14.0} {:>14.0} {:>14.0}",
            bulk.makespan_s, inc.makespan_s, bulk.mean_release_lag_s, inc.mean_release_lag_s
        );
    }

    section("scheduler throughput");
    let dag = generate_dag(100_000, 20, 4, 9);
    b.bench("schedule 100k jobs (incremental)", || {
        schedule(&dag, 512, Release::Incremental).jobs
    });
}
