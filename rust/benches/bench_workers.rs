//! PERF-WORKERS bench: the distributed-executor lease machinery — lease
//! claim throughput off the shared kind-queue, scheduler fairness when
//! four workers race the same queue, and how quickly a killed worker's
//! leases come back to the fleet.
//!
//!     cargo bench --bench bench_workers
//!
//! Emits `BENCH_workers.json` (override the path with
//! `BENCH_WORKERS_JSON=...`; `scripts/bench.sh` points it at the repo
//! root). The `derived` section carries claims/sec, the per-worker claim
//! spread (stddev / max-min ratio) across the 4-worker race, and the
//! observed redelivery latency beyond the lease timeout after a "kill"
//! (a worker that leases and then simply never heartbeats again).

use std::sync::Arc;

use idds::broker::lease::WorkerRegistry;
use idds::broker::Broker;
use idds::metrics::Registry;
use idds::util::bench::{section, Bencher};
use idds::util::clock::WallClock;
use idds::util::json::Json;

fn registry(timeout_s: f64) -> WorkerRegistry {
    let clock = Arc::new(WallClock::new());
    let broker = Broker::new(clock.clone()).with_redelivery_timeout(timeout_s);
    WorkerRegistry::new(broker, clock, Registry::default())
}

fn enqueue(reg: &WorkerRegistry, n: usize) {
    for i in 0..n {
        reg.enqueue("Noop", idds::util::next_id(), &Json::obj().set("i", i as f64));
    }
}

fn main() {
    let mut b = Bencher::from_env();
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let n: usize = if quick { 2_000 } else { 20_000 };
    let kinds = ["Noop".to_string()];

    section(&format!("lease claim throughput: one worker draining {n} queued Works"));
    let claim = b.bench_with_setup(
        &format!("lease_claim_{n}_batch64"),
        || {
            let reg = registry(30.0);
            let (w, _epoch) = reg.register("bench-claim", &kinds);
            enqueue(&reg, n);
            (reg, w)
        },
        |(reg, w)| {
            let mut got = 0usize;
            while got < n {
                let grants = reg.lease(*w, 64).expect("known worker");
                assert!(!grants.is_empty(), "queue drained early at {got}");
                got += grants.len();
            }
            got
        },
    );
    let claims_per_sec = n as f64 / (claim.mean_ns / 1e9);

    section(&format!("claim+complete+settle round-trip: {n} Works"));
    let roundtrip = b.bench_with_setup(
        &format!("lease_complete_take_{n}"),
        || {
            let reg = registry(30.0);
            let (w, epoch) = reg.register("bench-rt", &kinds);
            enqueue(&reg, n);
            (reg, w, epoch)
        },
        |(reg, w, epoch)| {
            let mut done = 0usize;
            while done < n {
                for g in reg.lease(*w, 64).expect("known worker") {
                    assert!(reg.complete(*w, *epoch, g.lease, g.handle, Json::obj()));
                    assert!(reg.take_result(g.handle).is_some());
                    done += 1;
                }
            }
            done
        },
    );
    let roundtrips_per_sec = n as f64 / (roundtrip.mean_ns / 1e9);

    section(&format!("scheduler fairness: 4 workers racing {n} Works"));
    let (fair_counts, fair_stddev, fair_spread) = {
        let reg = registry(30.0);
        enqueue(&reg, n);
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let reg = reg.clone();
                let kinds = kinds.clone();
                std::thread::spawn(move || {
                    let (w, epoch) = reg.register(&format!("fair-{i}"), &kinds);
                    let mut claimed = 0u64;
                    let mut idle = 0u32;
                    // race until the queue stays dry: every claim is
                    // completed+settled so nothing redelivers
                    while idle < 3 {
                        let grants = reg.lease(w, 8).expect("known worker");
                        if grants.is_empty() {
                            idle += 1;
                            std::thread::sleep(std::time::Duration::from_micros(50));
                            continue;
                        }
                        idle = 0;
                        for g in grants {
                            reg.complete(w, epoch, g.lease, g.handle, Json::obj());
                            reg.take_result(g.handle);
                            claimed += 1;
                        }
                    }
                    claimed
                })
            })
            .collect();
        let counts: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(counts.iter().sum::<u64>(), n as u64);
        let mean = n as f64 / counts.len() as f64;
        let var = counts.iter().map(|&c| (c as f64 - mean).powi(2)).sum::<f64>()
            / counts.len() as f64;
        let stddev = var.sqrt();
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        let spread = if min > 0.0 { max / min } else { f64::INFINITY };
        println!("  per-worker claims {counts:?} (stddev {stddev:.1}, max/min {spread:.2})");
        (counts, stddev, spread)
    };

    section("redelivery latency after a kill: lease, never heartbeat, re-lease");
    let redeliveries: usize = if quick { 5 } else { 20 };
    let timeout_s = 0.05;
    let redeliver_ms = {
        let mut total_beyond_timeout = 0.0f64;
        for i in 0..redeliveries {
            let reg = registry(timeout_s);
            let (dead, _) = reg.register(&format!("dead-{i}"), &kinds);
            let (live, _) = reg.register(&format!("live-{i}"), &kinds);
            reg.enqueue("Noop", idds::util::next_id(), &Json::obj());
            assert_eq!(reg.lease(dead, 1).unwrap().len(), 1);
            let t0 = std::time::Instant::now();
            // the "kill": dead never heartbeats; poll as a survivor until
            // the broker hands the Work over
            loop {
                let grants = reg.lease(live, 1).expect("known worker");
                if !grants.is_empty() {
                    assert!(grants[0].redelivered);
                    break;
                }
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            total_beyond_timeout += (t0.elapsed().as_secs_f64() - timeout_s).max(0.0);
        }
        let mean_ms = total_beyond_timeout / redeliveries as f64 * 1e3;
        println!(
            "  mean latency beyond the {:.0}ms lease timeout: {mean_ms:.2} ms",
            timeout_s * 1e3
        );
        mean_ms
    };

    let summary = Json::obj()
        .set("bench", "bench_workers")
        .set("quick", quick)
        .set(
            "results",
            Json::Arr(b.results().iter().map(|r| r.to_json()).collect()),
        )
        .set(
            "derived",
            Json::obj()
                .set("works", n as u64)
                .set("lease_claims_per_sec", claims_per_sec)
                .set("claim_complete_settle_per_sec", roundtrips_per_sec)
                .set(
                    "fairness_claims_per_worker",
                    Json::Arr(fair_counts.iter().map(|&c| Json::from(c)).collect()),
                )
                .set("fairness_stddev", fair_stddev)
                .set("fairness_max_over_min", fair_spread)
                .set("redelivery_extra_latency_ms", redeliver_ms),
        );
    let path =
        std::env::var("BENCH_WORKERS_JSON").unwrap_or_else(|_| "BENCH_workers.json".to_string());
    match std::fs::write(&path, summary.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
