//! PERF-REPLICATION bench: WAL-shipping throughput and standby lag —
//! the primary-side ship scan (re-encode durable frames from segment
//! files), the standby-side fold (decode → idempotent replay → local
//! append), the full REST catch-up pipeline, and the steady-state lag a
//! follower holds while the primary writes at full speed.
//!
//!     cargo bench --bench bench_replication
//!
//! Emits `BENCH_replication.json` (override the path with
//! `BENCH_REPLICATION_JSON=...`; `scripts/bench.sh` points it at the
//! repo root). The `derived` section carries apply events/sec and the
//! steady-state `replication.lag_lsn` stats the acceptance bar asks for.

use std::path::PathBuf;
use std::sync::Arc;

use idds::broker::Broker;
use idds::config::Config;
use idds::metrics::Registry;
use idds::persist::replicate::{ship_frames, ShipReply};
use idds::persist::wal::decode_frames;
use idds::persist::{
    ClusterState, FsyncMode, Persist, PersistOptions, Replica, ReplicationOptions,
};
use idds::rest::{serve, ServerState};
use idds::store::{RequestKind, Store};
use idds::util::bench::{section, Bencher};
use idds::util::clock::WallClock;
use idds::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "idds-bench-repl-{tag}-{}-{}",
        std::process::id(),
        idds::util::next_id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn opts() -> PersistOptions {
    PersistOptions {
        segment_bytes: 4 * 1024 * 1024,
        fsync: FsyncMode::Never, // shipping reads durable bytes either way
        checkpoint_keep: 2,
        flush_idle_ms: 2,
        ..PersistOptions::default()
    }
}

/// A primary data dir preloaded with `n` request events, WAL flushed.
fn preload(n: usize, tag: &str) -> (Store, Persist, PathBuf) {
    let dir = tmp_dir(tag);
    let store = Store::new(Arc::new(WallClock::new()));
    let (persist, _) = Persist::open(&dir, opts(), &store, Registry::default()).unwrap();
    for i in 0..n {
        store.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
    }
    persist.flush();
    (store, persist, dir)
}

fn main() {
    let mut b = Bencher::from_env();
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let n: usize = if quick { 2_000 } else { 20_000 };

    section(&format!("ship scan: re-encode {n} durable frames from segments"));
    let (store, persist, dir) = preload(n, "ship");
    let durable = persist.wal().durable_lsn();
    let ship = b.bench("ship_frames full history", || {
        match ship_frames(persist.wal(), 1, usize::MAX).unwrap() {
            ShipReply::Batch { count, .. } => {
                assert!(count >= n);
                count
            }
            ShipReply::Gone { .. } => panic!("nothing pruned here"),
        }
    });
    let ship_evps = durable as f64 / (ship.mean_ns / 1e9);

    section(&format!("standby fold: decode + replay + local append of {n} frames"));
    let frames = match ship_frames(persist.wal(), 1, usize::MAX).unwrap() {
        ShipReply::Batch { frames, .. } => frames,
        ShipReply::Gone { .. } => unreachable!(),
    };
    let frame_bytes = frames.len();
    let mut fold_dirs = Vec::new();
    let fold = b.bench_with_setup(
        "decode+apply+append_shipped",
        || {
            let sdir = tmp_dir("fold");
            let sstore = Store::new(Arc::new(WallClock::new()));
            let sbroker = Broker::new(Arc::new(WallClock::new()));
            let (spersist, _) =
                Persist::open_replica(&sdir, opts(), &sstore, &sbroker, Registry::default())
                    .unwrap();
            (sstore, spersist, sdir)
        },
        |(sstore, spersist, sdir)| {
            let evs = decode_frames(&frames).unwrap();
            let applied = evs.len();
            for (lsn, ev) in evs {
                sstore.apply_event(&ev);
                spersist.wal().append_shipped(lsn, ev);
            }
            spersist.flush();
            fold_dirs.push(sdir.clone());
            applied
        },
    );
    for d in &fold_dirs {
        std::fs::remove_dir_all(d).ok();
    }
    let apply_evps = durable as f64 / (fold.mean_ns / 1e9);

    section(&format!("end-to-end catch-up over REST: {n} events"));
    let cfg = Config::defaults();
    let broker = Broker::new(Arc::new(WallClock::new()));
    let cluster = ClusterState::primary(Some(dir.clone()), 1);
    let server = serve(
        ServerState::new(store.clone(), broker, Registry::default(), &cfg)
            .with_persist(persist.clone())
            .with_cluster(cluster),
        &cfg,
    )
    .unwrap();
    let primary_addr = server.addr.to_string();
    let ropts = ReplicationOptions { poll_interval_ms: 1, batch_bytes: 1 << 20, retry_ms: 10 };
    let catchup = {
        let t0 = std::time::Instant::now();
        let sdir = tmp_dir("e2e");
        let sstore = Store::new(Arc::new(WallClock::new()));
        let sbroker = Broker::new(Arc::new(WallClock::new()));
        let smetrics = Registry::default();
        let (spersist, _) =
            Persist::open_replica(&sdir, opts(), &sstore, &sbroker, smetrics.clone()).unwrap();
        let scluster = ClusterState::replica(sdir.clone(), &primary_addr, 1);
        let replica = Replica::start(
            sstore,
            sbroker,
            spersist.clone(),
            scluster,
            "dev-token",
            ropts.clone(),
            smetrics,
        )
        .unwrap();
        while replica.cluster().applied_lsn() < durable {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let dt = t0.elapsed().as_secs_f64();
        replica.stop();
        spersist.shutdown();
        std::fs::remove_dir_all(&sdir).ok();
        println!("  catch-up: {n} events in {dt:.3}s ({:.0} ev/s)", durable as f64 / dt);
        durable as f64 / dt
    };

    section("steady-state lag: follower under a writing primary");
    let (lag_mean, lag_max) = {
        let sdir = tmp_dir("lag");
        let sstore = Store::new(Arc::new(WallClock::new()));
        let sbroker = Broker::new(Arc::new(WallClock::new()));
        let smetrics = Registry::default();
        let (spersist, _) =
            Persist::open_replica(&sdir, opts(), &sstore, &sbroker, smetrics.clone()).unwrap();
        let scluster = ClusterState::replica(sdir.clone(), &primary_addr, 1);
        let replica = Replica::start(
            sstore,
            sbroker,
            spersist.clone(),
            scluster,
            "dev-token",
            ropts,
            smetrics,
        )
        .unwrap();
        // let the follower reach the preloaded head before sampling
        while replica.cluster().applied_lsn() < durable {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let writes: usize = if quick { 2_000 } else { 10_000 };
        let mut samples = Vec::new();
        for i in 0..writes {
            store.add_request(&format!("w{i}"), "u", RequestKind::Workflow, Json::Null);
            if i % 64 == 0 {
                persist.flush();
                samples.push(replica.cluster().lag_lsn());
            }
        }
        persist.flush();
        let target = persist.wal().durable_lsn();
        while replica.cluster().applied_lsn() < target {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        replica.stop();
        spersist.shutdown();
        std::fs::remove_dir_all(&sdir).ok();
        let max = samples.iter().copied().max().unwrap_or(0);
        let mean = samples.iter().sum::<u64>() as f64 / samples.len().max(1) as f64;
        println!("  lag over {} samples: mean {mean:.1}, max {max}", samples.len());
        (mean, max)
    };

    server.stop();
    persist.shutdown();
    std::fs::remove_dir_all(&dir).ok();

    let summary = Json::obj()
        .set("bench", "bench_replication")
        .set("quick", quick)
        .set(
            "results",
            Json::Arr(b.results().iter().map(|r| r.to_json()).collect()),
        )
        .set(
            "derived",
            Json::obj()
                .set("events", n as u64)
                .set("ship_scan_events_per_sec", ship_evps)
                .set("ship_batch_bytes", frame_bytes as u64)
                .set("apply_events_per_sec", apply_evps)
                .set("rest_catchup_events_per_sec", catchup)
                .set("steady_state_lag_mean_lsn", lag_mean)
                .set("steady_state_lag_max_lsn", lag_max),
        );
    let path = std::env::var("BENCH_REPLICATION_JSON")
        .unwrap_or_else(|_| "BENCH_replication.json".to_string());
    match std::fs::write(&path, summary.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
