//! PERF-CHECKPOINT bench: full (base) vs delta checkpoint cost on a
//! large store — bytes and latency at 0.1% / 1% / 10% churn — plus the
//! chain-fold recovery cost (base + K deltas vs base alone).
//!
//!     cargo bench --bench bench_checkpoint
//!
//! Emits `BENCH_checkpoint.json` (override the path with
//! `BENCH_CHECKPOINT_JSON=...`; `scripts/bench.sh` points it at the repo
//! root). The `derived` section carries the delta-vs-base byte ratios so
//! the "≥10× fewer bytes at ≤1% churn" acceptance bar is
//! machine-checkable: delta checkpoint I/O must scale with dirty rows,
//! not table size.

use std::path::PathBuf;
use std::sync::Arc;

use idds::metrics::Registry;
use idds::persist::{FsyncMode, Persist, PersistOptions};
use idds::store::{CollectionKind, RequestKind, Store};
use idds::util::bench::{fmt_ns, section, Bencher};
use idds::util::clock::WallClock;
use idds::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "idds-bench-ckpt-{tag}-{}-{}",
        std::process::id(),
        idds::util::next_id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn opts() -> PersistOptions {
    PersistOptions {
        segment_bytes: 256 * 1024 * 1024,
        fsync: FsyncMode::Never, // isolate serialization+write cost from fsync
        checkpoint_keep: 2,
        flush_idle_ms: 5,
        ..PersistOptions::default()
    }
}

/// One campaign-shaped store: a request/transform/collection spine with
/// `n` contents (the table that dominates at HL-LHC scale).
fn populate(store: &Store, n: usize) -> Vec<u64> {
    let rid = store.add_request("campaign", "bench", RequestKind::DataCarousel, Json::Null);
    let tid = store.add_transform(rid, "stage", Json::Null);
    let cid = store.add_collection(tid, "in-ds", CollectionKind::Input);
    store.add_contents(cid, (0..n).map(|i| (format!("f{i}"), 1_000_000 + i as u64)))
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    // full-checkpoint serialization of 1M rows is heavy; keep iteration
    // counts small and let the spread show in p50/p99
    let mut b = Bencher::new(1, if quick { 2 } else { 5 });
    let n_contents: usize = if quick { 50_000 } else { 1_000_000 };

    let dir = tmp_dir("main");
    let store = Store::new(Arc::new(WallClock::new()));
    let (persist, _) = Persist::open(&dir, opts(), &store, Registry::default()).unwrap();
    let ids = populate(&store, n_contents);
    println!("store populated: {n_contents} contents");

    section("base (full) checkpoint");
    let mut base_bytes = 0u64;
    let base = b.bench(&format!("base checkpoint ({n_contents} contents)"), || {
        let r = persist.checkpoint_full(&store).unwrap();
        base_bytes = r.bytes;
        r.bytes
    });
    println!("base checkpoint bytes: {base_bytes}");

    section("delta checkpoints at 0.1% / 1% / 10% churn");
    // churn via set_content_ddm_file: always legal, marks exactly k rows
    // dirty, and the delta must scale with k — not with n_contents
    let mut delta_stats: Vec<(f64, u64, f64)> = Vec::new(); // (churn, bytes, mean_ns)
    for churn in [0.001_f64, 0.01, 0.1] {
        let k = ((n_contents as f64 * churn) as usize).max(1);
        let mut bytes = 0u64;
        let mut stamp = 0u64;
        let res = b.bench_with_setup(
            &format!("delta checkpoint @ {:.1}% churn ({k} rows)", churn * 100.0),
            || {
                stamp += 1;
                for &id in &ids[..k] {
                    store.set_content_ddm_file(id, stamp).unwrap();
                }
            },
            |_| {
                let r = persist.checkpoint_delta(&store).unwrap();
                assert!(!r.full, "forced delta");
                assert_eq!(r.rows, k as u64, "delta rows == churned rows");
                bytes = r.bytes;
                r.bytes
            },
        );
        let ratio = base_bytes as f64 / bytes.max(1) as f64;
        println!(
            "churn {:>5.1}%: delta {bytes} bytes vs base {base_bytes} ({ratio:.1}x smaller)",
            churn * 100.0
        );
        delta_stats.push((churn, bytes, res.mean_ns));
    }
    persist.shutdown();

    section("chain-fold recovery (base + K deltas) vs base-only");
    // a fresh dir with a deterministic chain: base, then K deltas of 1%
    // churn each, no WAL suffix beyond the chain tail
    let k_deltas = 8usize;
    let chain_dir = tmp_dir("chain");
    {
        let s = Store::new(Arc::new(WallClock::new()));
        let (p, _) = Persist::open(&chain_dir, opts(), &s, Registry::default()).unwrap();
        let ids = populate(&s, n_contents);
        p.checkpoint_full(&s).unwrap();
        let step = (n_contents / 100).max(1);
        for round in 0..k_deltas {
            for &id in &ids[round * step..(round + 1) * step] {
                s.set_content_ddm_file(id, round as u64 + 1).unwrap();
            }
            let r = p.checkpoint_delta(&s).unwrap();
            assert!(!r.full);
        }
        p.shutdown();
    }
    let chain_recovery = b.bench_with_setup(
        &format!("recovery: base + {k_deltas} deltas fold"),
        || Store::new(Arc::new(WallClock::new())),
        |s| {
            let (p, report) = Persist::open(&chain_dir, opts(), s, Registry::default()).unwrap();
            assert_eq!(report.deltas_folded, k_deltas);
            p.shutdown();
        },
    );
    std::fs::remove_dir_all(&chain_dir).ok();

    let base_dir = tmp_dir("baseonly");
    {
        let s = Store::new(Arc::new(WallClock::new()));
        let (p, _) = Persist::open(&base_dir, opts(), &s, Registry::default()).unwrap();
        populate(&s, n_contents);
        p.checkpoint_full(&s).unwrap();
        p.shutdown();
    }
    let base_recovery = b.bench_with_setup(
        "recovery: base only",
        || Store::new(Arc::new(WallClock::new())),
        |s| {
            let (p, report) = Persist::open(&base_dir, opts(), s, Registry::default()).unwrap();
            assert_eq!(report.deltas_folded, 0);
            p.shutdown();
        },
    );
    std::fs::remove_dir_all(&base_dir).ok();
    std::fs::remove_dir_all(&dir).ok();

    println!(
        "\nchain-fold overhead: {} (base-only) -> {} (base + {k_deltas} deltas)",
        fmt_ns(base_recovery.mean_ns),
        fmt_ns(chain_recovery.mean_ns)
    );

    let mut derived = Json::obj()
        .set("contents", n_contents)
        .set("base_bytes", base_bytes)
        .set("base_checkpoint_ms", base.mean_ns / 1e6)
        .set("chain_deltas", k_deltas)
        .set("chain_fold_recovery_ms", chain_recovery.mean_ns / 1e6)
        .set("base_only_recovery_ms", base_recovery.mean_ns / 1e6);
    for (churn, bytes, mean_ns) in &delta_stats {
        let tag = format!("{}pct", churn * 1000.0 / 10.0);
        derived = derived
            .set(&format!("delta_bytes_{tag}"), *bytes)
            .set(&format!("delta_ms_{tag}"), mean_ns / 1e6)
            .set(
                &format!("base_over_delta_bytes_{tag}"),
                base_bytes as f64 / (*bytes).max(1) as f64,
            );
    }

    let summary = Json::obj()
        .set("bench", "bench_checkpoint")
        .set("quick", quick)
        .set(
            "results",
            Json::Arr(b.results().iter().map(|r| r.to_json()).collect()),
        )
        .set("derived", derived);
    let path = std::env::var("BENCH_CHECKPOINT_JSON")
        .unwrap_or_else(|_| "BENCH_checkpoint.json".to_string());
    match std::fs::write(&path, summary.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
