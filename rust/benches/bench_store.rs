//! PERF-STORE bench: state-store and broker throughput under daemon-like
//! load — the L3 coordinator must not be the bottleneck.
//!
//!     cargo bench --bench bench_store
//!
//! Emits a machine-readable summary to `BENCH_store.json` (override the
//! path with `BENCH_STORE_JSON=...`; `scripts/bench.sh` points it at the
//! repo root) so the perf trajectory is comparable PR-over-PR.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use idds::broker::Broker;
use idds::store::{CollectionKind, ContentStatus, Id, RequestKind, RequestStatus, Store};
use idds::util::bench::{section, Bencher};
use idds::util::clock::WallClock;
use idds::util::json::Json;

fn store_with_collection(clock: &Arc<WallClock>) -> (Store, Id) {
    let s = Store::new(clock.clone());
    let rid = s.add_request("r", "u", RequestKind::DataCarousel, Json::Null);
    let tid = s.add_transform(rid, "w", Json::Null);
    let cid = s.add_collection(tid, "in", CollectionKind::Input);
    (s, cid)
}

fn main() {
    let mut b = Bencher::from_env();
    let clock = Arc::new(WallClock::new());

    section("store contents (file-level granularity hot path)");
    {
        let (s, cid) = store_with_collection(&clock);
        b.bench("add_contents 10k files", || {
            s.add_contents(cid, (0..10_000).map(|i| (format!("f{i}"), 1u64)))
                .len()
        });
    }
    {
        // fresh contents per iteration, created OUTSIDE the timed region:
        // after one full pass the rows are terminal (Released), so timing
        // repeat passes would measure illegal-transition rejections, not
        // updates.
        let clock2 = clock.clone();
        b.bench_with_setup(
            "bulk status update 100k contents (5 passes)",
            move || {
                let (s, cid) = store_with_collection(&clock2);
                let ids = s.add_contents(cid, (0..100_000).map(|i| (format!("f{i}"), 1u64)));
                (s, ids)
            },
            |(s, ids)| {
                let mut moved = 0;
                moved += s.update_contents_status(ids.as_slice(), ContentStatus::Staging);
                moved += s.update_contents_status(ids.as_slice(), ContentStatus::Available);
                moved += s.update_contents_status(ids.as_slice(), ContentStatus::Delivered);
                moved += s.update_contents_status(ids.as_slice(), ContentStatus::Processed);
                moved += s.update_contents_status(ids.as_slice(), ContentStatus::Released);
                assert_eq!(moved, 500_000, "every pass must move every row");
                moved
            },
        );
        let (s, cid) = store_with_collection(&clock);
        let ids = s.add_contents(cid, (0..100_000).map(|i| (format!("f{i}"), 1u64)));
        s.update_contents_status(&ids, ContentStatus::Staging);
        b.bench("count_contents O(1) lookup", || {
            s.count_contents(cid, ContentStatus::Staging)
        });
    }

    section("status index scans (sorted BTreeSet indexes)");
    {
        let s = Store::new(clock.clone());
        for i in 0..10_000 {
            s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
        }
        b.bench("requests_with_status over 10k", || {
            s.requests_with_status(RequestStatus::New).len()
        });
        b.bench("requests_with_status_limit 256 of 10k", || {
            s.requests_with_status_limit(RequestStatus::New, 256).len()
        });
    }

    section("batched transitions vs per-row loop");
    {
        let clock2 = clock.clone();
        b.bench_with_setup(
            "per-row update_request_status x4096",
            move || {
                let s = Store::new(clock2.clone());
                let ids: Vec<Id> = (0..4096)
                    .map(|i| {
                        s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null)
                    })
                    .collect();
                (s, ids)
            },
            |(s, ids)| {
                for id in ids.iter() {
                    s.update_request_status(*id, RequestStatus::Transforming).unwrap();
                }
            },
        );
        let clock2 = clock.clone();
        b.bench_with_setup(
            "batched update_requests_status x4096",
            move || {
                let s = Store::new(clock2.clone());
                let ids: Vec<Id> = (0..4096)
                    .map(|i| {
                        s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null)
                    })
                    .collect();
                (s, ids)
            },
            |(s, ids)| {
                assert_eq!(
                    s.update_requests_status(ids.as_slice(), RequestStatus::Transforming),
                    4096
                );
            },
        );
    }

    section("multi-thread contention (4 writers x distinct collections + 4 pollers)");
    {
        const COLLS: usize = 4;
        const FILES: usize = 20_000;
        const CHUNK: usize = 2_048;
        let clock2 = clock.clone();
        b.bench_with_setup(
            "4 writers + 4 status pollers, 80k contents",
            move || {
                let s = Store::new(clock2.clone());
                let rid = s.add_request("r", "u", RequestKind::DataCarousel, Json::Null);
                let tid = s.add_transform(rid, "w", Json::Null);
                let colls: Vec<(Id, Vec<Id>)> = (0..COLLS)
                    .map(|c| {
                        let cid = s.add_collection(tid, &format!("in{c}"), CollectionKind::Input);
                        let ids =
                            s.add_contents(cid, (0..FILES).map(|i| (format!("f{c}/{i}"), 1u64)));
                        (cid, ids)
                    })
                    .collect();
                (s, colls)
            },
            |(s, colls)| {
                let done = AtomicBool::new(false);
                let mut polls = 0usize;
                std::thread::scope(|scope| {
                    for (_, ids) in colls.iter() {
                        let s = s.clone();
                        scope.spawn(move || {
                            for to in [
                                ContentStatus::Staging,
                                ContentStatus::Available,
                                ContentStatus::Delivered,
                                ContentStatus::Processed,
                            ] {
                                for chunk in ids.chunks(CHUNK) {
                                    s.update_contents_status(chunk, to);
                                }
                            }
                        });
                    }
                    let mut poll_handles = Vec::new();
                    for (cid, _) in colls.iter() {
                        let s = s.clone();
                        let done = &done;
                        let cid = *cid;
                        poll_handles.push(scope.spawn(move || {
                            let mut n = 0usize;
                            while !done.load(Ordering::Relaxed) {
                                std::hint::black_box(
                                    s.count_contents(cid, ContentStatus::Available),
                                );
                                std::hint::black_box(
                                    s.contents_with_status(cid, ContentStatus::Delivered).len(),
                                );
                                n += 1;
                            }
                            n
                        }));
                    }
                    // scope joins writers when the closure returns; signal
                    // pollers once writers are done by watching progress
                    for (cid, _) in colls.iter() {
                        while s.count_contents(*cid, ContentStatus::Processed) < FILES {
                            std::thread::yield_now();
                        }
                    }
                    done.store(true, Ordering::Relaxed);
                    for h in poll_handles {
                        polls += h.join().unwrap();
                    }
                });
                for (cid, _) in colls.iter() {
                    assert_eq!(s.count_contents(*cid, ContentStatus::Processed), FILES);
                }
                polls
            },
        );
    }

    section("broker: per-message vs batched publish/ack");
    {
        // before: one mutex acquisition per publish and per ack
        let br = Broker::new(clock.clone());
        let sub = br.subscribe("t");
        b.bench("per-message publish+poll+ack 1k", || {
            for i in 0..1000 {
                br.publish("t", Json::Num(i as f64));
            }
            let ds = br.poll(sub, 1000);
            for d in &ds {
                br.ack(sub, d.id);
            }
            ds.len()
        });
        // after: the Conductor's fan-out shape — one lock per batch
        let br = Broker::new(clock.clone());
        let sub = br.subscribe("t");
        b.bench("publish_many+poll+ack_many 1k", || {
            br.publish_many("t", (0..1000).map(|i| Json::Num(i as f64)).collect());
            let ds = br.poll(sub, 1000);
            let ids: Vec<u64> = ds.iter().map(|d| d.id).collect();
            br.ack_many(sub, &ids);
            ds.len()
        });
    }

    section("json");
    {
        let mut obj = Json::obj();
        for i in 0..100 {
            obj = obj.set(
                &format!("key{i}"),
                Json::Arr((0..20).map(|j| Json::Num((i * j) as f64)).collect()),
            );
        }
        let text = obj.to_string();
        println!("payload size: {} bytes", text.len());
        b.bench("json parse 100x20 object", || {
            idds::util::json::parse(&text).unwrap()
        });
        b.bench("json serialize 100x20 object", || obj.to_string());
        let mut buf = String::new();
        b.bench("json serialize into reused buffer", || {
            buf.clear();
            obj.write_to(&mut buf);
            buf.len()
        });
    }

    // machine-readable summary for PR-over-PR comparison
    let summary = Json::obj()
        .set("bench", "bench_store")
        .set(
            "quick",
            std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false),
        )
        .set(
            "results",
            Json::Arr(b.results().iter().map(|r| r.to_json()).collect()),
        );
    let path =
        std::env::var("BENCH_STORE_JSON").unwrap_or_else(|_| "BENCH_store.json".to_string());
    match std::fs::write(&path, summary.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
