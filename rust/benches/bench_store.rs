//! PERF-STORE bench: state-store and broker throughput under daemon-like
//! load — the L3 coordinator must not be the bottleneck.
//!
//!     cargo bench --bench bench_store

use std::sync::Arc;

use idds::broker::Broker;
use idds::store::{ContentStatus, RequestKind, Store};
use idds::util::bench::{section, Bencher};
use idds::util::clock::WallClock;
use idds::util::json::Json;

fn main() {
    let mut b = Bencher::from_env();
    let clock = Arc::new(WallClock::new());

    section("store contents (file-level granularity hot path)");
    {
        let s = Store::new(clock.clone());
        let rid = s.add_request("r", "u", RequestKind::DataCarousel, Json::Null);
        let tid = s.add_transform(rid, "w", Json::Null);
        let cid = s.add_collection(tid, "in", idds::store::CollectionKind::Input);
        b.bench("add_contents 10k files", || {
            s.add_contents(cid, (0..10_000).map(|i| (format!("f{i}"), 1u64)))
                .len()
        });
    }
    {
        let s = Store::new(clock.clone());
        let rid = s.add_request("r", "u", RequestKind::DataCarousel, Json::Null);
        let tid = s.add_transform(rid, "w", Json::Null);
        let cid = s.add_collection(tid, "in", idds::store::CollectionKind::Input);
        let ids = s.add_contents(cid, (0..100_000).map(|i| (format!("f{i}"), 1u64)));
        b.bench("bulk status update 100k contents", || {
            s.update_contents_status(&ids, ContentStatus::Staging);
            s.update_contents_status(&ids, ContentStatus::Available);
            s.update_contents_status(&ids, ContentStatus::Delivered);
            s.update_contents_status(&ids, ContentStatus::Processed);
            s.update_contents_status(&ids, ContentStatus::Released);
            // reset path for next iteration is impossible (terminal), so
            // re-add fresh contents outside timing? cost is dominated by
            // the 5 passes above regardless.
        });
        b.bench("count_contents O(1) lookup", || {
            s.count_contents(cid, ContentStatus::Released)
        });
    }

    section("status index scans");
    {
        let s = Store::new(clock.clone());
        for i in 0..10_000 {
            s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
        }
        b.bench("requests_with_status over 10k", || {
            s.requests_with_status(idds::store::RequestStatus::New).len()
        });
    }

    section("broker");
    {
        let br = Broker::new(clock.clone());
        let sub = br.subscribe("t");
        b.bench("publish+poll+ack 1k messages", || {
            for i in 0..1000 {
                br.publish("t", Json::Num(i as f64));
            }
            let ds = br.poll(sub, 1000);
            for d in &ds {
                br.ack(sub, d.id);
            }
            ds.len()
        });
    }

    section("json");
    {
        let mut obj = Json::obj();
        for i in 0..100 {
            obj = obj.set(
                &format!("key{i}"),
                Json::Arr((0..20).map(|j| Json::Num((i * j) as f64)).collect()),
            );
        }
        let text = obj.to_string();
        println!("payload size: {} bytes", text.len());
        b.bench("json parse 100x20 object", || {
            idds::util::json::parse(&text).unwrap()
        });
        b.bench("json serialize 100x20 object", || obj.to_string());
    }
}
