//! The five iDDS daemons over the shared store (paper section 2):
//!
//! ```text
//! client → [REST] → Request(New)
//!   Clerk       : Request New → Workflow engine → initial Works
//!                 (transforms) → Request Transforming; finalizes requests
//!                 whose transforms are all terminal + marshalled.
//!   Marshaller  : terminal transforms → evaluate Condition branches →
//!                 generate follow-up Works (DG support, incl. cycles).
//!   Transformer : Transform New → input/output Collections (+Contents) →
//!                 Processing(New) → Transform Activated→Running.
//!   Carrier     : Processing New → submit to executor → poll → Finished;
//!                 writes the Work result and queues a message.
//!   Conductor   : store messages New → claimed Delivered → broker publish
//!                 (claim commits first; see `Store::claim_messages` docs).
//! ```
//!
//! With durability on (`idds serve --data-dir`), the broker the Conductor
//! publishes into is itself durable: subscriptions, per-subscriber
//! backlogs and in-flight deliveries are rebuilt by recovery
//! (`Persist::open_with_broker`), so consumers resume exactly where the
//! previous process died instead of silently losing queued work — no
//! daemon-side resume logic is needed beyond publishing into the
//! recovered broker.
//!
//! All daemon state beyond the store lives in [`Pipeline`] (the per-request
//! workflow engines and the marshalled set) so the daemons stay restartable
//! and the store remains the single source of truth for status.
//!
//! **Interned workflows**: the Clerk resolves each submitted definition
//! through the process-wide `WorkflowRegistry` to a shared compiled graph
//! (`workflow.registry.hits`/`.misses`), so engines hold counters + an
//! `Arc`, never a full `Workflow` clone, and the Marshaller's condition
//! walk is driven by the per-source out-edge index
//! (`workflow.engine.condition_evals` counts evaluated edges). Engine
//! state is persisted per request (`Store::set_request_engine`) and the
//! engines map is lazily rebuilt from it after a restart, so conditions
//! pending at a crash still fire and already-fired ones never duplicate.
//!
//! **Change-driven polling**: every store table carries a generation
//! counter; each daemon remembers the generations it observed at the start
//! of its last tick and skips the tick entirely when nothing it depends on
//! has changed — no row or index lock is touched, only atomics. Skips are
//! counted in `pipeline.<daemon>.poll_skips`. Two wrinkles:
//!
//! * the Clerk's finalization gate also depends on the Marshaller's
//!   `marshalled` set, which is pipeline state, not store state — the
//!   Marshaller bumps a shared `marshal_epoch` the Clerk observes;
//! * the Carrier's polling stage watches *executors* complete, which is
//!   not a store event, so only its submit stage is generation-gated.
//!
//! All status writes on the tick path go through the store's batched
//! transition APIs (`update_*s_status`, `claim_messages`) — one lock
//! acquisition per stripe touched instead of a write lock per row.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::broker::Broker;
use crate::metrics::{Counter, Registry};
use crate::persist::bus::{
    EventBus, T_MESSAGES, T_PROCESSINGS, T_REQUESTS, T_TRANSFORMS,
};
use crate::store::{
    CollectionKind, Id, ProcessingStatus, RequestStatus, Store, TransformStatus,
};
use crate::util::json::Json;
use crate::workflow::{Engine as WfEngine, StateUpdate, Work, WorkKind, WorkflowRegistry};

use super::executors::ExecutorSet;
use super::Daemon;

/// Generation snapshot a daemon compares against; `u64::MAX` means "never
/// polled" so the first tick always runs.
struct Seen(AtomicU64);

impl Seen {
    fn new() -> Self {
        Seen(AtomicU64::new(u64::MAX))
    }

    /// True when `gen` matches the last observed value; otherwise records
    /// `gen` and returns false. Recording happens *before* the tick runs,
    /// so a daemon's own writes re-arm the next tick rather than being
    /// masked.
    fn unchanged(&self, gen: u64) -> bool {
        if self.0.load(Ordering::Acquire) == gen {
            true
        } else {
            self.0.store(gen, Ordering::Release);
            false
        }
    }

    /// Force the next tick to run — for daemons that stop at their batch
    /// limit with work left over but without having written to the store
    /// (the generations alone would mask the leftovers).
    fn rearm(&self) {
        self.0.store(u64::MAX, Ordering::Release);
    }
}

/// Shared pipeline context for all five daemons.
#[derive(Clone)]
pub struct Pipeline {
    pub store: Store,
    pub broker: Broker,
    pub metrics: Registry,
    pub executors: ExecutorSet,
    /// request id → live workflow engine (per-request counters over the
    /// interned compiled graph; lazily rebuilt from the store's persisted
    /// engine state after a restart — see [`Pipeline::with_engine`])
    engines: Arc<Mutex<HashMap<Id, WfEngine>>>,
    /// request id → names of transforms that already existed when the
    /// request's engine was rebuilt from persisted state. A recovered
    /// engine's counters may lag transforms written in the crash window,
    /// so its fan-out dedupes against this set (O(1) per work, built once
    /// per recovered request); requests that never recovered have no
    /// entry and pay nothing.
    recovered_names: Arc<Mutex<HashMap<Id, HashSet<String>>>>,
    /// transforms whose conditions the Marshaller has evaluated
    marshalled: Arc<Mutex<HashSet<Id>>>,
    /// bumped whenever `marshalled` grows — the non-store signal the
    /// Clerk's change-driven gate must observe
    marshal_epoch: Arc<AtomicU64>,
    /// event bus, when the host runs event-driven: marshal-epoch bumps
    /// are re-broadcast as synthetic requests-table signals (the epoch is
    /// pipeline state, so no WAL event ever carries it)
    bus: Option<EventBus>,
    batch: usize,
}

impl Pipeline {
    pub fn new(store: Store, broker: Broker, metrics: Registry, executors: ExecutorSet) -> Self {
        Pipeline {
            store,
            broker,
            metrics,
            executors,
            engines: Arc::new(Mutex::new(HashMap::new())),
            recovered_names: Arc::new(Mutex::new(HashMap::new())),
            marshalled: Arc::new(Mutex::new(HashSet::new())),
            marshal_epoch: Arc::new(AtomicU64::new(0)),
            bus: None,
            batch: 256,
        }
    }

    /// Attach the event bus so the Marshaller's marshal-epoch bumps wake
    /// the Clerk's finalization gate like any store mutation would.
    pub fn with_bus(mut self, bus: EventBus) -> Self {
        self.bus = Some(bus);
        self
    }

    pub fn daemons(&self) -> (Clerk, Marshaller, Transformer, Carrier, Conductor) {
        (
            Clerk {
                p: self.clone(),
                skips: self.metrics.poll_skip_counter("clerk"),
                seen_requests: Seen::new(),
                seen_transforms: Seen::new(),
                seen_epoch: Seen::new(),
            },
            Marshaller {
                p: self.clone(),
                skips: self.metrics.poll_skip_counter("marshaller"),
                seen_transforms: Seen::new(),
            },
            Transformer {
                p: self.clone(),
                skips: self.metrics.poll_skip_counter("transformer"),
                seen_transforms: Seen::new(),
            },
            Carrier {
                p: self.clone(),
                skips: self.metrics.poll_skip_counter("carrier"),
                seen_processings: Seen::new(),
            },
            Conductor {
                p: self.clone(),
                skips: self.metrics.poll_skip_counter("conductor"),
                seen_messages: Seen::new(),
            },
        )
    }

    fn mark_marshalled(&self, tf_id: Id) {
        self.marshalled.lock().unwrap().insert(tf_id);
        self.marshal_epoch.fetch_add(1, Ordering::Release);
        if let Some(bus) = &self.bus {
            bus.signal(T_REQUESTS);
        }
    }

    /// Materialize a generated Work as a transform. Idempotent by name
    /// (`template#iteration` is unique per engine) for recovered requests:
    /// if a crash landed the transform in the WAL but not the engine-state
    /// update, the re-fired condition after restart finds the name in the
    /// request's `recovered_names` set and skips it. Requests with no
    /// recovery history have no set and pay no check at all.
    fn add_work_transform(&self, request_id: Id, work: &Work, kind: WorkKind) -> bool {
        let tf_name = format!("{}#{}", work.template, work.iteration);
        {
            let mut recovered = self.recovered_names.lock().unwrap();
            if let Some(set) = recovered.get_mut(&request_id) {
                if !set.insert(tf_name.clone()) {
                    return false; // already materialized before the crash
                }
            }
        }
        // record the kind so the Carrier can dispatch without the engine
        let wj = work.to_json().set("kind", kind.as_str());
        self.store.add_transform(request_id, &tf_name, wj);
        self.metrics.counter("pipeline.works_generated").inc();
        true
    }

    /// Record the transform names a request already has — called once
    /// whenever an engine is rebuilt from persisted state, so subsequent
    /// fan-out can deduplicate against the crash window in O(1) per work.
    /// The store scan runs before the lock: holding `recovered_names`
    /// across O(transforms) reads would stall the other daemon's
    /// `add_work_transform` for the duration.
    fn note_recovered(&self, request_id: Id) {
        let names: HashSet<String> = self
            .store
            .transforms_of_request(request_id)
            .into_iter()
            .filter_map(|tid| self.store.get_transform(tid).ok().map(|t| t.name))
            .collect();
        self.recovered_names.lock().unwrap().entry(request_id).or_insert(names);
    }

    /// Resume a persisted engine and clamp it against the transforms
    /// already in the store (see `Engine::clamp_to_materialized`).
    fn resume_engine(
        &self,
        request_id: Id,
        compiled: std::sync::Arc<crate::workflow::CompiledWorkflow>,
        state: &Json,
    ) -> WfEngine {
        let mut e = WfEngine::resume(compiled, state);
        e.clamp_to_materialized(self.store.transforms_of_request(request_id).into_iter().filter_map(
            |tid| Work::from_json(&self.store.get_transform(tid).ok()?.work).ok(),
        ));
        e
    }

    /// Run `f` against the live engine for `request_id`, lazily rebuilding
    /// it after a restart: the request's workflow definition is re-interned
    /// through the global [`WorkflowRegistry`] and the persisted engine
    /// state resumed (or, for snapshots predating engine state, counters
    /// are reconciled from the request's transforms, treating terminal
    /// Works as already marshalled so fan-out cannot duplicate). Returns
    /// `None` when the request row is gone, its workflow no longer
    /// compiles, or the request is already terminal — a finalized request
    /// can never legitimately produce new works, so the Marshaller's
    /// post-restart re-walk of its transforms costs one row read per
    /// transform instead of a parse + engine rebuild.
    fn with_engine<T>(&self, request_id: Id, f: impl FnOnce(&mut WfEngine) -> T) -> Option<T> {
        {
            let mut engines = self.engines.lock().unwrap();
            if let Some(e) = engines.get_mut(&request_id) {
                return Some(f(e));
            }
        }
        let req = self.store.get_request(request_id).ok()?;
        if req.status.is_terminal() {
            return None;
        }
        let (compiled, hit) = match WorkflowRegistry::global().intern_json(&req.workflow) {
            Ok(r) => r,
            Err(e) => {
                log::warn!("cannot re-intern workflow of request {request_id}: {e}");
                return None;
            }
        };
        self.count_registry(hit);
        let engine = if req.engine.is_null() {
            let mut e = WfEngine::from_compiled(compiled);
            let works = self.store.transforms_of_request(request_id).into_iter().filter_map(
                |tid| {
                    let tf = self.store.get_transform(tid).ok()?;
                    let w = Work::from_json(&tf.work).ok()?;
                    Some((w, tf.status.is_terminal()))
                },
            );
            e.reconcile(works);
            e
        } else {
            self.resume_engine(request_id, compiled, &req.engine)
        };
        // arm crash-window dedupe before the engine can fire anything
        self.note_recovered(request_id);
        let mut engines = self.engines.lock().unwrap();
        Some(f(engines.entry(request_id).or_insert(engine)))
    }

    /// Persist a drained engine-state update: the full state rewrites the
    /// row (`RequestEngine` in the WAL); a delta folds into the row and
    /// logs only the compact `RequestEngineDelta` — closing the "full
    /// state per completion" write amplification on wide workflows.
    fn write_engine_update(&self, request_id: Id, update: Option<StateUpdate>) {
        match update {
            Some(StateUpdate::Full(state)) => {
                let _ = self.store.set_request_engine(request_id, state);
            }
            Some(StateUpdate::Delta(delta)) => {
                let _ = self.store.apply_engine_delta(request_id, delta);
            }
            None => {}
        }
    }

    fn count_registry(&self, hit: bool) {
        self.metrics
            .counter(if hit { "workflow.registry.hits" } else { "workflow.registry.misses" })
            .inc();
    }
}

// ---------------------------------------------------------------------------

/// Clerk: request intake + finalization.
pub struct Clerk {
    pub(crate) p: Pipeline,
    skips: Arc<Counter>,
    seen_requests: Seen,
    seen_transforms: Seen,
    seen_epoch: Seen,
}

impl Daemon for Clerk {
    fn name(&self) -> &'static str {
        "clerk"
    }

    fn poll_once(&self) -> usize {
        super::traced_tick(&self.p.metrics, "clerk", || self.tick())
    }

    // T_REQUESTS also covers the marshal epoch: `mark_marshalled`
    // re-broadcasts its bump as a synthetic requests signal
    fn interests(&self) -> u32 {
        T_REQUESTS | T_TRANSFORMS
    }
}

impl Clerk {
    fn tick(&self) -> usize {
        let rg = self.p.store.requests_generation();
        let tg = self.p.store.transforms_generation();
        let me = self.p.marshal_epoch.load(Ordering::Acquire);
        // bitwise &, not &&: all three snapshots must be recorded even
        // when an earlier one already differs
        if self.seen_requests.unchanged(rg)
            & self.seen_transforms.unchanged(tg)
            & self.seen_epoch.unchanged(me)
        {
            self.skips.inc();
            return 0;
        }
        let mut n = 0;
        // intake
        let mut to_transforming: Vec<Id> = Vec::new();
        let mut to_failed: Vec<Id> = Vec::new();
        for req_id in self
            .p
            .store
            .requests_with_status_limit(RequestStatus::New, self.p.batch)
        {
            n += 1;
            let Ok(req) = self.p.store.get_request(req_id) else { continue };
            // Stitch across the REST boundary: the submit handler tagged
            // this request id with its request-span context, so intake
            // joins the submitter's trace; untagged requests (recovered
            // after restart, direct store writes) parent to the tick span.
            let mut req_sp = match crate::obs::take_tag(req_id) {
                Some(ctx) => crate::obs::span_with_parent("daemon.clerk.request", ctx),
                None => crate::obs::span("daemon.clerk.request"),
            };
            req_sp.attr("request_id", req_id);
            // resolve to the shared compiled workflow — no per-request
            // Workflow clone; a campaign re-submitting one shape is all
            // registry hits after the first request
            match WorkflowRegistry::global().intern_json(&req.workflow) {
                Ok((compiled, hit)) => {
                    self.p.count_registry(hit);
                    // A crash between a previous intake's writes and its
                    // status batch re-intakes the request as New. If engine
                    // state was persisted, start() already ran (the state
                    // is written only after the entry transforms) — resume
                    // it rather than clobbering any marshal progress and
                    // minting duplicate entry iterations.
                    let mut engine = if req.engine.is_null() {
                        WfEngine::from_compiled(compiled)
                    } else {
                        self.p.resume_engine(req_id, compiled, &req.engine)
                    };
                    let works =
                        if engine.was_recovered() { Vec::new() } else { engine.start() };
                    if engine.was_recovered()
                        || !self.p.store.transforms_of_request(req_id).is_empty()
                    {
                        // re-intake: arm crash-window dedupe
                        self.p.note_recovered(req_id);
                    }
                    for w in &works {
                        let kind =
                            engine.template(&w.template).map(|t| t.kind).unwrap_or(WorkKind::Noop);
                        self.p.add_work_transform(req_id, w, kind);
                    }
                    if !engine.was_recovered() {
                        // transforms first, engine state second: a crash in
                        // between re-fires on restart and dedupes by name,
                        // while the opposite order would lose the works. A
                        // fresh engine's first write is always the full
                        // state (its row has no base to fold a delta onto).
                        self.p.write_engine_update(req_id, engine.take_state_update());
                    }
                    // or_insert: a Marshaller racing this re-intake may
                    // already have rebuilt (and advanced) the engine —
                    // never clobber it with a stale one
                    self.p.engines.lock().unwrap().entry(req_id).or_insert(engine);
                    to_transforming.push(req_id);
                }
                Err(e) => {
                    log::warn!("clerk: request {req_id} invalid workflow: {e}");
                    to_failed.push(req_id);
                }
            }
        }
        self.p
            .store
            .update_requests_status(&to_transforming, RequestStatus::Transforming);
        self.p
            .store
            .update_requests_status(&to_failed, RequestStatus::Failed);
        // finalization
        let mut finish: Vec<Id> = Vec::new();
        let mut subfinish: Vec<Id> = Vec::new();
        let mut fail: Vec<Id> = Vec::new();
        for req_id in self
            .p
            .store
            .requests_with_status_limit(RequestStatus::Transforming, self.p.batch)
        {
            let tfs = self.p.store.transforms_of_request(req_id);
            if tfs.is_empty() {
                continue;
            }
            let marshalled = self.p.marshalled.lock().unwrap();
            let mut all_done = true;
            let mut any_failed = false;
            let mut all_failed = true;
            for tf_id in &tfs {
                let Ok(tf) = self.p.store.get_transform(*tf_id) else { continue };
                if !tf.status.is_terminal() || !marshalled.contains(tf_id) {
                    all_done = false;
                    break;
                }
                match tf.status {
                    TransformStatus::Failed | TransformStatus::Cancelled => any_failed = true,
                    _ => all_failed = false,
                }
            }
            drop(marshalled);
            if all_done {
                if !any_failed {
                    finish.push(req_id);
                } else if all_failed {
                    fail.push(req_id);
                } else {
                    subfinish.push(req_id);
                }
            }
        }
        for (ids, to) in [
            (&finish, RequestStatus::Finished),
            (&subfinish, RequestStatus::SubFinished),
            (&fail, RequestStatus::Failed),
        ] {
            if ids.is_empty() {
                continue;
            }
            let moved = self.p.store.update_requests_status(ids, to);
            if moved > 0 {
                let mut engines = self.p.engines.lock().unwrap();
                let mut recovered = self.p.recovered_names.lock().unwrap();
                for id in ids.iter() {
                    engines.remove(id);
                    recovered.remove(id);
                }
                self.p
                    .metrics
                    .counter("pipeline.requests_finalized")
                    .add(moved as u64);
                n += moved;
            }
        }
        n
    }
}

// ---------------------------------------------------------------------------

/// Marshaller: DG evaluation on terminal transforms.
pub struct Marshaller {
    pub(crate) p: Pipeline,
    skips: Arc<Counter>,
    seen_transforms: Seen,
}

impl Daemon for Marshaller {
    fn name(&self) -> &'static str {
        "marshaller"
    }

    fn poll_once(&self) -> usize {
        super::traced_tick(&self.p.metrics, "marshaller", || self.tick())
    }

    fn interests(&self) -> u32 {
        T_TRANSFORMS
    }
}

impl Marshaller {
    fn tick(&self) -> usize {
        if self
            .seen_transforms
            .unchanged(self.p.store.transforms_generation())
        {
            self.skips.inc();
            return 0;
        }
        let mut n = 0;
        for status in [TransformStatus::Finished, TransformStatus::Failed] {
            // full fetch, not _limit: marshalled transforms stay terminal
            // forever, so a fixed id window would starve later arrivals —
            // the `marshalled` filter is the real cursor here
            for tf_id in self.p.store.transforms_with_status(status) {
                if self.p.marshalled.lock().unwrap().contains(&tf_id) {
                    continue;
                }
                let Ok(tf) = self.p.store.get_transform(tf_id) else { continue };
                let work = match Work::from_json(&tf.work) {
                    Ok(w) => w,
                    Err(e) => {
                        log::warn!("marshaller: transform {tf_id} bad work json: {e}");
                        self.p.mark_marshalled(tf_id);
                        continue;
                    }
                };
                let result = tf.work.get("result").cloned().unwrap_or_else(Json::obj);
                // only successful works fire condition branches; the
                // completed-instance set makes the walk idempotent, so a
                // restart re-visiting terminal transforms is a no-op
                let (new_works, new_state) = self
                    .p
                    .with_engine(tf.request_id, |engine| {
                        if engine.already_completed(work.instance) {
                            return (Vec::new(), None);
                        }
                        let tagged: Vec<(Work, WorkKind)> = if status
                            == TransformStatus::Finished
                        {
                            self.p
                                .metrics
                                .counter("workflow.engine.condition_evals")
                                .add(engine.out_degree(&work.template) as u64);
                            let fired = {
                                let mut wf_sp = crate::obs::span("workflow.on_complete");
                                wf_sp.attr("template", work.template.as_str());
                                engine.on_complete(&work, &result)
                            };
                            match fired {
                                Ok(ws) => ws
                                    .into_iter()
                                    .map(|w| {
                                        let kind = engine
                                            .template(&w.template)
                                            .map(|t| t.kind)
                                            .unwrap_or(WorkKind::Noop);
                                        (w, kind)
                                    })
                                    .collect(),
                                Err(e) => {
                                    log::warn!("marshaller: condition eval failed: {e}");
                                    // the result is immutable, so the error
                                    // is permanent — count the instance as
                                    // complete so the floor advances and a
                                    // restart stops re-evaluating a dead
                                    // branch
                                    engine.mark_complete(work.instance);
                                    Vec::new()
                                }
                            }
                        } else {
                            // failed works never fire conditions, but their
                            // instances must still count as completed so
                            // the completion floor can advance past them
                            engine.mark_complete(work.instance);
                            Vec::new()
                        };
                        // drain the compact delta (or the full state right
                        // after a rebuild) instead of serializing the whole
                        // engine per completion
                        (tagged, engine.take_state_update())
                    })
                    .unwrap_or((Vec::new(), None));
                if !new_works.is_empty() {
                    self.p
                        .metrics
                        .counter("workflow.engine.edges_fired")
                        .add(new_works.len() as u64);
                }
                for (w, kind) in &new_works {
                    self.p.add_work_transform(tf.request_id, w, *kind);
                }
                // transforms before state — see the Clerk's ordering note
                self.p.write_engine_update(tf.request_id, new_state);
                self.p.mark_marshalled(tf_id);
                self.p.metrics.counter("pipeline.transforms_marshalled").inc();
                n += 1;
                if n >= self.p.batch {
                    // leftovers remain but marshalling itself may not have
                    // written to the store — force the next tick to run
                    self.seen_transforms.rearm();
                    return n;
                }
            }
        }
        n
    }
}

// ---------------------------------------------------------------------------

/// Transformer: attach collections, create processings.
pub struct Transformer {
    pub(crate) p: Pipeline,
    skips: Arc<Counter>,
    seen_transforms: Seen,
}

impl Daemon for Transformer {
    fn name(&self) -> &'static str {
        "transformer"
    }

    fn poll_once(&self) -> usize {
        super::traced_tick(&self.p.metrics, "transformer", || self.tick())
    }

    fn interests(&self) -> u32 {
        T_TRANSFORMS
    }
}

impl Transformer {
    fn tick(&self) -> usize {
        if self
            .seen_transforms
            .unchanged(self.p.store.transforms_generation())
        {
            self.skips.inc();
            return 0;
        }
        let mut activated: Vec<Id> = Vec::new();
        for tf_id in self
            .p
            .store
            .transforms_with_status_limit(TransformStatus::New, self.p.batch)
        {
            let Ok(tf) = self.p.store.get_transform(tf_id) else { continue };
            // input collection from params.input_files (name:size pairs), if any
            let in_coll = self.p.store.add_collection(
                tf_id,
                &format!("{}.input", tf.name),
                CollectionKind::Input,
            );
            let files = tf.work.get_path(&["params", "input_files"]).and_then(|f| f.as_arr());
            if let Some(files) = files {
                let items: Vec<(String, u64)> = files
                    .iter()
                    .filter_map(|f| {
                        Some((
                            f.get("name")?.as_str()?.to_string(),
                            f.get("size")?.as_u64().unwrap_or(0),
                        ))
                    })
                    .collect();
                self.p.store.add_contents(in_coll, items);
            }
            self.p.store.add_collection(
                tf_id,
                &format!("{}.output", tf.name),
                CollectionKind::Output,
            );
            self.p.store.add_processing(tf_id);
            activated.push(tf_id);
        }
        if activated.is_empty() {
            return 0;
        }
        self.p
            .store
            .update_transforms_status(&activated, TransformStatus::Activated);
        self.p
            .store
            .update_transforms_status(&activated, TransformStatus::Running);
        self.p
            .metrics
            .counter("pipeline.transforms_activated")
            .add(activated.len() as u64);
        activated.len()
    }
}

// ---------------------------------------------------------------------------

/// Carrier: submit processings to executors and poll them.
pub struct Carrier {
    pub(crate) p: Pipeline,
    skips: Arc<Counter>,
    seen_processings: Seen,
}

impl Daemon for Carrier {
    fn name(&self) -> &'static str {
        "carrier"
    }

    fn poll_once(&self) -> usize {
        super::traced_tick(&self.p.metrics, "carrier", || self.tick())
    }

    fn interests(&self) -> u32 {
        T_PROCESSINGS
    }

    // executor completions never cross the bus: while anything is in
    // flight the Carrier keeps the short poll interval instead of the
    // fallback heartbeat
    fn busy_poll(&self) -> bool {
        !self
            .p
            .store
            .processings_with_status_limit(ProcessingStatus::Submitted, 1)
            .is_empty()
            || !self
                .p
                .store
                .processings_with_status_limit(ProcessingStatus::Running, 1)
                .is_empty()
    }
}

impl Carrier {
    fn tick(&self) -> usize {
        // submit stage: driven purely by store state, so it is gated
        let mut n = 0;
        if self
            .seen_processings
            .unchanged(self.p.store.processings_generation())
        {
            self.skips.inc();
        } else {
            n += self.submit_new();
        }
        // polling stage: executor completion is not a store event, so this
        // must run every tick (cheap when the Submitted/Running sets are
        // empty)
        n + self.poll_running()
    }

    fn submit_new(&self) -> usize {
        let store = &self.p.store;
        let mut items: Vec<(Id, Id, Json)> = Vec::new(); // (pid, transform_id, work)
        for pid in store.processings_with_status_limit(ProcessingStatus::New, self.p.batch) {
            let Ok(proc) = store.get_processing(pid) else { continue };
            let Ok(tf) = store.get_transform(proc.transform_id) else { continue };
            items.push((pid, proc.transform_id, tf.work));
        }
        if items.is_empty() {
            return 0;
        }
        let pids: Vec<Id> = items.iter().map(|(pid, _, _)| *pid).collect();
        store.update_processings_status(&pids, ProcessingStatus::Submitting);
        let mut submitted: Vec<Id> = Vec::new();
        let mut failed: Vec<Id> = Vec::new();
        let mut failed_tfs: Vec<Id> = Vec::new();
        for (pid, tf_id, work) in &items {
            let kind = work.get("kind").and_then(|k| k.as_str()).unwrap_or("Noop");
            let Some(exec) = self.p.executors.get(kind) else {
                log::warn!("carrier: no executor for kind '{kind}'");
                failed.push(*pid);
                failed_tfs.push(*tf_id);
                continue;
            };
            match exec.submit(work) {
                Ok(handle) => {
                    let _ = store.set_processing_wfm_task(*pid, handle);
                    submitted.push(*pid);
                }
                Err(e) => {
                    log::warn!("carrier: submit failed: {e}");
                    failed.push(*pid);
                    failed_tfs.push(*tf_id);
                }
            }
        }
        let moved = store.update_processings_status(&submitted, ProcessingStatus::Submitted);
        if moved > 0 {
            self.p
                .metrics
                .counter("pipeline.processings_submitted")
                .add(moved as u64);
        }
        store.update_processings_status(&failed, ProcessingStatus::Failed);
        store.update_transforms_status(&failed_tfs, TransformStatus::Failed);
        items.len()
    }

    fn poll_running(&self) -> usize {
        let store = &self.p.store;
        // gather in-flight processings grouped by executor kind so each
        // backend is polled once per tick via poll_many
        struct InFlight {
            pid: Id,
            tf_id: Id,
            request_id: Id,
            tf_name: String,
            handle: u64,
            work: Json,
            was_submitted: bool,
        }
        let mut by_kind: HashMap<String, Vec<InFlight>> = HashMap::new();
        for status in [ProcessingStatus::Submitted, ProcessingStatus::Running] {
            for pid in store.processings_with_status(status) {
                let Ok(proc) = store.get_processing(pid) else { continue };
                let Ok(tf) = store.get_transform(proc.transform_id) else { continue };
                let Some(handle) = proc.wfm_task else { continue };
                let kind = tf
                    .work
                    .get("kind")
                    .and_then(|k| k.as_str())
                    .unwrap_or("Noop")
                    .to_string();
                by_kind.entry(kind).or_default().push(InFlight {
                    pid,
                    tf_id: proc.transform_id,
                    request_id: tf.request_id,
                    tf_name: tf.name,
                    handle,
                    work: tf.work,
                    was_submitted: status == ProcessingStatus::Submitted,
                });
            }
        }
        if by_kind.is_empty() {
            return 0;
        }
        let mut n = 0;
        let mut still_running: Vec<Id> = Vec::new();
        let mut fin_pids: Vec<Id> = Vec::new();
        let mut fail_pids: Vec<Id> = Vec::new();
        let mut fin_tfs: Vec<Id> = Vec::new();
        let mut fail_tfs: Vec<Id> = Vec::new();
        for (kind, items) in by_kind {
            let Some(exec) = self.p.executors.get(&kind) else { continue };
            let handles: Vec<u64> = items.iter().map(|i| i.handle).collect();
            // match results by handle key, not position — the trait does
            // not promise input ordering
            let mut results: HashMap<u64, anyhow::Result<Option<Json>>> =
                exec.poll_many(&handles).into_iter().collect();
            for item in items {
                let Some(res) = results.remove(&item.handle) else { continue };
                match res {
                    Ok(None) => {
                        if item.was_submitted {
                            still_running.push(item.pid);
                        }
                    }
                    Ok(Some(result)) => {
                        let failed = !result.get("error").map(Json::is_null).unwrap_or(true);
                        // raw transforms (tests, foreign writers) may carry a
                        // non-object work payload; Json::set would panic on it
                        let base =
                            if item.work.as_obj().is_some() { item.work } else { Json::obj() };
                        let work = base.set("result", result.clone());
                        let _ = store.update_transform_work(item.tf_id, work);
                        if failed {
                            fail_pids.push(item.pid);
                            fail_tfs.push(item.tf_id);
                        } else {
                            fin_pids.push(item.pid);
                            fin_tfs.push(item.tf_id);
                        }
                        // queue a Conductor message (output availability)
                        store.add_message(
                            "idds.work.finished",
                            Some(item.tf_id),
                            Json::obj()
                                .set("request_id", item.request_id)
                                .set("transform_id", item.tf_id)
                                .set("template", item.tf_name.as_str())
                                .set("failed", failed)
                                .set("result", result),
                        );
                        n += 1;
                    }
                    Err(e) => {
                        log::warn!("carrier: poll failed: {e}");
                    }
                }
            }
        }
        store.update_processings_status(&still_running, ProcessingStatus::Running);
        store.update_processings_status(&fin_pids, ProcessingStatus::Finished);
        store.update_processings_status(&fail_pids, ProcessingStatus::Failed);
        store.update_transforms_status(&fin_tfs, TransformStatus::Finished);
        store.update_transforms_status(&fail_tfs, TransformStatus::Failed);
        if n > 0 {
            self.p
                .metrics
                .counter("pipeline.processings_finished")
                .add(n as u64);
        }
        n
    }
}

// ---------------------------------------------------------------------------

/// Conductor: deliver availability notifications to consumers.
pub struct Conductor {
    pub(crate) p: Pipeline,
    skips: Arc<Counter>,
    seen_messages: Seen,
}

impl Daemon for Conductor {
    fn name(&self) -> &'static str {
        "conductor"
    }

    fn poll_once(&self) -> usize {
        super::traced_tick(&self.p.metrics, "conductor", || self.tick())
    }

    fn interests(&self) -> u32 {
        T_MESSAGES
    }
}

impl Conductor {
    fn tick(&self) -> usize {
        if self
            .seen_messages
            .unchanged(self.p.store.messages_generation())
        {
            self.skips.inc();
            return 0;
        }
        let msgs = self.p.store.claim_messages(self.p.batch);
        if msgs.is_empty() {
            return 0;
        }
        let n = msgs.len();
        // group by topic so the broker mutex is taken once per topic per
        // tick (in practice one topic), not once per message; the claimed
        // records are consumed, so payloads move instead of deep-cloning
        let mut by_topic: HashMap<String, Vec<Json>> = HashMap::new();
        for msg in msgs {
            by_topic.entry(msg.topic).or_default().push(msg.payload);
        }
        for (topic, payloads) in by_topic {
            self.p.broker.publish_many(&topic, payloads);
        }
        self.p
            .metrics
            .counter("pipeline.messages_delivered")
            .add(n as u64);
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::daemons::executors::NoopExecutor;
    use crate::daemons::pump;
    use crate::store::RequestKind;
    use crate::util::clock::WallClock;
    use crate::workflow::{Condition, Predicate, WorkKind, WorkTemplate, Workflow};

    fn pipeline() -> Pipeline {
        let clock = Arc::new(WallClock::new());
        Pipeline::new(
            Store::new(clock.clone()),
            Broker::new(clock),
            Registry::default(),
            ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default())),
        )
    }

    fn run_all(p: &Pipeline) -> usize {
        let (clerk, marsh, tfr, carrier, conductor) = p.daemons();
        pump(&[&clerk, &marsh, &tfr, &carrier, &conductor], 1000)
    }

    #[test]
    fn linear_workflow_runs_to_finished() {
        let p = pipeline();
        let wf = Workflow::new("lin")
            .add_template(WorkTemplate::new("a"))
            .add_template(WorkTemplate::new("b"))
            .add_condition(Condition::always("a", "b"))
            .entry("a");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        run_all(&p);
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Finished
        );
        let tfs = p.store.transforms_of_request(req);
        assert_eq!(tfs.len(), 2, "a then b");
        for tf in tfs {
            assert_eq!(
                p.store.get_transform(tf).unwrap().status,
                TransformStatus::Finished
            );
        }
    }

    #[test]
    fn conditional_branch_skipped_when_false() {
        let p = pipeline();
        let wf = Workflow::new("gate")
            .add_template(
                WorkTemplate::new("a").default(
                    "result",
                    Json::obj().set("loss", 0.9),
                ),
            )
            .add_template(WorkTemplate::new("good"))
            .add_template(WorkTemplate::new("bad"))
            .add_condition(Condition::when("a", "good", Predicate::lt("loss", 0.5)))
            .add_condition(Condition::when("a", "bad", Predicate::gt("loss", 0.5)))
            .entry("a");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        run_all(&p);
        let names: Vec<String> = p
            .store
            .transforms_of_request(req)
            .into_iter()
            .map(|t| p.store.get_transform(t).unwrap().name)
            .collect();
        assert!(names.iter().any(|n| n.starts_with("bad")), "{names:?}");
        assert!(!names.iter().any(|n| n.starts_with("good")), "{names:?}");
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Finished
        );
    }

    #[test]
    fn cyclic_workflow_terminates_at_cap() {
        let p = pipeline();
        let wf = Workflow::new("cyc")
            .add_template(WorkTemplate::new("a").max_instances(4))
            .add_condition(Condition::always("a", "a"))
            .entry("a");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        run_all(&p);
        assert_eq!(p.store.transforms_of_request(req).len(), 4);
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Finished
        );
    }

    #[test]
    fn pending_condition_fires_on_fresh_pipeline_after_restart() {
        let p = pipeline();
        let wf = Workflow::new("lin")
            .add_template(WorkTemplate::new("a"))
            .add_template(WorkTemplate::new("b"))
            .add_condition(Condition::always("a", "b"))
            .entry("a");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        {
            // no Marshaller: 'a' terminates but its condition stays pending
            let (clerk, _marsh, tfr, carrier, conductor) = p.daemons();
            pump(&[&clerk, &tfr, &carrier, &conductor], 1000);
        }
        assert_eq!(p.store.transforms_of_request(req).len(), 1);
        assert!(
            !p.store.get_request(req).unwrap().engine.is_null(),
            "the Clerk must persist engine state"
        );

        // "restart": a fresh pipeline over the same store starts with an
        // empty engines map and must resume from the persisted state
        let p2 = Pipeline::new(
            p.store.clone(),
            p.broker.clone(),
            Registry::default(),
            ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default())),
        );
        run_all(&p2);
        assert_eq!(
            p.store.transforms_of_request(req).len(),
            2,
            "the pending condition must fire after the restart"
        );
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Finished
        );
    }

    #[test]
    fn clerk_reintake_resumes_state_without_duplicate_entries() {
        let p = pipeline();
        let wf = Workflow::new("lin")
            .add_template(WorkTemplate::new("a"))
            .add_template(WorkTemplate::new("b"))
            .add_condition(Condition::always("a", "b"))
            .entry("a");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        // simulate a crashed intake: entry transform + engine state were
        // persisted, but the Transforming status batch never landed, so
        // the request is still New at "restart"
        let (compiled, _) = crate::workflow::WorkflowRegistry::global().intern(&wf).unwrap();
        let mut engine = WfEngine::from_compiled(compiled);
        let works = engine.start();
        assert_eq!(works.len(), 1);
        for w in &works {
            p.add_work_transform(req, w, WorkKind::Noop);
        }
        p.store.set_request_engine(req, engine.state_json()).unwrap();
        assert_eq!(p.store.get_request(req).unwrap().status, RequestStatus::New);

        // a fresh pipeline re-intakes: it must resume the persisted state
        // (no duplicate entry iteration, no clobbered progress)
        let p2 = Pipeline::new(
            p.store.clone(),
            p.broker.clone(),
            Registry::default(),
            ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default())),
        );
        run_all(&p2);
        let names: Vec<String> = p
            .store
            .transforms_of_request(req)
            .into_iter()
            .map(|t| p.store.get_transform(t).unwrap().name)
            .collect();
        assert_eq!(names.len(), 2, "exactly one a and one b: {names:?}");
        assert!(names.contains(&"a#0".to_string()) && names.contains(&"b#0".to_string()));
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Finished
        );
    }

    #[test]
    fn refire_in_marshal_crash_window_is_deduped_not_duplicated() {
        let p = pipeline();
        let wf = Workflow::new("lin3w")
            .add_template(WorkTemplate::new("a"))
            .add_template(WorkTemplate::new("b"))
            .add_template(WorkTemplate::new("c"))
            .add_condition(Condition::always("a", "b"))
            .add_condition(Condition::always("b", "c"))
            .entry("a");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        {
            // run everything except the Marshaller: a#0 finishes, engine
            // state in the store is the Clerk's (a:1, nothing completed)
            let (clerk, _marsh, tfr, carrier, conductor) = p.daemons();
            pump(&[&clerk, &tfr, &carrier, &conductor], 1000);
        }
        // emulate a marshal of a#0 that crashed AFTER materializing b#0
        // but BEFORE its set_request_engine write landed
        let a_tf = p.store.transforms_of_request(req)[0];
        let a_work = Work::from_json(&p.store.get_transform(a_tf).unwrap().work).unwrap();
        let state = p.store.get_request(req).unwrap().engine;
        let (compiled, _) = crate::workflow::WorkflowRegistry::global().intern(&wf).unwrap();
        let mut pre_crash = WfEngine::resume(compiled, &state);
        let fired = pre_crash.on_complete(&a_work, &Json::obj()).unwrap();
        assert_eq!(fired.len(), 1);
        p.add_work_transform(req, &fired[0], WorkKind::Noop);
        // (no set_request_engine: the state now lags transform b#0)

        // restart: the re-fire of a -> b must reproduce the name b#0 and
        // be suppressed, not mint b#1
        let p2 = Pipeline::new(
            p.store.clone(),
            p.broker.clone(),
            Registry::default(),
            ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default())),
        );
        run_all(&p2);
        let mut names: Vec<String> = p
            .store
            .transforms_of_request(req)
            .into_iter()
            .map(|t| p.store.get_transform(t).unwrap().name)
            .collect();
        names.sort();
        assert_eq!(names, vec!["a#0", "b#0", "c#0"], "no duplicate fan-out");
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Finished
        );
    }

    #[test]
    fn remarshalling_after_restart_does_not_duplicate_works() {
        let p = pipeline();
        let wf = Workflow::new("lin3")
            .add_template(WorkTemplate::new("a"))
            .add_template(WorkTemplate::new("b"))
            .add_template(WorkTemplate::new("c"))
            .add_condition(Condition::always("a", "b"))
            .add_condition(Condition::always("b", "c"))
            .entry("a");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        run_all(&p);
        assert_eq!(p.store.transforms_of_request(req).len(), 3);

        // a fresh pipeline re-walks the terminal transforms (its
        // marshalled set is empty); the persisted completed-instance set
        // must make that walk a no-op
        let p2 = Pipeline::new(
            p.store.clone(),
            p.broker.clone(),
            Registry::default(),
            ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default())),
        );
        run_all(&p2);
        assert_eq!(
            p.store.transforms_of_request(req).len(),
            3,
            "re-marshalling must not duplicate fan-out"
        );
    }

    #[test]
    fn conductor_publishes_to_broker() {
        let p = pipeline();
        let sub = p.broker.subscribe("idds.work.finished");
        let wf = Workflow::new("one")
            .add_template(WorkTemplate::new("a"))
            .entry("a");
        p.store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        run_all(&p);
        let msgs = p.broker.poll(sub, 10);
        assert_eq!(msgs.len(), 1);
        assert_eq!(
            msgs[0].payload.get("failed").unwrap().as_bool(),
            Some(false)
        );
    }

    #[test]
    fn missing_executor_fails_request() {
        let clock = Arc::new(WallClock::new());
        let p = Pipeline::new(
            Store::new(clock.clone()),
            Broker::new(clock),
            Registry::default(),
            ExecutorSet::default(), // no executors at all
        );
        let wf = Workflow::new("one")
            .add_template(WorkTemplate::new("a"))
            .entry("a");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        let (clerk, marsh, tfr, carrier, conductor) = p.daemons();
        pump(&[&clerk, &marsh, &tfr, &carrier, &conductor], 1000);
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Failed
        );
    }

    #[test]
    fn invalid_workflow_fails_at_clerk() {
        let p = pipeline();
        let req = p.store.add_request(
            "r",
            "u",
            RequestKind::Workflow,
            Json::obj().set("name", "x"), // no entries
        );
        run_all(&p);
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Failed
        );
    }

    #[test]
    fn transformer_registers_input_contents() {
        let p = pipeline();
        let wf = Workflow::new("data")
            .add_template(WorkTemplate::new("proc").default(
                "input_files",
                Json::Arr(vec![
                    Json::obj().set("name", "f1").set("size", 100u64),
                    Json::obj().set("name", "f2").set("size", 200u64),
                ]),
            ))
            .entry("proc");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        run_all(&p);
        let tfs = p.store.transforms_of_request(req);
        let colls = p.store.collections_of_transform(tfs[0]);
        assert_eq!(colls.len(), 2);
        let input = colls
            .iter()
            .find(|c| c.kind == CollectionKind::Input)
            .unwrap();
        assert_eq!(p.store.contents_of_collection(input.id).len(), 2);
    }

    fn bus_pipeline() -> (Pipeline, crate::persist::bus::EventBus) {
        let clock = Arc::new(WallClock::new());
        let store = Store::new(clock.clone());
        let metrics = Registry::default();
        let bus = crate::persist::bus::EventBus::new(&metrics);
        // no data dir in unit tests: the BusPersister publishes at apply
        // time, the same hook the WAL flusher uses after group commit
        assert!(store.set_persister(Arc::new(crate::persist::bus::BusPersister::new(bus.clone()))));
        let p = Pipeline::new(
            store,
            Broker::new(clock),
            metrics,
            ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default())),
        )
        .with_bus(bus.clone());
        (p, bus)
    }

    fn host_daemons(p: &Pipeline) -> Vec<Arc<dyn crate::daemons::Daemon>> {
        let (clerk, marsh, tfr, carrier, conductor) = p.daemons();
        vec![
            Arc::new(clerk),
            Arc::new(marsh),
            Arc::new(tfr),
            Arc::new(carrier),
            Arc::new(conductor),
        ]
    }

    #[test]
    fn bus_wakeups_finish_a_request_well_before_the_heartbeat() {
        let (p, bus) = bus_pipeline();
        // heartbeat far beyond the assertion window: if any stage of the
        // clerk→transformer→carrier→finalize chain had to wait for a
        // heartbeat tick, the request could not finish in time — every
        // hand-off must ride a bus wakeup
        let host = crate::daemons::AgentHost::start_with_bus(
            host_daemons(&p),
            std::time::Duration::from_millis(5),
            std::time::Duration::from_secs(60),
            Some(&bus),
        );
        // let every daemon run its unconditional first poll and park in
        // its wait — from here on, only signals (or the 60 s heartbeat)
        // can make progress
        std::thread::sleep(std::time::Duration::from_millis(100));
        let wf = Workflow::new("one").add_template(WorkTemplate::new("a")).entry("a");
        let req = p.store.add_request("r", "u", RequestKind::Workflow, wf.to_json());
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while std::time::Instant::now() < deadline {
            if p.store.get_request(req).unwrap().status == RequestStatus::Finished {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        host.stop();
        assert_eq!(p.store.get_request(req).unwrap().status, RequestStatus::Finished);
        let wakeups: u64 = ["clerk", "marshaller", "transformer", "carrier", "conductor"]
            .iter()
            .map(|d| p.metrics.counter(&format!("pipeline.{d}.wakeups")).get())
            .sum();
        assert!(wakeups > 0, "progress must have come from bus wakeups");
    }

    #[test]
    fn quiescent_daemons_idle_on_the_heartbeat_alone() {
        let (p, bus) = bus_pipeline();
        // short heartbeat, zero traffic: every tick must be a fallback
        // heartbeat (a generation-gated skip), never a bus wakeup
        let host = crate::daemons::AgentHost::start_with_bus(
            host_daemons(&p),
            std::time::Duration::from_millis(5),
            std::time::Duration::from_millis(20),
            Some(&bus),
        );
        std::thread::sleep(std::time::Duration::from_millis(300));
        host.stop();
        let wakeups: u64 = ["clerk", "marshaller", "transformer", "carrier", "conductor"]
            .iter()
            .map(|d| p.metrics.counter(&format!("pipeline.{d}.wakeups")).get())
            .sum();
        assert_eq!(wakeups, 0, "no events were published, so no wakeups");
        let skips: u64 = ["clerk", "marshaller", "transformer", "carrier", "conductor"]
            .iter()
            .map(|d| p.metrics.poll_skip_counter(d).get())
            .sum();
        assert!(skips >= 5, "the fallback heartbeat must still tick: {skips} skips");
    }

    #[test]
    fn change_driven_daemons_skip_quiescent_store() {
        let p = pipeline();
        let wf = Workflow::new("one")
            .add_template(WorkTemplate::new("a"))
            .entry("a");
        p.store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        let (clerk, marsh, tfr, carrier, conductor) = p.daemons();
        let daemons: [&dyn Daemon; 5] = [&clerk, &marsh, &tfr, &carrier, &conductor];
        pump(&daemons, 1000);
        let skips_after_pump: u64 = ["clerk", "marshaller", "transformer", "carrier", "conductor"]
            .iter()
            .map(|d| p.metrics.poll_skip_counter(d).get())
            .sum();
        // quiescent store: every further tick is a generation-gated skip
        for _ in 0..5 {
            for d in &daemons {
                assert_eq!(d.poll_once(), 0);
            }
        }
        let skips_now: u64 = ["clerk", "marshaller", "transformer", "carrier", "conductor"]
            .iter()
            .map(|d| p.metrics.poll_skip_counter(d).get())
            .sum();
        assert!(
            skips_now >= skips_after_pump + 4 * 5,
            "expected gated skips on a quiescent store: {skips_after_pump} -> {skips_now}"
        );
        // new work re-arms the gates
        let req2 = p
            .store
            .add_request("r2", "u", RequestKind::Workflow, wf.to_json());
        pump(&daemons, 1000);
        assert_eq!(
            p.store.get_request(req2).unwrap().status,
            RequestStatus::Finished
        );
    }
}
