//! The five iDDS daemons over the shared store (paper section 2):
//!
//! ```text
//! client → [REST] → Request(New)
//!   Clerk       : Request New → Workflow engine → initial Works
//!                 (transforms) → Request Transforming; finalizes requests
//!                 whose transforms are all terminal + marshalled.
//!   Marshaller  : terminal transforms → evaluate Condition branches →
//!                 generate follow-up Works (DG support, incl. cycles).
//!   Transformer : Transform New → input/output Collections (+Contents) →
//!                 Processing(New) → Transform Activated→Running.
//!   Carrier     : Processing New → submit to executor → poll → Finished;
//!                 writes the Work result and queues a message.
//!   Conductor   : store messages New → broker publish → Delivered.
//! ```
//!
//! All daemon state beyond the store lives in [`Pipeline`] (the per-request
//! workflow engines and the marshalled set) so the daemons stay restartable
//! and the store remains the single source of truth for status.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use crate::broker::Broker;
use crate::metrics::Registry;
use crate::store::{
    CollectionKind, Id, MessageStatus, ProcessingStatus, RequestStatus, Store, TransformStatus,
};
use crate::util::json::Json;
use crate::workflow::{Engine as WfEngine, Work, Workflow};

use super::executors::ExecutorSet;
use super::Daemon;

/// Shared pipeline context for all five daemons.
#[derive(Clone)]
pub struct Pipeline {
    pub store: Store,
    pub broker: Broker,
    pub metrics: Registry,
    pub executors: ExecutorSet,
    /// request id → live workflow engine
    engines: Arc<Mutex<HashMap<Id, WfEngine>>>,
    /// transforms whose conditions the Marshaller has evaluated
    marshalled: Arc<Mutex<HashSet<Id>>>,
    batch: usize,
}

impl Pipeline {
    pub fn new(store: Store, broker: Broker, metrics: Registry, executors: ExecutorSet) -> Self {
        Pipeline {
            store,
            broker,
            metrics,
            executors,
            engines: Arc::new(Mutex::new(HashMap::new())),
            marshalled: Arc::new(Mutex::new(HashSet::new())),
            batch: 256,
        }
    }

    pub fn daemons(&self) -> (Clerk, Marshaller, Transformer, Carrier, Conductor) {
        (
            Clerk { p: self.clone() },
            Marshaller { p: self.clone() },
            Transformer { p: self.clone() },
            Carrier { p: self.clone() },
            Conductor { p: self.clone() },
        )
    }

    fn add_work_transform(&self, request_id: Id, work: &Work) {
        let tf_name = format!("{}#{}", work.template, work.iteration);
        let mut wj = work.to_json();
        // record the kind so the Carrier can dispatch without the engine
        if let Some(tpl) = self
            .engines
            .lock()
            .unwrap()
            .get(&request_id)
            .and_then(|e| e.workflow.templates.get(&work.template))
        {
            wj = wj.set("kind", tpl.kind.as_str());
        }
        self.store.add_transform(request_id, &tf_name, wj);
        self.metrics.counter("pipeline.works_generated").inc();
    }
}

// ---------------------------------------------------------------------------

/// Clerk: request intake + finalization.
pub struct Clerk {
    pub(crate) p: Pipeline,
}

impl Daemon for Clerk {
    fn name(&self) -> &'static str {
        "clerk"
    }

    fn poll_once(&self) -> usize {
        let mut n = 0;
        // intake
        for req_id in self
            .p
            .store
            .requests_with_status(RequestStatus::New)
            .into_iter()
            .take(self.p.batch)
        {
            n += 1;
            let Ok(req) = self.p.store.get_request(req_id) else { continue };
            match Workflow::from_json(&req.workflow).and_then(WfEngine::new) {
                Ok(mut engine) => {
                    let works = engine.start();
                    self.p.engines.lock().unwrap().insert(req_id, engine);
                    for w in &works {
                        self.p.add_work_transform(req_id, w);
                    }
                    let _ = self
                        .p
                        .store
                        .update_request_status(req_id, RequestStatus::Transforming);
                }
                Err(e) => {
                    log::warn!("clerk: request {req_id} invalid workflow: {e}");
                    let _ = self
                        .p
                        .store
                        .update_request_status(req_id, RequestStatus::Failed);
                }
            }
        }
        // finalization
        for req_id in self
            .p
            .store
            .requests_with_status(RequestStatus::Transforming)
            .into_iter()
            .take(self.p.batch)
        {
            let tfs = self.p.store.transforms_of_request(req_id);
            if tfs.is_empty() {
                continue;
            }
            let marshalled = self.p.marshalled.lock().unwrap();
            let mut all_done = true;
            let mut any_failed = false;
            let mut all_failed = true;
            for tf_id in &tfs {
                let Ok(tf) = self.p.store.get_transform(*tf_id) else { continue };
                if !tf.status.is_terminal() || !marshalled.contains(tf_id) {
                    all_done = false;
                    break;
                }
                match tf.status {
                    TransformStatus::Failed | TransformStatus::Cancelled => any_failed = true,
                    _ => all_failed = false,
                }
            }
            drop(marshalled);
            if all_done {
                let to = if !any_failed {
                    RequestStatus::Finished
                } else if all_failed {
                    RequestStatus::Failed
                } else {
                    RequestStatus::SubFinished
                };
                if self.p.store.update_request_status(req_id, to).is_ok() {
                    self.p.engines.lock().unwrap().remove(&req_id);
                    self.p.metrics.counter("pipeline.requests_finalized").inc();
                    n += 1;
                }
            }
        }
        n
    }
}

// ---------------------------------------------------------------------------

/// Marshaller: DG evaluation on terminal transforms.
pub struct Marshaller {
    pub(crate) p: Pipeline,
}

impl Daemon for Marshaller {
    fn name(&self) -> &'static str {
        "marshaller"
    }

    fn poll_once(&self) -> usize {
        let mut n = 0;
        for status in [TransformStatus::Finished, TransformStatus::Failed] {
            for tf_id in self.p.store.transforms_with_status(status) {
                if self.p.marshalled.lock().unwrap().contains(&tf_id) {
                    continue;
                }
                let Ok(tf) = self.p.store.get_transform(tf_id) else { continue };
                let work = match Work::from_json(&tf.work) {
                    Ok(w) => w,
                    Err(e) => {
                        log::warn!("marshaller: transform {tf_id} bad work json: {e}");
                        self.p.marshalled.lock().unwrap().insert(tf_id);
                        continue;
                    }
                };
                let result = tf.work.get("result").cloned().unwrap_or_else(Json::obj);
                // only successful works fire condition branches
                let new_works = if status == TransformStatus::Finished {
                    let mut engines = self.p.engines.lock().unwrap();
                    match engines.get_mut(&tf.request_id) {
                        Some(engine) => match engine.on_complete(&work, &result) {
                            Ok(ws) => ws,
                            Err(e) => {
                                log::warn!("marshaller: condition eval failed: {e}");
                                Vec::new()
                            }
                        },
                        None => Vec::new(),
                    }
                } else {
                    Vec::new()
                };
                for w in &new_works {
                    self.p.add_work_transform(tf.request_id, w);
                }
                self.p.marshalled.lock().unwrap().insert(tf_id);
                self.p.metrics.counter("pipeline.transforms_marshalled").inc();
                n += 1;
                if n >= self.p.batch {
                    return n;
                }
            }
        }
        n
    }
}

// ---------------------------------------------------------------------------

/// Transformer: attach collections, create processings.
pub struct Transformer {
    pub(crate) p: Pipeline,
}

impl Daemon for Transformer {
    fn name(&self) -> &'static str {
        "transformer"
    }

    fn poll_once(&self) -> usize {
        let mut n = 0;
        for tf_id in self
            .p
            .store
            .transforms_with_status(TransformStatus::New)
            .into_iter()
            .take(self.p.batch)
        {
            let Ok(tf) = self.p.store.get_transform(tf_id) else { continue };
            // input collection from params.input_files (name:size pairs), if any
            let in_coll = self.p.store.add_collection(
                tf_id,
                &format!("{}.input", tf.name),
                CollectionKind::Input,
            );
            if let Some(files) = tf.work.get_path(&["params", "input_files"]).and_then(|f| f.as_arr())
            {
                let items: Vec<(String, u64)> = files
                    .iter()
                    .filter_map(|f| {
                        Some((
                            f.get("name")?.as_str()?.to_string(),
                            f.get("size")?.as_u64().unwrap_or(0),
                        ))
                    })
                    .collect();
                self.p.store.add_contents(in_coll, items);
            }
            self.p.store.add_collection(
                tf_id,
                &format!("{}.output", tf.name),
                CollectionKind::Output,
            );
            self.p.store.add_processing(tf_id);
            let _ = self
                .p
                .store
                .update_transform_status(tf_id, TransformStatus::Activated);
            let _ = self
                .p
                .store
                .update_transform_status(tf_id, TransformStatus::Running);
            self.p.metrics.counter("pipeline.transforms_activated").inc();
            n += 1;
        }
        n
    }
}

// ---------------------------------------------------------------------------

/// Carrier: submit processings to executors and poll them.
pub struct Carrier {
    pub(crate) p: Pipeline,
}

impl Daemon for Carrier {
    fn name(&self) -> &'static str {
        "carrier"
    }

    fn poll_once(&self) -> usize {
        let mut n = 0;
        // submit new processings
        for pid in self
            .p
            .store
            .processings_with_status(ProcessingStatus::New)
            .into_iter()
            .take(self.p.batch)
        {
            let Ok(proc) = self.p.store.get_processing(pid) else { continue };
            let Ok(tf) = self.p.store.get_transform(proc.transform_id) else { continue };
            let kind = tf.work.get("kind").and_then(|k| k.as_str()).unwrap_or("Noop");
            let Some(exec) = self.p.executors.get(kind) else {
                log::warn!("carrier: no executor for kind '{kind}'");
                let _ = self
                    .p
                    .store
                    .update_processing_status(pid, ProcessingStatus::Submitting);
                let _ = self
                    .p
                    .store
                    .update_processing_status(pid, ProcessingStatus::Failed);
                let _ = self
                    .p
                    .store
                    .update_transform_status(proc.transform_id, TransformStatus::Failed);
                n += 1;
                continue;
            };
            let _ = self
                .p
                .store
                .update_processing_status(pid, ProcessingStatus::Submitting);
            match exec.submit(&tf.work) {
                Ok(handle) => {
                    let _ = self.p.store.set_processing_wfm_task(pid, handle);
                    let _ = self
                        .p
                        .store
                        .update_processing_status(pid, ProcessingStatus::Submitted);
                    self.p.metrics.counter("pipeline.processings_submitted").inc();
                }
                Err(e) => {
                    log::warn!("carrier: submit failed: {e}");
                    let _ = self
                        .p
                        .store
                        .update_processing_status(pid, ProcessingStatus::Failed);
                    let _ = self
                        .p
                        .store
                        .update_transform_status(proc.transform_id, TransformStatus::Failed);
                }
            }
            n += 1;
        }
        // poll running processings
        for status in [ProcessingStatus::Submitted, ProcessingStatus::Running] {
            for pid in self.p.store.processings_with_status(status) {
                let Ok(proc) = self.p.store.get_processing(pid) else { continue };
                let Ok(tf) = self.p.store.get_transform(proc.transform_id) else { continue };
                let kind = tf.work.get("kind").and_then(|k| k.as_str()).unwrap_or("Noop");
                let Some(exec) = self.p.executors.get(kind) else { continue };
                let Some(handle) = proc.wfm_task else { continue };
                match exec.poll(handle) {
                    Ok(None) => {
                        let _ = self
                            .p
                            .store
                            .update_processing_status(pid, ProcessingStatus::Running);
                    }
                    Ok(Some(result)) => {
                        let failed = !result.get("error").map(Json::is_null).unwrap_or(true);
                        let work = tf.work.clone().set("result", result.clone());
                        let _ = self.p.store.update_transform_work(proc.transform_id, work);
                        let _ = self.p.store.update_processing_status(
                            pid,
                            if failed {
                                ProcessingStatus::Failed
                            } else {
                                ProcessingStatus::Finished
                            },
                        );
                        let _ = self.p.store.update_transform_status(
                            proc.transform_id,
                            if failed {
                                TransformStatus::Failed
                            } else {
                                TransformStatus::Finished
                            },
                        );
                        // queue a Conductor message (output availability)
                        self.p.store.add_message(
                            "idds.work.finished",
                            Some(proc.transform_id),
                            Json::obj()
                                .set("request_id", tf.request_id)
                                .set("transform_id", proc.transform_id)
                                .set("template", tf.name.as_str())
                                .set("failed", failed)
                                .set("result", result),
                        );
                        self.p.metrics.counter("pipeline.processings_finished").inc();
                        n += 1;
                    }
                    Err(e) => {
                        log::warn!("carrier: poll failed: {e}");
                    }
                }
            }
        }
        n
    }
}

// ---------------------------------------------------------------------------

/// Conductor: deliver availability notifications to consumers.
pub struct Conductor {
    pub(crate) p: Pipeline,
}

impl Daemon for Conductor {
    fn name(&self) -> &'static str {
        "conductor"
    }

    fn poll_once(&self) -> usize {
        let mut n = 0;
        for mid in self
            .p
            .store
            .messages_with_status(MessageStatus::New)
            .into_iter()
            .take(self.p.batch)
        {
            let Ok(msg) = self.p.store.get_message(mid) else { continue };
            self.p.broker.publish(&msg.topic, msg.payload.clone());
            let _ = self.p.store.mark_message(mid, MessageStatus::Delivered);
            self.p.metrics.counter("pipeline.messages_delivered").inc();
            n += 1;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::daemons::executors::NoopExecutor;
    use crate::daemons::pump;
    use crate::store::RequestKind;
    use crate::util::clock::WallClock;
    use crate::workflow::{Condition, Predicate, WorkKind, WorkTemplate};

    fn pipeline() -> Pipeline {
        let clock = Arc::new(WallClock::new());
        Pipeline::new(
            Store::new(clock.clone()),
            Broker::new(clock),
            Registry::default(),
            ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default())),
        )
    }

    fn run_all(p: &Pipeline) -> usize {
        let (clerk, marsh, tfr, carrier, conductor) = p.daemons();
        pump(&[&clerk, &marsh, &tfr, &carrier, &conductor], 1000)
    }

    #[test]
    fn linear_workflow_runs_to_finished() {
        let p = pipeline();
        let wf = Workflow::new("lin")
            .add_template(WorkTemplate::new("a"))
            .add_template(WorkTemplate::new("b"))
            .add_condition(Condition::always("a", "b"))
            .entry("a");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        run_all(&p);
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Finished
        );
        let tfs = p.store.transforms_of_request(req);
        assert_eq!(tfs.len(), 2, "a then b");
        for tf in tfs {
            assert_eq!(
                p.store.get_transform(tf).unwrap().status,
                TransformStatus::Finished
            );
        }
    }

    #[test]
    fn conditional_branch_skipped_when_false() {
        let p = pipeline();
        let wf = Workflow::new("gate")
            .add_template(
                WorkTemplate::new("a").default(
                    "result",
                    Json::obj().set("loss", 0.9),
                ),
            )
            .add_template(WorkTemplate::new("good"))
            .add_template(WorkTemplate::new("bad"))
            .add_condition(Condition::when("a", "good", Predicate::lt("loss", 0.5)))
            .add_condition(Condition::when("a", "bad", Predicate::gt("loss", 0.5)))
            .entry("a");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        run_all(&p);
        let names: Vec<String> = p
            .store
            .transforms_of_request(req)
            .into_iter()
            .map(|t| p.store.get_transform(t).unwrap().name)
            .collect();
        assert!(names.iter().any(|n| n.starts_with("bad")), "{names:?}");
        assert!(!names.iter().any(|n| n.starts_with("good")), "{names:?}");
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Finished
        );
    }

    #[test]
    fn cyclic_workflow_terminates_at_cap() {
        let p = pipeline();
        let wf = Workflow::new("cyc")
            .add_template(WorkTemplate::new("a").max_instances(4))
            .add_condition(Condition::always("a", "a"))
            .entry("a");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        run_all(&p);
        assert_eq!(p.store.transforms_of_request(req).len(), 4);
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Finished
        );
    }

    #[test]
    fn conductor_publishes_to_broker() {
        let p = pipeline();
        let sub = p.broker.subscribe("idds.work.finished");
        let wf = Workflow::new("one")
            .add_template(WorkTemplate::new("a"))
            .entry("a");
        p.store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        run_all(&p);
        let msgs = p.broker.poll(sub, 10);
        assert_eq!(msgs.len(), 1);
        assert_eq!(
            msgs[0].payload.get("failed").unwrap().as_bool(),
            Some(false)
        );
    }

    #[test]
    fn missing_executor_fails_request() {
        let clock = Arc::new(WallClock::new());
        let p = Pipeline::new(
            Store::new(clock.clone()),
            Broker::new(clock),
            Registry::default(),
            ExecutorSet::default(), // no executors at all
        );
        let wf = Workflow::new("one")
            .add_template(WorkTemplate::new("a"))
            .entry("a");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        let (clerk, marsh, tfr, carrier, conductor) = p.daemons();
        pump(&[&clerk, &marsh, &tfr, &carrier, &conductor], 1000);
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Failed
        );
    }

    #[test]
    fn invalid_workflow_fails_at_clerk() {
        let p = pipeline();
        let req = p.store.add_request(
            "r",
            "u",
            RequestKind::Workflow,
            Json::obj().set("name", "x"), // no entries
        );
        run_all(&p);
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Failed
        );
    }

    #[test]
    fn transformer_registers_input_contents() {
        let p = pipeline();
        let wf = Workflow::new("data")
            .add_template(WorkTemplate::new("proc").default(
                "input_files",
                Json::Arr(vec![
                    Json::obj().set("name", "f1").set("size", 100u64),
                    Json::obj().set("name", "f2").set("size", 200u64),
                ]),
            ))
            .entry("proc");
        let req = p
            .store
            .add_request("r", "u", RequestKind::Workflow, wf.to_json());
        run_all(&p);
        let tfs = p.store.transforms_of_request(req);
        let colls = p.store.collections_of_transform(tfs[0]);
        assert_eq!(colls.len(), 2);
        let input = colls
            .iter()
            .find(|c| c.kind == CollectionKind::Input)
            .unwrap();
        assert_eq!(p.store.contents_of_collection(input.id).len(), 2);
    }
}
