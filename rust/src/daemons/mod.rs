//! The iDDS daemons (paper section 2, Fig. 1): Clerk, Marshaller,
//! Transformer, Carrier, Conductor.
//!
//! Each daemon implements [`Daemon::poll_once`] — one bounded unit of work
//! against the shared store/broker — so the same code runs in two modes:
//!
//! * **service mode**: [`AgentHost`] polls every daemon on its own thread
//!   at the configured interval (the live head-service deployment);
//! * **stepped mode**: tests and the discrete-event drivers call
//!   [`pump`] to run the daemons to quiescence deterministically.
//!
//! The actual execution of Work payloads is behind the
//! [`executors::Executor`] trait: Noop for orchestration-only Works,
//! the PJRT [`crate::runtime::Engine`] for HPO-training and decision
//! Works, and the WFM/DDM simulators for data-processing Works.

pub mod executors;
pub mod pipeline;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::metrics::Registry;
use crate::obs;
use crate::persist::bus::{EventBus, WakeSignal};

pub use pipeline::{Carrier, Clerk, Conductor, Marshaller, Pipeline, Transformer};

/// One iDDS daemon: a named poll loop.
pub trait Daemon: Send + Sync {
    fn name(&self) -> &'static str;

    /// Process up to one batch; returns how many items made progress.
    fn poll_once(&self) -> usize;

    /// Event-bus tables (a bitmask over `persist::bus::T_*`) whose
    /// mutations can unblock this daemon. The host arms one wake signal
    /// per daemon with this mask; the default subscribes to everything,
    /// which is always safe — just noisier.
    fn interests(&self) -> u32 {
        crate::persist::bus::T_ALL
    }

    /// True while the daemon must keep polling at the short interval even
    /// without bus events — the Carrier watching executor completions,
    /// which are not store mutations and so never reach the bus.
    fn busy_poll(&self) -> bool {
        false
    }
}

/// Instrumentation shared by every daemon's `poll_once`: a
/// `daemon.<name>.tick` span plus a `pipeline.<name>.tick_us` latency
/// histogram, recorded only for *active* ticks — idle polls (generation
/// gate hit, nothing claimed) cancel the span and record nothing, so the
/// trace ring and histograms hold signal instead of a poll-interval
/// heartbeat.
pub(crate) fn traced_tick(metrics: &Registry, name: &str, f: impl FnOnce() -> usize) -> usize {
    let mut sp = if obs::armed() {
        obs::span(&format!("daemon.{name}.tick"))
    } else {
        obs::span("")
    };
    let t0 = std::time::Instant::now();
    let n = f();
    if n == 0 {
        sp.cancel();
        return 0;
    }
    sp.attr("rows", n);
    metrics
        .histogram(&format!("pipeline.{name}.tick_us"))
        .observe(t0.elapsed().as_micros() as u64);
    n
}

/// Run daemons until a full sweep makes no progress (or `max_sweeps`).
/// Returns total progress count. Deterministic given deterministic
/// executors — the backbone of the integration tests.
pub fn pump(daemons: &[&dyn Daemon], max_sweeps: usize) -> usize {
    let mut total = 0;
    for _ in 0..max_sweeps {
        let mut progressed = 0;
        for d in daemons {
            progressed += d.poll_once();
        }
        total += progressed;
        if progressed == 0 {
            return total;
        }
    }
    total
}

/// Threaded host for service mode.
pub struct AgentHost {
    stop: Arc<AtomicBool>,
    signals: Vec<Arc<WakeSignal>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl AgentHost {
    /// Spawn one thread per daemon, polling at `interval` with no event
    /// bus (tests, embedded hosts). Equivalent to the event-driven form
    /// with the heartbeat pinned to the poll interval — the signal is
    /// still armed so [`AgentHost::stop`] interrupts an idle sleep
    /// immediately instead of waiting it out.
    pub fn start(daemons: Vec<Arc<dyn Daemon>>, interval: std::time::Duration) -> AgentHost {
        Self::start_with_bus(daemons, interval, interval, None)
    }

    /// Spawn one thread per daemon, woken by the bus instead of a timer.
    ///
    /// Each daemon idles on a [`WakeSignal`] armed with its
    /// [`Daemon::interests`] mask: a matching publish wakes it at once
    /// (counted in `pipeline.<name>.wakeups`); otherwise it re-polls only
    /// every `heartbeat` — the low-frequency fallback that bounds the
    /// damage of any missed-signal bug. A [`Daemon::busy_poll`] daemon
    /// (the Carrier with work in flight) keeps the short `interval`
    /// instead, since what it waits for never crosses the bus. The epoch
    /// is snapshotted *before* `poll_once`, so a publish landing mid-poll
    /// makes the following wait return immediately — no lost wakeups.
    pub fn start_with_bus(
        daemons: Vec<Arc<dyn Daemon>>,
        interval: std::time::Duration,
        heartbeat: std::time::Duration,
        bus: Option<&EventBus>,
    ) -> AgentHost {
        let stop = Arc::new(AtomicBool::new(false));
        let mut signals = Vec::new();
        let threads: Vec<std::thread::JoinHandle<()>> = daemons
            .into_iter()
            .map(|d| {
                let signal = match bus {
                    Some(b) => b.watch(d.interests()),
                    None => WakeSignal::new(),
                };
                signals.push(Arc::clone(&signal));
                let wakeups =
                    bus.map(|b| b.metrics().counter(&format!("pipeline.{}.wakeups", d.name())));
                let stop = Arc::clone(&stop);
                std::thread::Builder::new()
                    .name(format!("idds-{}", d.name()))
                    .spawn(move || {
                        while !stop.load(Ordering::SeqCst) {
                            let seen = signal.epoch();
                            let n = d.poll_once();
                            if stop.load(Ordering::SeqCst) {
                                break;
                            }
                            if n == 0 {
                                let timeout =
                                    if d.busy_poll() { interval } else { heartbeat };
                                let (_, woke) = signal.wait_past(seen, timeout);
                                if woke && !stop.load(Ordering::SeqCst) {
                                    if let Some(c) = &wakeups {
                                        c.inc();
                                    }
                                }
                            }
                        }
                    })
                    .expect("spawn daemon")
            })
            .collect();
        AgentHost { stop, signals, threads }
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake every idle daemon out of its wait — shutdown latency is
        // one in-flight poll, not a heartbeat
        for s in &self.signals {
            s.notify();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for AgentHost {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    struct CountDown {
        left: AtomicUsize,
    }

    impl Daemon for CountDown {
        fn name(&self) -> &'static str {
            "countdown"
        }
        fn poll_once(&self) -> usize {
            let cur = self.left.load(Ordering::SeqCst);
            if cur == 0 {
                0
            } else {
                self.left.store(cur - 1, Ordering::SeqCst);
                1
            }
        }
    }

    #[test]
    fn pump_runs_to_quiescence() {
        let d = CountDown { left: AtomicUsize::new(5) };
        let total = pump(&[&d], 100);
        assert_eq!(total, 5);
        assert_eq!(d.poll_once(), 0);
    }

    #[test]
    fn pump_respects_max_sweeps() {
        let d = CountDown { left: AtomicUsize::new(1000) };
        let total = pump(&[&d], 3);
        assert_eq!(total, 3);
    }

    #[test]
    fn agent_host_stop_interrupts_idle_sleep() {
        // a drained daemon parked on a 30 s interval must still stop
        // promptly: stop() notifies the wake signals instead of waiting
        // the sleep out
        let d = Arc::new(CountDown { left: AtomicUsize::new(0) });
        let host = AgentHost::start(
            vec![Arc::clone(&d) as Arc<dyn Daemon>],
            std::time::Duration::from_secs(30),
        );
        // let the thread reach its idle wait
        std::thread::sleep(std::time::Duration::from_millis(50));
        let t0 = std::time::Instant::now();
        host.stop();
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(5),
            "stop must not wait out the poll interval: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn agent_host_drains_work() {
        let d = Arc::new(CountDown { left: AtomicUsize::new(20) });
        let host = AgentHost::start(
            vec![Arc::clone(&d) as Arc<dyn Daemon>],
            std::time::Duration::from_millis(1),
        );
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        while d.left.load(Ordering::SeqCst) > 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        host.stop();
        assert_eq!(d.left.load(Ordering::SeqCst), 0);
    }
}
