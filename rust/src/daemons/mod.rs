//! The iDDS daemons (paper section 2, Fig. 1): Clerk, Marshaller,
//! Transformer, Carrier, Conductor.
//!
//! Each daemon implements [`Daemon::poll_once`] — one bounded unit of work
//! against the shared store/broker — so the same code runs in two modes:
//!
//! * **service mode**: [`AgentHost`] polls every daemon on its own thread
//!   at the configured interval (the live head-service deployment);
//! * **stepped mode**: tests and the discrete-event drivers call
//!   [`pump`] to run the daemons to quiescence deterministically.
//!
//! The actual execution of Work payloads is behind the
//! [`executors::Executor`] trait: Noop for orchestration-only Works,
//! the PJRT [`crate::runtime::Engine`] for HPO-training and decision
//! Works, and the WFM/DDM simulators for data-processing Works.

pub mod executors;
pub mod pipeline;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::metrics::Registry;
use crate::obs;

pub use pipeline::{Carrier, Clerk, Conductor, Marshaller, Pipeline, Transformer};

/// One iDDS daemon: a named poll loop.
pub trait Daemon: Send + Sync {
    fn name(&self) -> &'static str;

    /// Process up to one batch; returns how many items made progress.
    fn poll_once(&self) -> usize;
}

/// Instrumentation shared by every daemon's `poll_once`: a
/// `daemon.<name>.tick` span plus a `pipeline.<name>.tick_us` latency
/// histogram, recorded only for *active* ticks — idle polls (generation
/// gate hit, nothing claimed) cancel the span and record nothing, so the
/// trace ring and histograms hold signal instead of a poll-interval
/// heartbeat.
pub(crate) fn traced_tick(metrics: &Registry, name: &str, f: impl FnOnce() -> usize) -> usize {
    let mut sp = if obs::armed() {
        obs::span(&format!("daemon.{name}.tick"))
    } else {
        obs::span("")
    };
    let t0 = std::time::Instant::now();
    let n = f();
    if n == 0 {
        sp.cancel();
        return 0;
    }
    sp.attr("rows", n);
    metrics
        .histogram(&format!("pipeline.{name}.tick_us"))
        .observe(t0.elapsed().as_micros() as u64);
    n
}

/// Run daemons until a full sweep makes no progress (or `max_sweeps`).
/// Returns total progress count. Deterministic given deterministic
/// executors — the backbone of the integration tests.
pub fn pump(daemons: &[&dyn Daemon], max_sweeps: usize) -> usize {
    let mut total = 0;
    for _ in 0..max_sweeps {
        let mut progressed = 0;
        for d in daemons {
            progressed += d.poll_once();
        }
        total += progressed;
        if progressed == 0 {
            return total;
        }
    }
    total
}

/// Threaded host for service mode.
pub struct AgentHost {
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl AgentHost {
    /// Spawn one thread per daemon, polling at `interval`.
    pub fn start(daemons: Vec<Arc<dyn Daemon>>, interval: std::time::Duration) -> AgentHost {
        let stop = Arc::new(AtomicBool::new(false));
        let threads = daemons
            .into_iter()
            .map(|d| {
                let stop = Arc::clone(&stop);
                std::thread::Builder::new()
                    .name(format!("idds-{}", d.name()))
                    .spawn(move || {
                        while !stop.load(Ordering::SeqCst) {
                            let n = d.poll_once();
                            if n == 0 {
                                std::thread::sleep(interval);
                            }
                        }
                    })
                    .expect("spawn daemon")
            })
            .collect();
        AgentHost { stop, threads }
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for AgentHost {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    struct CountDown {
        left: AtomicUsize,
    }

    impl Daemon for CountDown {
        fn name(&self) -> &'static str {
            "countdown"
        }
        fn poll_once(&self) -> usize {
            let cur = self.left.load(Ordering::SeqCst);
            if cur == 0 {
                0
            } else {
                self.left.store(cur - 1, Ordering::SeqCst);
                1
            }
        }
    }

    #[test]
    fn pump_runs_to_quiescence() {
        let d = CountDown { left: AtomicUsize::new(5) };
        let total = pump(&[&d], 100);
        assert_eq!(total, 5);
        assert_eq!(d.poll_once(), 0);
    }

    #[test]
    fn pump_respects_max_sweeps() {
        let d = CountDown { left: AtomicUsize::new(1000) };
        let total = pump(&[&d], 3);
        assert_eq!(total, 3);
    }

    #[test]
    fn agent_host_drains_work() {
        let d = Arc::new(CountDown { left: AtomicUsize::new(20) });
        let host = AgentHost::start(
            vec![Arc::clone(&d) as Arc<dyn Daemon>],
            std::time::Duration::from_millis(1),
        );
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        while d.left.load(Ordering::SeqCst) > 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        host.stop();
        assert_eq!(d.left.load(Ordering::SeqCst), 0);
    }
}
