//! Work-payload executors behind the Carrier.
//!
//! The Carrier submits Processing objects "to the WFM system" (paper
//! section 2). In this repo the WFM is one of several backends, selected
//! by the Work's [`WorkKind`]:
//!
//! * [`NoopExecutor`]    — orchestration-only Works (Rubin DAG vertices,
//!   tests): completes on the next poll, echoing configured outputs.
//! * [`RuntimeExecutor`] — HPO-training and decision Works: executes the
//!   AOT PJRT artifacts (`mlp_train`, `al_decision`) on a worker pool,
//!   completion is observed by polling (matching the asynchronous
//!   evaluation structure of paper Fig. 6).
//! * [`RemoteExecutor`]  — distributed Works: submits by enqueueing a
//!   lease on the kind's shared claim queue
//!   ([`crate::broker::lease::WorkerRegistry`]); remote worker processes
//!   execute and report back, completion is observed by polling the
//!   registry's buffered results. Same contract, different machine.
//!
//! Data-processing Works run against the DDM/WFM discrete-event
//! simulators and are driven by the carousel module, not by an executor
//! here — simulated time cannot block a live daemon thread.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::broker::lease::WorkerRegistry;
use crate::runtime::EngineHandle;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workflow::WorkKind;

/// Asynchronous payload executor.
pub trait Executor: Send + Sync {
    /// Begin executing; `work` is the serialized Work (template params under
    /// `params`). Returns an opaque handle.
    fn submit(&self, work: &Json) -> Result<u64>;

    /// Poll a handle: `None` while running, `Some(result)` once done.
    fn poll(&self, handle: u64) -> Result<Option<Json>>;

    /// Poll many handles at once. The default loops over [`Executor::poll`];
    /// backends with internal locking override this to take their lock a
    /// single time per Carrier tick instead of once per in-flight handle.
    fn poll_many(&self, handles: &[u64]) -> Vec<(u64, Result<Option<Json>>)> {
        handles.iter().map(|&h| (h, self.poll(h))).collect()
    }
}

/// Executor registry keyed by WorkKind.
#[derive(Clone, Default)]
pub struct ExecutorSet {
    map: HashMap<&'static str, Arc<dyn Executor>>,
}

impl ExecutorSet {
    pub fn with(mut self, kind: WorkKind, exec: Arc<dyn Executor>) -> Self {
        self.map.insert(kind.as_str(), exec);
        self
    }

    pub fn get(&self, kind: &str) -> Option<Arc<dyn Executor>> {
        self.map.get(kind).cloned()
    }

    /// The kinds this set can execute, sorted — what a worker process
    /// advertises at registration.
    pub fn kinds(&self) -> Vec<&'static str> {
        let mut kinds: Vec<&'static str> = self.map.keys().copied().collect();
        kinds.sort_unstable();
        kinds
    }
}

/// Completes immediately; result echoes `params.result` (or {}).
pub struct NoopExecutor {
    done: Mutex<HashMap<u64, Json>>,
}

impl Default for NoopExecutor {
    fn default() -> Self {
        NoopExecutor {
            done: Mutex::new(HashMap::new()),
        }
    }
}

impl Executor for NoopExecutor {
    fn submit(&self, work: &Json) -> Result<u64> {
        let handle = crate::util::next_id();
        let result = work
            .get_path(&["params", "result"])
            .cloned()
            .unwrap_or_else(Json::obj);
        self.done.lock().unwrap().insert(handle, result);
        Ok(handle)
    }

    fn poll(&self, handle: u64) -> Result<Option<Json>> {
        Ok(self.done.lock().unwrap().remove(&handle))
    }

    fn poll_many(&self, handles: &[u64]) -> Vec<(u64, Result<Option<Json>>)> {
        let mut done = self.done.lock().unwrap();
        handles.iter().map(|&h| (h, Ok(done.remove(&h)))).collect()
    }
}

/// Submits by enqueueing a lease on the kind's shared claim queue instead
/// of executing in-process — the Carrier cannot tell the difference. The
/// work is durably queued in the broker (it survives head restarts like
/// any published message); a fleet worker leases it, executes, and reports
/// the completion back through the registry, where [`Executor::poll`]
/// picks it up on the next Carrier tick.
///
/// `poll` on a handle with no buffered result returns `Ok(None)` — that
/// covers "still queued", "leased and running", *and* "registry forgot the
/// binding across a head restart" (the broker redelivers the work, a
/// worker re-executes it, and the result shows up one lease cycle later).
/// Remote execution is therefore at-least-once; the Carrier transitions
/// each processing exactly once regardless.
pub struct RemoteExecutor {
    registry: WorkerRegistry,
    kind: &'static str,
}

impl RemoteExecutor {
    pub fn new(registry: WorkerRegistry, kind: WorkKind) -> Self {
        RemoteExecutor { registry, kind: kind.as_str() }
    }
}

impl Executor for RemoteExecutor {
    fn submit(&self, work: &Json) -> Result<u64> {
        let handle = crate::util::next_id();
        self.registry.enqueue(self.kind, handle, work);
        Ok(handle)
    }

    fn poll(&self, handle: u64) -> Result<Option<Json>> {
        Ok(self.registry.take_result(handle))
    }

    fn poll_many(&self, handles: &[u64]) -> Vec<(u64, Result<Option<Json>>)> {
        handles.iter().map(|&h| (h, Ok(self.registry.take_result(h)))).collect()
    }
}

enum SlotState {
    Running,
    Done(Json),
    Failed(String),
}

/// Executes HPO-training and decision Works on the PJRT engine, one worker
/// pool for all submissions (the "geographically distributed GPU
/// resources" of paper section 3.2, collapsed to a local pool that
/// preserves the asynchronous-evaluation code path).
pub struct RuntimeExecutor {
    engine: EngineHandle,
    pool: crate::util::pool::ThreadPool,
    slots: Arc<Mutex<HashMap<u64, SlotState>>>,
}

impl RuntimeExecutor {
    pub fn new(engine: EngineHandle, workers: usize) -> Self {
        RuntimeExecutor {
            engine,
            pool: crate::util::pool::ThreadPool::new(workers, "rt-exec"),
            slots: Arc::new(Mutex::new(HashMap::new())),
        }
    }

    /// Deterministic payload dataset for a training Work (seeded by the
    /// Work's `seed` param so every hyperparameter point of one HPO task
    /// trains on identical data).
    fn payload_data(engine: &EngineHandle, seed: u64) -> Result<TrainData> {
        let spec = engine.spec("mlp_train").context("mlp_train spec")?;
        let train_n = spec.consts["train_n"] as usize;
        let val_n = spec.consts["val_n"] as usize;
        let in_dim = spec.consts["in_dim"] as usize;
        let hidden = spec.consts["hidden"] as usize;
        let mut rng = Rng::new(seed);
        let mut mk = |n: usize, scale: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.normal() * scale) as f32).collect()
        };
        let xtr = mk(train_n * in_dim, 1.0);
        let xval = mk(val_n * in_dim, 1.0);
        let w1 = mk(in_dim * hidden, 0.3);
        let w2 = mk(hidden, 0.3);
        let target = |x: &[f32], i: usize| (x[i * in_dim] * 2.0).sin() + 0.5 * x[i * in_dim + 1];
        let ytr: Vec<f32> = (0..train_n).map(|i| target(&xtr, i)).collect();
        let yval: Vec<f32> = (0..val_n).map(|i| target(&xval, i)).collect();
        Ok(TrainData {
            xtr,
            ytr,
            xval,
            yval,
            w1,
            b1: vec![0.0; hidden],
            w2,
            b2: vec![0.0; 1],
        })
    }
}

struct TrainData {
    xtr: Vec<f32>,
    ytr: Vec<f32>,
    xval: Vec<f32>,
    yval: Vec<f32>,
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
}

fn param_f32(work: &Json, name: &str) -> Result<f32> {
    work.get_path(&["params", name])
        .and_then(|v| v.as_f64())
        .map(|v| v as f32)
        .with_context(|| format!("work param '{name}' missing or not numeric"))
}

impl Executor for RuntimeExecutor {
    fn submit(&self, work: &Json) -> Result<u64> {
        let handle = crate::util::next_id();
        let kind = work.get("kind").and_then(|k| k.as_str()).unwrap_or("");
        let engine = self.engine.clone();
        let slots = Arc::clone(&self.slots);
        slots.lock().unwrap().insert(handle, SlotState::Running);

        match kind {
            "HpoTraining" => {
                let hp = [
                    param_f32(work, "log_lr")?,
                    param_f32(work, "momentum")?,
                    param_f32(work, "log_l2")?,
                    param_f32(work, "log_clip")?,
                ];
                let seed = work
                    .get_path(&["params", "seed"])
                    .and_then(|v| v.as_u64())
                    .unwrap_or(0);
                self.pool.execute(move || {
                    let outcome = (|| -> Result<Json> {
                        let d = RuntimeExecutor::payload_data(&engine, seed)?;
                        let out = engine.mlp_train(
                            &hp, &d.xtr, &d.ytr, &d.xval, &d.yval, &d.w1, &d.b1, &d.w2, &d.b2,
                        )?;
                        Ok(Json::obj()
                            .set("val_loss", out.val_loss as f64)
                            .set("train_loss", out.train_loss as f64))
                    })();
                    let state = match outcome {
                        Ok(j) => SlotState::Done(j),
                        Err(e) => SlotState::Failed(e.to_string()),
                    };
                    slots.lock().unwrap().insert(handle, state);
                });
            }
            "Decision" => {
                let stats: Vec<f32> = work
                    .get_path(&["params", "stats"])
                    .and_then(|s| s.as_arr())
                    .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
                    .unwrap_or_default();
                let weights: Vec<f32> = work
                    .get_path(&["params", "weights"])
                    .and_then(|s| s.as_arr())
                    .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
                    .unwrap_or_default();
                let bias = param_f32(work, "bias").unwrap_or(0.0);
                let threshold = param_f32(work, "threshold").unwrap_or(0.5);
                self.pool.execute(move || {
                    let outcome = (|| -> Result<Json> {
                        let (score, go) = engine.al_decision(&stats, &weights, bias, threshold)?;
                        Ok(Json::obj().set("score", score as f64).set("go", go))
                    })();
                    let state = match outcome {
                        Ok(j) => SlotState::Done(j),
                        Err(e) => SlotState::Failed(e.to_string()),
                    };
                    slots.lock().unwrap().insert(handle, state);
                });
            }
            other => {
                slots.lock().unwrap().insert(
                    handle,
                    SlotState::Failed(format!("RuntimeExecutor cannot run kind '{other}'")),
                );
            }
        }
        Ok(handle)
    }

    fn poll(&self, handle: u64) -> Result<Option<Json>> {
        let mut slots = self.slots.lock().unwrap();
        poll_slot(&mut slots, handle)
    }

    fn poll_many(&self, handles: &[u64]) -> Vec<(u64, Result<Option<Json>>)> {
        let mut slots = self.slots.lock().unwrap();
        handles
            .iter()
            .map(|&h| (h, poll_slot(&mut slots, h)))
            .collect()
    }
}

fn poll_slot(slots: &mut HashMap<u64, SlotState>, handle: u64) -> Result<Option<Json>> {
    match slots.get(&handle) {
        None => anyhow::bail!("unknown handle {handle}"),
        Some(SlotState::Running) => Ok(None),
        Some(SlotState::Done(_)) => {
            let Some(SlotState::Done(j)) = slots.remove(&handle) else { unreachable!() };
            Ok(Some(j))
        }
        Some(SlotState::Failed(_)) => {
            let Some(SlotState::Failed(msg)) = slots.remove(&handle) else { unreachable!() };
            Ok(Some(Json::obj().set("error", msg.as_str())))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_completes_with_echo() {
        let e = NoopExecutor::default();
        let work = Json::obj().set(
            "params",
            Json::obj().set("result", Json::obj().set("x", 1.0)),
        );
        let h = e.submit(&work).unwrap();
        let r = e.poll(h).unwrap().unwrap();
        assert_eq!(r.get("x").unwrap().as_f64(), Some(1.0));
        // handle consumed
        assert!(e.poll(h).unwrap().is_none());
    }

    #[test]
    fn poll_many_matches_per_handle_poll() {
        let e = NoopExecutor::default();
        let mk = |x: f64| {
            Json::obj().set(
                "params",
                Json::obj().set("result", Json::obj().set("x", x)),
            )
        };
        let h1 = e.submit(&mk(1.0)).unwrap();
        let h2 = e.submit(&mk(2.0)).unwrap();
        let out = e.poll_many(&[h1, h2, 999]);
        assert_eq!(out.len(), 3);
        assert_eq!(
            out[0].1.as_ref().unwrap().as_ref().unwrap().get("x").unwrap().as_f64(),
            Some(1.0)
        );
        assert_eq!(
            out[1].1.as_ref().unwrap().as_ref().unwrap().get("x").unwrap().as_f64(),
            Some(2.0)
        );
        assert!(out[2].1.as_ref().unwrap().is_none(), "unknown handle is None for Noop");
    }

    #[test]
    fn executor_set_dispatch() {
        let set = ExecutorSet::default().with(WorkKind::Noop, Arc::new(NoopExecutor::default()));
        assert!(set.get("Noop").is_some());
        assert!(set.get("HpoTraining").is_none());
    }

    #[test]
    fn remote_executor_round_trips_through_the_registry() {
        let clock = crate::util::clock::SimClock::new();
        let broker = crate::broker::Broker::new(clock.clone() as Arc<dyn crate::util::clock::Clock>);
        let registry = WorkerRegistry::new(
            broker,
            clock,
            crate::metrics::Registry::default(),
        );
        let exec = RemoteExecutor::new(registry.clone(), WorkKind::Noop);
        let work = Json::obj().set("kind", "Noop").set("params", Json::obj().set("y", 3.0));
        let h = exec.submit(&work).unwrap();
        assert!(exec.poll(h).unwrap().is_none(), "nothing until a worker completes");

        // an inline "worker": register, lease, execute (echo), complete
        let (w, e) = registry.register("inline", &["Noop".into()]);
        let grants = registry.lease(w, 10).unwrap();
        assert_eq!(grants.len(), 1);
        assert_eq!(grants[0].handle, h);
        assert_eq!(grants[0].work.get_path(&["params", "y"]).unwrap().as_f64(), Some(3.0));
        assert!(registry.complete(w, e, grants[0].lease, h, Json::obj().set("done", true)));

        let out = exec.poll_many(&[h]);
        assert_eq!(out[0].1.as_ref().unwrap().as_ref().unwrap().get("done").unwrap().as_bool(), Some(true));
        assert!(exec.poll(h).unwrap().is_none(), "consumed, like every executor");
    }
}
