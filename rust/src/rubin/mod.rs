//! Rubin Observatory (LSST) DG workloads (paper section 3.3.1).
//!
//! "A single workflow can consist of a hundred thousand jobs forming the
//! vertexes of a DAG. ... Every workflow is mapped to sequentially
//! concatenated Work objects in iDDS. iDDS also allows Work objects to be
//! incrementally released based on messaging, in order to avoid long
//! waiting in each Work."
//!
//! This module provides:
//! * [`generate_dag`] — layered random DAGs with per-job dependencies, the
//!   shape Rubin middleware emits per payload submission;
//! * [`map_to_works`] — the iDDS mapping: topological layers →
//!   sequentially concatenated Works (one Work per layer chunk);
//! * [`schedule`] — a slot-limited executor comparing **bulk release**
//!   (a Work's jobs start only when the previous Work fully finishes — the
//!   "long waiting in each Work") against **incremental release** (a job
//!   starts the moment its own dependencies finish, driven by per-job
//!   completion messages).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::util::rng::Rng;

pub type JobIdx = usize;

/// One DAG vertex: dependencies, topological layer, and wall time.
#[derive(Debug, Clone)]
pub struct DagJob {
    /// indexes of jobs this one depends on (all in earlier layers)
    pub deps: Vec<JobIdx>,
    pub layer: usize,
    pub wall_s: f64,
}

/// A layered payload DAG (the shape Rubin middleware emits).
#[derive(Debug, Clone)]
pub struct Dag {
    pub jobs: Vec<DagJob>,
    pub layers: usize,
}

/// Generate a layered DAG: `n_jobs` spread over `layers`, each job
/// depending on up to `max_deps` jobs from the previous layer, with
/// heavy-tailed wall times.
pub fn generate_dag(n_jobs: usize, layers: usize, max_deps: usize, seed: u64) -> Dag {
    assert!(layers >= 1 && n_jobs >= layers);
    let mut rng = Rng::new(seed);
    let per_layer = n_jobs / layers;
    let mut jobs = Vec::with_capacity(n_jobs);
    let mut layer_start = vec![0usize; layers + 1];
    for l in 0..layers {
        layer_start[l] = jobs.len();
        let count = if l == layers - 1 {
            n_jobs - jobs.len()
        } else {
            per_layer
        };
        for _ in 0..count {
            let deps = if l == 0 {
                Vec::new()
            } else {
                let prev_start = layer_start[l - 1];
                let prev_len = layer_start[l] - prev_start;
                let k = 1 + rng.below(max_deps as u64) as usize;
                (0..k)
                    .map(|_| prev_start + rng.below(prev_len as u64) as usize)
                    .collect()
            };
            let wall = rng.exponential(300.0).clamp(30.0, 7200.0);
            jobs.push(DagJob {
                deps,
                layer: l,
                wall_s: wall,
            });
        }
    }
    layer_start[layers] = jobs.len();
    Dag { jobs, layers }
}

/// The iDDS mapping: one Work per layer (sequentially concatenated), with
/// each Work's job list. Returns (work index → job indexes).
pub fn map_to_works(dag: &Dag) -> Vec<Vec<JobIdx>> {
    let mut works = vec![Vec::new(); dag.layers];
    for (i, j) in dag.jobs.iter().enumerate() {
        works[j.layer].push(i);
    }
    works
}

/// How jobs of the sequentially concatenated Works enter the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Release {
    /// next Work starts only when the previous Work is fully done
    Bulk,
    /// jobs released by per-dependency completion messages (iDDS)
    Incremental,
}

/// Outcome of one scheduled run (compare Bulk vs Incremental).
#[derive(Debug, Clone, Copy)]
pub struct ScheduleResult {
    pub release: Release,
    pub jobs: usize,
    pub makespan_s: f64,
    /// mean time jobs spend ready-but-unreleased (the "long waiting")
    pub mean_release_lag_s: f64,
    pub messages: u64,
}

#[derive(PartialEq, PartialOrd)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// Slot-limited execution of the DAG under a release policy.
pub fn schedule(dag: &Dag, slots: usize, release: Release) -> ScheduleResult {
    let n = dag.jobs.len();
    let works = map_to_works(dag);
    let mut deps_left: Vec<usize> = dag.jobs.iter().map(|j| j.deps.len()).collect();
    let mut dependents: Vec<Vec<JobIdx>> = vec![Vec::new(); n];
    for (i, j) in dag.jobs.iter().enumerate() {
        for &d in &j.deps {
            dependents[d].push(i);
        }
    }
    // deps_done_at[i]: when job i's last dependency finished (readiness)
    let mut ready_at = vec![f64::NAN; n];
    let mut released = vec![false; n];
    let mut finish_at = vec![f64::NAN; n];
    let mut queue: Vec<JobIdx> = Vec::new();
    let mut running: BinaryHeap<Reverse<(OrdF64, JobIdx)>> = BinaryHeap::new();
    let mut free = slots;
    let mut now = 0.0f64;
    let mut done = 0usize;
    let mut messages = 0u64;
    let mut current_work = 0usize;
    let mut work_done_count = vec![0usize; works.len()];

    // initial release
    match release {
        Release::Incremental => {
            for (i, j) in dag.jobs.iter().enumerate() {
                if j.deps.is_empty() {
                    ready_at[i] = 0.0;
                    released[i] = true;
                    queue.push(i);
                }
            }
        }
        Release::Bulk => {
            for &i in &works[0] {
                ready_at[i] = 0.0;
                released[i] = true;
                queue.push(i);
            }
        }
    }

    while done < n {
        // dispatch
        while free > 0 {
            let Some(i) = queue.pop() else { break };
            free -= 1;
            running.push(Reverse((OrdF64(now + dag.jobs[i].wall_s), i)));
        }
        // next completion
        let Some(Reverse((OrdF64(t), i))) = running.pop() else {
            panic!("deadlock: {done}/{n} done, queue empty, nothing running");
        };
        now = t;
        finish_at[i] = t;
        free += 1;
        done += 1;
        work_done_count[dag.jobs[i].layer] += 1;

        match release {
            Release::Incremental => {
                // per-job completion message releases dependents
                for &dep in &dependents[i] {
                    deps_left[dep] -= 1;
                    messages += 1;
                    if deps_left[dep] == 0 {
                        ready_at[dep] = now;
                        released[dep] = true;
                        queue.push(dep);
                    }
                }
            }
            Release::Bulk => {
                // readiness still tracked for the lag metric
                for &dep in &dependents[i] {
                    deps_left[dep] -= 1;
                    if deps_left[dep] == 0 {
                        ready_at[dep] = now;
                    }
                }
                // barrier: release the next Work when this one drains
                if dag.jobs[i].layer == current_work
                    && work_done_count[current_work] == works[current_work].len()
                {
                    current_work += 1;
                    messages += 1; // one Work-level message
                    if current_work < works.len() {
                        for &j in &works[current_work] {
                            released[j] = true;
                            if ready_at[j].is_nan() {
                                ready_at[j] = now;
                            }
                            queue.push(j);
                        }
                    }
                }
            }
        }
    }

    let makespan = finish_at.iter().cloned().fold(0.0, f64::max);
    // release lag: started-at-earliest (when entered queue) minus ready_at.
    // With bulk release a job ready at t waits until its Work opens.
    let mut lag_sum = 0.0;
    let mut lag_n = 0usize;
    for i in 0..n {
        if dag.jobs[i].deps.is_empty() {
            continue;
        }
        let start = finish_at[i] - dag.jobs[i].wall_s;
        let lag = (start - ready_at[i]).max(0.0);
        lag_sum += lag;
        lag_n += 1;
    }
    ScheduleResult {
        release,
        jobs: n,
        makespan_s: makespan,
        mean_release_lag_s: if lag_n == 0 { 0.0 } else { lag_sum / lag_n as f64 },
        messages,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dag_structure_valid() {
        let dag = generate_dag(1000, 10, 3, 1);
        assert_eq!(dag.jobs.len(), 1000);
        for (i, j) in dag.jobs.iter().enumerate() {
            for &d in &j.deps {
                assert!(d < i, "deps point backwards");
                assert_eq!(dag.jobs[d].layer + 1, j.layer);
            }
        }
        // layer 0 has no deps
        assert!(dag.jobs.iter().filter(|j| j.layer == 0).all(|j| j.deps.is_empty()));
    }

    #[test]
    fn works_mapping_covers_all_jobs() {
        let dag = generate_dag(500, 5, 2, 2);
        let works = map_to_works(&dag);
        assert_eq!(works.len(), 5);
        assert_eq!(works.iter().map(|w| w.len()).sum::<usize>(), 500);
    }

    #[test]
    fn both_policies_complete_everything() {
        let dag = generate_dag(2000, 8, 3, 3);
        let b = schedule(&dag, 64, Release::Bulk);
        let i = schedule(&dag, 64, Release::Incremental);
        assert_eq!(b.jobs, 2000);
        assert_eq!(i.jobs, 2000);
        assert!(b.makespan_s > 0.0 && i.makespan_s > 0.0);
    }

    #[test]
    fn incremental_release_no_slower_and_less_waiting() {
        for seed in [1, 7, 42] {
            let dag = generate_dag(3000, 10, 3, seed);
            let b = schedule(&dag, 128, Release::Bulk);
            let i = schedule(&dag, 128, Release::Incremental);
            assert!(
                i.makespan_s <= b.makespan_s + 1e-6,
                "seed {seed}: inc {} vs bulk {}",
                i.makespan_s,
                b.makespan_s
            );
            assert!(
                i.mean_release_lag_s < b.mean_release_lag_s,
                "seed {seed}: inc lag {} vs bulk lag {}",
                i.mean_release_lag_s,
                b.mean_release_lag_s
            );
        }
    }

    #[test]
    fn hundred_thousand_jobs_map_fast() {
        let t0 = std::time::Instant::now();
        let dag = generate_dag(100_000, 20, 4, 9);
        let works = map_to_works(&dag);
        assert_eq!(works.iter().map(|w| w.len()).sum::<usize>(), 100_000);
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(5),
            "mapping 100k jobs took {:?}",
            t0.elapsed()
        );
    }
}
