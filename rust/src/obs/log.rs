//! Leveled JSON-lines logger behind the `log` facade.
//!
//! One line per event on stderr, e.g.
//! `{"ts":1754650000.123,"level":"WARN","target":"idds::persist::wal","msg":"..."}`
//! — machine-parseable where the old scattered `eprintln!` sites were
//! not. Levels resolve per component: `obs.log.level` is the default
//! and any `obs.log.<component>` key (say `obs.log.persist = "debug"`)
//! overrides it for log targets containing that component name.
//! Repeats are rate-limited per call site: within
//! `obs.log.repeat_window_s` seconds a `(target, line)` pair logs once,
//! and the next emission carries a `"repeated": N` count for the
//! suppressed occurrences.
//!
//! The logger is a `static` installed with [`log::set_logger`]
//! (the facade's allocation-free path), so [`init`] is idempotent —
//! repeated calls just re-apply configuration.

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use ::log::{LevelFilter, Metadata, Record};

use crate::config::Config;
use crate::util::json::Json;

/// Call sites tracked for repeat suppression before the map is pruned.
const REPEAT_SITES_CAP: usize = 1024;

struct Repeat {
    last_s: u64,
    suppressed: u64,
}

pub struct JsonLogger {
    /// Default [`LevelFilter`] as usize (atomics can't hold the enum).
    default_level: AtomicUsize,
    /// `(component, level)` overrides; longest component match wins.
    components: Mutex<Vec<(String, LevelFilter)>>,
    repeat_window_s: AtomicU64,
    repeats: Mutex<BTreeMap<(String, u32), Repeat>>,
}

static LOGGER: JsonLogger = JsonLogger {
    default_level: AtomicUsize::new(LevelFilter::Info as usize),
    components: Mutex::new(Vec::new()),
    repeat_window_s: AtomicU64::new(5),
    repeats: Mutex::new(BTreeMap::new()),
};

fn filter_from_usize(v: usize) -> LevelFilter {
    match v {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

fn now_epoch() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

impl JsonLogger {
    fn level_for(&self, target: &str) -> LevelFilter {
        let comps = self.components.lock().unwrap();
        let mut best: Option<(usize, LevelFilter)> = None;
        for (comp, lvl) in comps.iter() {
            if target.contains(comp.as_str())
                && best.map(|(len, _)| comp.len() > len).unwrap_or(true)
            {
                best = Some((comp.len(), *lvl));
            }
        }
        match best {
            Some((_, lvl)) => lvl,
            None => filter_from_usize(self.default_level.load(Ordering::Relaxed)),
        }
    }
}

fn format_line(level: &str, target: &str, msg: &str, repeated: u64) -> String {
    let mut j = Json::obj()
        .set("ts", now_epoch())
        .set("level", Json::Str(level.to_string()))
        .set("target", Json::Str(target.to_string()))
        .set("msg", Json::Str(msg.to_string()));
    if repeated > 0 {
        j = j.set("repeated", repeated);
    }
    j.to_string()
}

impl ::log::Log for JsonLogger {
    fn enabled(&self, md: &Metadata) -> bool {
        md.level() <= self.level_for(md.target())
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let mut repeated = 0;
        let window = self.repeat_window_s.load(Ordering::Relaxed);
        if window > 0 {
            let now_s = now_epoch() as u64;
            let key = (record.target().to_string(), record.line().unwrap_or(0));
            let mut map = self.repeats.lock().unwrap();
            let e = map.entry(key).or_insert(Repeat { last_s: 0, suppressed: 0 });
            if now_s < e.last_s.saturating_add(window) {
                e.suppressed += 1;
                return;
            }
            repeated = e.suppressed;
            e.suppressed = 0;
            e.last_s = now_s;
            while map.len() > REPEAT_SITES_CAP {
                map.pop_first();
            }
        }
        let line = format_line(
            record.level().as_str(),
            record.target(),
            &record.args().to_string(),
            repeated,
        );
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "{line}");
    }

    fn flush(&self) {
        let _ = std::io::stderr().flush();
    }
}

fn parse_level(s: &str) -> Option<LevelFilter> {
    s.parse::<LevelFilter>().ok()
}

/// Install (idempotent) and configure the logger from `obs.log.*`.
pub fn init(cfg: &Config) {
    let default = cfg
        .str("obs.log.level")
        .ok()
        .and_then(|s| parse_level(&s))
        .unwrap_or(LevelFilter::Info);
    LOGGER.default_level.store(default as usize, Ordering::Relaxed);
    if let Ok(w) = cfg.u64("obs.log.repeat_window_s") {
        LOGGER.repeat_window_s.store(w, Ordering::Relaxed);
    }
    let mut comps: Vec<(String, LevelFilter)> = Vec::new();
    for key in cfg.keys() {
        let Some(comp) = key.strip_prefix("obs.log.") else { continue };
        if comp == "level" || comp == "repeat_window_s" || comp.is_empty() {
            continue;
        }
        if let Some(lvl) = cfg.str(key).ok().and_then(|s| parse_level(&s)) {
            comps.push((comp.to_string(), lvl));
        }
    }
    // the facade's global gate must admit the most verbose resolver
    let global = comps.iter().map(|&(_, l)| l).chain([default]).max().unwrap_or(default);
    *LOGGER.components.lock().unwrap() = comps;
    let _ = ::log::set_logger(&LOGGER);
    ::log::set_max_level(global);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_is_valid_json() {
        let line = format_line("WARN", "idds::persist::wal", "fsync \"failed\"\n", 3);
        let j = crate::util::json::parse(&line).unwrap();
        assert_eq!(j.get("level").unwrap().as_str(), Some("WARN"));
        assert_eq!(j.get("msg").unwrap().as_str(), Some("fsync \"failed\"\n"));
        assert_eq!(j.get("repeated").unwrap().as_u64(), Some(3));
        let quiet = format_line("INFO", "t", "m", 0);
        assert!(crate::util::json::parse(&quiet).unwrap().get("repeated").is_none());
    }

    #[test]
    fn component_override_beats_default() {
        LOGGER
            .default_level
            .store(LevelFilter::Info as usize, Ordering::Relaxed);
        {
            let mut comps = LOGGER.components.lock().unwrap();
            comps.clear();
            comps.push(("persist".to_string(), LevelFilter::Debug));
            comps.push(("persist::wal".to_string(), LevelFilter::Error));
        }
        assert_eq!(LOGGER.level_for("idds::broker"), LevelFilter::Info);
        assert_eq!(LOGGER.level_for("idds::persist::mod"), LevelFilter::Debug);
        // longest component match wins
        assert_eq!(LOGGER.level_for("idds::persist::wal"), LevelFilter::Error);
        LOGGER.components.lock().unwrap().clear();
    }

    #[test]
    fn level_parse() {
        assert_eq!(parse_level("debug"), Some(LevelFilter::Debug));
        assert_eq!(parse_level("WARN"), Some(LevelFilter::Warn));
        assert_eq!(parse_level("nope"), None);
    }
}
