//! Observability: spans, trace propagation, and the completed-span ring.
//!
//! The tracer is a process-global with an **armed** flag: when tracing is
//! off, [`span`] is one relaxed atomic load returning an inert guard — no
//! id generation, no clock read, no allocation — so instrumentation can
//! sit on hot paths (store insert, broker publish) at negligible cost
//! (`bench_obs` pins the number). When armed, a span guard carries a
//! process-unique `(trace_id, span_id)` pair, parents itself under the
//! thread's current span, and on drop records a [`SpanRecord`] into a
//! bounded ring. A second, smaller ring pins every span whose duration
//! crossed `obs.trace.slow_us`, so outliers survive even when the main
//! ring has churned past them. Traces are assembled at query time by
//! scanning both rings for a trace id (`GET /api/traces/<id>`): spans
//! that finish late (a daemon tick completing after the client already
//! got its response) still join the tree.
//!
//! Cross-process propagation rides the `X-IDDS-Trace: <trace>-<span>`
//! header (both halves lowercase hex): `rest::Client` and the standby's
//! replication pull inject it, `rest::route` adopts it, so one trace id
//! spans a `Client::submit` on one box and the handler on another.
//! Cross-*daemon* stitching uses the [`tag`]/[`take_tag`] map: the
//! submit handler tags the new request id with its span context and the
//! Clerk picks the tag up on intake, parenting the asynchronous pipeline
//! work under the original submit trace.

pub mod log;

use std::cell::Cell;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use crate::config::Config;
use crate::util::json::Json;

/// Header carrying `<trace_id hex>-<span_id hex>` across processes.
pub const TRACE_HEADER: &str = "X-IDDS-Trace";

const DEFAULT_RING: usize = 4096;
const DEFAULT_SLOW_RING: usize = 512;
const DEFAULT_SLOW_US: u64 = 100_000;
/// Bound on the request-id → submit-context stitch map.
const TAG_CAP: usize = 4096;
/// Odd stride for id generation: never repeats within 2^64 draws.
const ID_STRIDE: u64 = 0x9e37_79b9_7f4a_7c15;

/// A span's identity: which trace it belongs to and its own id.
/// `trace_id == 0` means "no active span" (the disarmed / root state).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct TraceCtx {
    pub trace_id: u64,
    pub span_id: u64,
}

impl TraceCtx {
    pub const NONE: TraceCtx = TraceCtx { trace_id: 0, span_id: 0 };

    pub fn is_none(self) -> bool {
        self.trace_id == 0
    }

    /// Wire form for [`TRACE_HEADER`].
    pub fn header_value(self) -> String {
        format!("{:016x}-{:016x}", self.trace_id, self.span_id)
    }

    /// Parse the wire form; `None` on anything malformed.
    pub fn parse(s: &str) -> Option<TraceCtx> {
        let (t, p) = s.trim().split_once('-')?;
        let trace_id = u64::from_str_radix(t, 16).ok()?;
        let span_id = u64::from_str_radix(p, 16).ok()?;
        if trace_id == 0 {
            return None;
        }
        Some(TraceCtx { trace_id, span_id })
    }
}

/// A completed span as retained by the rings.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent_id: u64,
    pub name: String,
    /// Wall-clock start, microseconds since the Unix epoch.
    pub start_us: u64,
    pub dur_us: u64,
    pub attrs: Vec<(String, String)>,
}

/// Fixed-capacity ring of completed spans (oldest evicted first).
struct Ring {
    cap: usize,
    buf: VecDeque<SpanRecord>,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring { cap: cap.max(1), buf: VecDeque::new() }
    }

    fn push(&mut self, rec: SpanRecord) {
        while self.buf.len() >= self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(rec);
    }

    fn set_cap(&mut self, cap: usize) {
        self.cap = cap.max(1);
        while self.buf.len() > self.cap {
            self.buf.pop_front();
        }
    }
}

struct Tracer {
    next_id: AtomicU64,
    slow_us: AtomicU64,
    ring: Mutex<Ring>,
    slow: Mutex<Ring>,
    tags: Mutex<BTreeMap<u64, TraceCtx>>,
}

/// Kept outside the `OnceLock` so the disarmed fast path is exactly one
/// relaxed load with no pointer chase.
static ARMED: AtomicBool = AtomicBool::new(false);
static TRACER: OnceLock<Tracer> = OnceLock::new();

thread_local! {
    static CURRENT: Cell<TraceCtx> = Cell::new(TraceCtx::NONE);
}

fn tracer() -> &'static Tracer {
    TRACER.get_or_init(|| {
        let seed = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(1)
            ^ ((std::process::id() as u64) << 32);
        Tracer {
            next_id: AtomicU64::new(seed | 1),
            slow_us: AtomicU64::new(DEFAULT_SLOW_US),
            ring: Mutex::new(Ring::new(DEFAULT_RING)),
            slow: Mutex::new(Ring::new(DEFAULT_SLOW_RING)),
            tags: Mutex::new(BTreeMap::new()),
        }
    })
}

fn next_id(t: &Tracer) -> u64 {
    let id = t.next_id.fetch_add(ID_STRIDE, Ordering::Relaxed);
    if id == 0 { ID_STRIDE } else { id }
}

fn now_us() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

/// Is tracing armed? One relaxed load — callers may use this to skip
/// attribute formatting entirely.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Arm or disarm the tracer at runtime.
pub fn arm(on: bool) {
    if on {
        tracer(); // make sure the rings exist before spans land
    }
    ARMED.store(on, Ordering::Relaxed);
}

/// Apply `obs.trace.*` config (ring capacities, slow threshold, armed).
pub fn configure(cfg: &Config) {
    let t = tracer();
    if let Some(cap) = cfg.get("obs.trace.ring_capacity").and_then(|j| j.as_u64()) {
        t.ring.lock().unwrap().set_cap(cap as usize);
    }
    if let Some(us) = cfg.get("obs.trace.slow_us").and_then(|j| j.as_u64()) {
        t.slow_us.store(us, Ordering::Relaxed);
    }
    let enabled = cfg
        .get("obs.trace.enabled")
        .and_then(|j| j.as_bool())
        .unwrap_or(true);
    arm(enabled);
}

/// The calling thread's active span context ([`TraceCtx::NONE`] when
/// disarmed or outside any span).
pub fn current() -> TraceCtx {
    CURRENT.with(|c| c.get())
}

struct ActiveSpan {
    ctx: TraceCtx,
    /// Thread-local context to restore on drop (NOT the span's parent:
    /// an adopted remote parent never becomes this thread's context).
    prev: TraceCtx,
    /// `span_id` of the parent recorded into the ring (0 = root).
    parent_span: u64,
    name: String,
    started: Instant,
    start_us: u64,
    attrs: Vec<(String, String)>,
}

/// RAII span: records itself into the ring on drop. Inert (a single
/// `None`) when the tracer is disarmed.
pub struct SpanGuard(Option<ActiveSpan>);

impl SpanGuard {
    /// This span's identity (NONE when inert).
    pub fn ctx(&self) -> TraceCtx {
        self.0.as_ref().map(|a| a.ctx).unwrap_or(TraceCtx::NONE)
    }

    /// Attach a key/value attribute (no-op when inert).
    pub fn attr(&mut self, key: &str, val: impl std::fmt::Display) {
        if let Some(a) = self.0.as_mut() {
            a.attrs.push((key.to_string(), val.to_string()));
        }
    }

    /// Drop without recording — for spans that turned out to be no-ops
    /// (a daemon tick that touched zero rows). Still restores the
    /// thread's previous context.
    pub fn cancel(mut self) {
        if let Some(a) = self.0.take() {
            CURRENT.with(|c| c.set(a.prev));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(a) = self.0.take() else { return };
        CURRENT.with(|c| c.set(a.prev));
        let rec = SpanRecord {
            trace_id: a.ctx.trace_id,
            span_id: a.ctx.span_id,
            parent_id: a.parent_span,
            name: a.name,
            start_us: a.start_us,
            dur_us: a.started.elapsed().as_micros() as u64,
            attrs: a.attrs,
        };
        let t = tracer();
        if rec.dur_us >= t.slow_us.load(Ordering::Relaxed) {
            t.slow.lock().unwrap().push(rec.clone());
        }
        t.ring.lock().unwrap().push(rec);
    }
}

fn start_span(name: &str, parent: TraceCtx) -> SpanGuard {
    let t = tracer();
    let span_id = next_id(t);
    let trace_id = if parent.is_none() { next_id(t) } else { parent.trace_id };
    let ctx = TraceCtx { trace_id, span_id };
    let prev = CURRENT.with(|c| {
        let p = c.get();
        c.set(ctx);
        p
    });
    SpanGuard(Some(ActiveSpan {
        ctx,
        prev,
        parent_span: parent.span_id,
        name: name.to_string(),
        started: Instant::now(),
        start_us: now_us(),
        attrs: Vec::new(),
    }))
}

/// Open a span parented under the thread's current span (a new root if
/// there is none). Disarmed: returns an inert guard after one relaxed
/// atomic load.
#[inline]
pub fn span(name: &str) -> SpanGuard {
    if !armed() {
        return SpanGuard(None);
    }
    let parent = current();
    start_span(name, parent)
}

/// Open a span under an explicit parent context — the adoption point
/// for `X-IDDS-Trace` headers and [`take_tag`] stitches.
pub fn span_with_parent(name: &str, parent: TraceCtx) -> SpanGuard {
    if !armed() {
        return SpanGuard(None);
    }
    if parent.is_none() {
        return start_span(name, current());
    }
    start_span(name, parent)
}

/// Record an already-finished span directly into the rings, bypassing
/// the thread-local parenting machinery. For long-lived work whose guard
/// cannot be held across other spans on the same thread — the REST event
/// loop records connection lifecycles this way, because holding a
/// [`SpanGuard`] per connection on the loop thread would re-parent every
/// sibling connection's spans under the first one. Always a root span.
/// No-op when disarmed.
pub fn record_span(name: &str, dur: std::time::Duration, attrs: &[(&str, String)]) {
    if !armed() {
        return;
    }
    let t = tracer();
    let span_id = next_id(t);
    let trace_id = next_id(t);
    let dur_us = dur.as_micros() as u64;
    let rec = SpanRecord {
        trace_id,
        span_id,
        parent_id: 0,
        name: name.to_string(),
        start_us: now_us().saturating_sub(dur_us),
        dur_us,
        attrs: attrs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
    };
    if dur_us >= t.slow_us.load(Ordering::Relaxed) {
        t.slow.lock().unwrap().push(rec.clone());
    }
    t.ring.lock().unwrap().push(rec);
}

/// Remember `ctx` under a numeric key (request id) so an asynchronous
/// consumer can stitch its work into the originating trace. Bounded:
/// oldest keys evicted past [`TAG_CAP`].
pub fn tag(key: u64, ctx: TraceCtx) {
    if !armed() || ctx.is_none() {
        return;
    }
    let mut tags = tracer().tags.lock().unwrap();
    while tags.len() >= TAG_CAP {
        tags.pop_first();
    }
    tags.insert(key, ctx);
}

/// Claim (and remove) a context stashed by [`tag`].
pub fn take_tag(key: u64) -> Option<TraceCtx> {
    if !armed() {
        return None;
    }
    tracer().tags.lock().unwrap().remove(&key)
}

/// Parse a 16-digit-hex trace id from a URL path segment.
pub fn parse_trace_id(s: &str) -> Option<u64> {
    let id = u64::from_str_radix(s.trim(), 16).ok()?;
    if id == 0 { None } else { Some(id) }
}

fn span_json(s: &SpanRecord) -> Json {
    let mut j = Json::obj()
        .set("span_id", Json::Str(format!("{:016x}", s.span_id)))
        .set("parent_id", Json::Str(format!("{:016x}", s.parent_id)))
        .set("name", Json::Str(s.name.clone()))
        .set("start_us", s.start_us)
        .set("dur_us", s.dur_us);
    if !s.attrs.is_empty() {
        let mut attrs = Json::obj();
        for (k, v) in &s.attrs {
            attrs = attrs.set(k, Json::Str(v.clone()));
        }
        j = j.set("attrs", attrs);
    }
    j
}

/// Every retained span of `trace_id`, deduped across the two rings and
/// sorted by start time.
fn collect_trace(trace_id: u64) -> Vec<SpanRecord> {
    let t = tracer();
    let mut seen = BTreeMap::new();
    for rec in t.ring.lock().unwrap().buf.iter() {
        if rec.trace_id == trace_id {
            seen.insert(rec.span_id, rec.clone());
        }
    }
    for rec in t.slow.lock().unwrap().buf.iter() {
        if rec.trace_id == trace_id {
            seen.entry(rec.span_id).or_insert_with(|| rec.clone());
        }
    }
    let mut spans: Vec<SpanRecord> = seen.into_values().collect();
    spans.sort_by_key(|s| (s.start_us, s.span_id));
    spans
}

fn build_tree(span: &SpanRecord, by_parent: &BTreeMap<u64, Vec<&SpanRecord>>) -> Json {
    let mut j = span_json(span);
    if let Some(kids) = by_parent.get(&span.span_id) {
        j = j.set(
            "children",
            Json::Arr(kids.iter().map(|k| build_tree(k, by_parent)).collect()),
        );
    }
    j
}

/// The span tree for one trace (`GET /api/traces/<id>`); `None` when
/// nothing is retained for that id.
pub fn trace_json(trace_id: u64) -> Option<Json> {
    let spans = collect_trace(trace_id);
    if spans.is_empty() {
        return None;
    }
    let ids: std::collections::BTreeSet<u64> = spans.iter().map(|s| s.span_id).collect();
    let mut by_parent: BTreeMap<u64, Vec<&SpanRecord>> = BTreeMap::new();
    let mut roots: Vec<&SpanRecord> = Vec::new();
    for s in &spans {
        // orphans (parent evicted or still open) surface as roots
        if s.parent_id != 0 && ids.contains(&s.parent_id) {
            by_parent.entry(s.parent_id).or_default().push(s);
        } else {
            roots.push(s);
        }
    }
    let start = spans.iter().map(|s| s.start_us).min().unwrap_or(0);
    let end = spans.iter().map(|s| s.start_us + s.dur_us).max().unwrap_or(0);
    Some(
        Json::obj()
            .set("trace_id", Json::Str(format!("{trace_id:016x}")))
            .set("spans", spans.len() as u64)
            .set("dur_us", end.saturating_sub(start))
            .set(
                "roots",
                Json::Arr(roots.iter().map(|r| build_tree(r, &by_parent)).collect()),
            ),
    )
}

fn summarize(trace_id: u64) -> Json {
    let spans = collect_trace(trace_id);
    let root = spans
        .iter()
        .find(|s| s.parent_id == 0)
        .or_else(|| spans.first());
    let start = spans.iter().map(|s| s.start_us).min().unwrap_or(0);
    let end = spans.iter().map(|s| s.start_us + s.dur_us).max().unwrap_or(0);
    Json::obj()
        .set("trace_id", Json::Str(format!("{trace_id:016x}")))
        .set(
            "root",
            Json::Str(root.map(|r| r.name.clone()).unwrap_or_default()),
        )
        .set("spans", spans.len() as u64)
        .set("start_us", start)
        .set("dur_us", end.saturating_sub(start))
}

/// `GET /api/traces?limit=N`: the most recently completed traces plus
/// the slowest retained outliers.
pub fn traces_json(limit: usize) -> Json {
    let limit = limit.clamp(1, 256);
    let t = tracer();
    // distinct ids, newest completion first
    let mut recent_ids: Vec<u64> = Vec::new();
    for rec in t.ring.lock().unwrap().buf.iter().rev() {
        if !recent_ids.contains(&rec.trace_id) {
            recent_ids.push(rec.trace_id);
            if recent_ids.len() >= limit {
                break;
            }
        }
    }
    // slowest retained spans, one entry per trace
    let mut slow_ids: Vec<(u64, u64)> = Vec::new();
    for rec in t.slow.lock().unwrap().buf.iter() {
        match slow_ids.iter_mut().find(|(id, _)| *id == rec.trace_id) {
            Some((_, d)) => *d = (*d).max(rec.dur_us),
            None => slow_ids.push((rec.trace_id, rec.dur_us)),
        }
    }
    slow_ids.sort_by(|a, b| b.1.cmp(&a.1));
    slow_ids.truncate(limit);
    Json::obj()
        .set(
            "recent",
            Json::Arr(recent_ids.iter().map(|&id| summarize(id)).collect()),
        )
        .set(
            "slowest",
            Json::Arr(slow_ids.iter().map(|&(id, _)| summarize(id)).collect()),
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let ctx = TraceCtx { trace_id: 0xdead_beef, span_id: 42 };
        let parsed = TraceCtx::parse(&ctx.header_value()).unwrap();
        assert_eq!(parsed, ctx);
        assert!(TraceCtx::parse("garbage").is_none());
        assert!(TraceCtx::parse("0-1").is_none(), "zero trace id rejected");
        assert!(TraceCtx::parse("").is_none());
    }

    #[test]
    fn ring_evicts_oldest() {
        let mut r = Ring::new(3);
        for i in 0..5u64 {
            r.push(SpanRecord {
                trace_id: 1,
                span_id: i + 1,
                parent_id: 0,
                name: format!("s{i}"),
                start_us: i,
                dur_us: 1,
                attrs: Vec::new(),
            });
        }
        assert_eq!(r.buf.len(), 3);
        assert_eq!(r.buf.front().unwrap().span_id, 3);
        r.set_cap(1);
        assert_eq!(r.buf.len(), 1);
        assert_eq!(r.buf.back().unwrap().span_id, 5);
    }

    #[test]
    fn nested_spans_share_a_trace() {
        arm(true);
        let trace_id;
        {
            let outer = span("outer");
            trace_id = outer.ctx().trace_id;
            assert_ne!(trace_id, 0);
            assert_eq!(current(), outer.ctx());
            {
                let inner = span("inner");
                assert_eq!(inner.ctx().trace_id, trace_id);
                assert_ne!(inner.ctx().span_id, outer.ctx().span_id);
            }
            assert_eq!(current(), outer.ctx(), "inner drop restored outer");
        }
        assert!(current().is_none());
        let j = trace_json(trace_id).expect("trace retained");
        assert_eq!(j.get("spans").unwrap().as_u64(), Some(2));
        let roots = j.get("roots").unwrap().as_arr().unwrap();
        assert_eq!(roots.len(), 1);
        let root = &roots[0];
        assert_eq!(root.get("name").unwrap().as_str(), Some("outer"));
        let kids = root.get("children").unwrap().as_arr().unwrap();
        assert_eq!(kids[0].get("name").unwrap().as_str(), Some("inner"));
    }

    #[test]
    fn cancel_restores_context_without_recording() {
        arm(true);
        let outer = span("cancel-outer");
        let trace_id = outer.ctx().trace_id;
        let inner = span("cancelled");
        inner.cancel();
        assert_eq!(current(), outer.ctx());
        drop(outer);
        let j = trace_json(trace_id).unwrap();
        assert_eq!(j.get("spans").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn remote_parent_adoption() {
        arm(true);
        let remote = TraceCtx { trace_id: next_id(tracer()), span_id: next_id(tracer()) };
        let sp = span_with_parent("adopted", remote);
        assert_eq!(sp.ctx().trace_id, remote.trace_id);
        drop(sp);
        let spans = collect_trace(remote.trace_id);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].parent_id, remote.span_id);
    }

    #[test]
    fn tag_stitches_and_is_bounded() {
        arm(true);
        let root = span("tag-root");
        let ctx = root.ctx();
        tag(7_000_001, ctx);
        assert_eq!(take_tag(7_000_001), Some(ctx));
        assert_eq!(take_tag(7_000_001), None, "tags are claim-once");
        tag(7_000_002, ctx);
        for i in 0..TAG_CAP as u64 + 10 {
            tag(8_000_000 + i, ctx);
        }
        assert!(take_tag(7_000_002).is_none(), "oldest evicted at cap");
        assert!(tracer().tags.lock().unwrap().len() <= TAG_CAP);
        tracer().tags.lock().unwrap().clear();
    }

    #[test]
    fn slow_ring_pins_outliers() {
        arm(true);
        // everything qualifies as slow under a zero threshold
        let prev = tracer().slow_us.swap(0, Ordering::Relaxed);
        let sp = span("slow-op");
        let trace_id = sp.ctx().trace_id;
        drop(sp);
        tracer().slow_us.store(prev, Ordering::Relaxed);
        let in_slow = tracer()
            .slow
            .lock()
            .unwrap()
            .buf
            .iter()
            .any(|r| r.trace_id == trace_id);
        assert!(in_slow, "slow span retained in the outlier ring");
        let j = traces_json(16);
        assert!(j.get("recent").unwrap().as_arr().unwrap().len() >= 1);
    }

    #[test]
    fn trace_id_parses_hex() {
        assert_eq!(parse_trace_id("00000000000000ff"), Some(255));
        assert_eq!(parse_trace_id("zz"), None);
        assert_eq!(parse_trace_id("0"), None);
    }
}
