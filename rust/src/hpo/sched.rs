//! Fleet-utilization model for the HPO service (the async structure of
//! paper Fig. 6).
//!
//! iDDS evaluates hyperparameter points *asynchronously*: workers pull the
//! next point the moment they finish, while the central service refines
//! the search space in the background. The pre-iDDS alternative is
//! synchronous batch rounds: propose a batch, wait for the whole batch,
//! repeat — stragglers idle the fleet.
//!
//! This discrete-event model quantifies that gap for a fleet of `workers`
//! with heavy-tailed evaluation times (grid GPUs are heterogeneous):
//! [`simulate`] returns makespan, utilization and points/hour for both
//! policies on identical sampled durations.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// one global barrier per proposal round (batch = fleet size)
    SequentialRounds,
    /// workers pull the next point immediately (iDDS)
    AsyncPull,
}

/// Throughput/utilization summary of one policy over one duration sample.
#[derive(Debug, Clone, Copy)]
pub struct FleetResult {
    pub policy: Policy,
    pub points: usize,
    pub workers: usize,
    pub makespan_s: f64,
    /// busy-time / (workers * makespan)
    pub utilization: f64,
    pub points_per_hour: f64,
}

/// Sample evaluation durations: lognormal-ish heavy tail around
/// `mean_eval_s` with heterogeneity factor per worker.
pub fn sample_durations(points: usize, mean_eval_s: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..points)
        .map(|_| {
            let z = rng.normal();
            (mean_eval_s * (0.25 * z).exp() * rng.range_f64(0.6, 1.8)).max(1.0)
        })
        .collect()
}

/// Run one policy over the given durations.
pub fn simulate(policy: Policy, durations: &[f64], workers: usize) -> FleetResult {
    assert!(workers > 0);
    let busy: f64 = durations.iter().sum();
    let makespan = match policy {
        Policy::AsyncPull => {
            // greedy list scheduling: next point to the earliest-free worker
            let mut free = vec![0.0f64; workers];
            for &d in durations {
                let w = free
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                free[w] += d;
            }
            free.iter().cloned().fold(0.0, f64::max)
        }
        Policy::SequentialRounds => {
            // rounds of `workers` points; a round ends when its slowest
            // point ends (the synchronous-batch barrier)
            durations
                .chunks(workers)
                .map(|round| round.iter().cloned().fold(0.0, f64::max))
                .sum()
        }
    };
    let utilization = busy / (workers as f64 * makespan.max(1e-9));
    FleetResult {
        policy,
        points: durations.len(),
        workers,
        makespan_s: makespan,
        utilization,
        points_per_hour: durations.len() as f64 / (makespan / 3600.0).max(1e-12),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_never_slower_than_sequential() {
        for seed in 0..10 {
            let d = sample_durations(200, 600.0, seed);
            let a = simulate(Policy::AsyncPull, &d, 16);
            let s = simulate(Policy::SequentialRounds, &d, 16);
            assert!(a.makespan_s <= s.makespan_s + 1e-9, "seed {seed}");
            assert!(a.utilization >= s.utilization - 1e-9, "seed {seed}");
        }
    }

    #[test]
    fn async_utilization_near_one_for_many_points() {
        let d = sample_durations(2000, 600.0, 1);
        let a = simulate(Policy::AsyncPull, &d, 16);
        assert!(a.utilization > 0.95, "{}", a.utilization);
    }

    #[test]
    fn sequential_pays_straggler_penalty() {
        let d = sample_durations(512, 600.0, 2);
        let s = simulate(Policy::SequentialRounds, &d, 32);
        let a = simulate(Policy::AsyncPull, &d, 32);
        // heavy-tailed rounds leave real idle time on the floor
        assert!(
            s.utilization < 0.9 * a.utilization,
            "seq {} vs async {}",
            s.utilization,
            a.utilization
        );
    }

    #[test]
    fn degenerate_cases() {
        let d = vec![10.0];
        let a = simulate(Policy::AsyncPull, &d, 4);
        assert!((a.makespan_s - 10.0).abs() < 1e-9);
        let s = simulate(Policy::SequentialRounds, &d, 4);
        assert!((s.makespan_s - 10.0).abs() < 1e-9);
        // uniform durations: policies tie
        let d = vec![5.0; 64];
        let a = simulate(Policy::AsyncPull, &d, 8);
        let s = simulate(Policy::SequentialRounds, &d, 8);
        assert!((a.makespan_s - s.makespan_s).abs() < 1e-9);
    }
}
