//! Hyperparameter Optimization service (paper section 3.2, Fig. 6).
//!
//! iDDS "centrally scans the search space using advanced optimization
//! algorithms to generate hyperparameter points, while hyperparameter
//! points are asynchronously evaluated on remote GPU resources". Here:
//!
//! * the **proposal step** runs the AOT `gp_propose` artifact (GP
//!   surrogate + Expected Improvement, Pallas kernels inside) through the
//!   PJRT runtime — [`BayesOpt`];
//! * the **evaluation step** runs the AOT `mlp_train` payload — the stand-
//!   in for remote GPU training (substitution table in DESIGN.md);
//! * [`sched`] models the async-vs-sequential utilization comparison as a
//!   discrete-event simulation over a worker fleet with a realistic
//!   evaluation-time distribution (wall-clock on one CPU box cannot show
//!   fleet utilization).

pub mod sched;
pub mod space;

use anyhow::{Context, Result};

use crate::runtime::EngineHandle;
use crate::util::rng::Rng;

pub use space::{ParamDim, SearchSpace};

/// Point-proposal strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Uniform sampling of the normalized search box (the baseline).
    Random,
    /// GP surrogate + EI through the AOT artifact.
    Bayesian,
}

/// One evaluated hyperparameter point.
#[derive(Debug, Clone)]
pub struct Evaluated {
    /// normalized [0,1]^d coordinates
    pub x: Vec<f64>,
    pub loss: f64,
}

/// Result of one HPO run.
#[derive(Debug, Clone)]
pub struct HpoRunResult {
    pub strategy: Strategy,
    pub history: Vec<Evaluated>,
    /// best loss after k+1 evaluations (convergence curve)
    pub best_curve: Vec<f64>,
}

impl HpoRunResult {
    /// Best loss found over the whole run.
    pub fn best(&self) -> f64 {
        *self.best_curve.last().unwrap_or(&f64::INFINITY)
    }

    /// Evaluations needed to reach `target`; None if never reached.
    pub fn evals_to_reach(&self, target: f64) -> Option<usize> {
        self.best_curve.iter().position(|&b| b <= target).map(|i| i + 1)
    }
}

/// The Bayesian-optimization loop driving the AOT artifacts.
pub struct BayesOpt {
    engine: EngineHandle,
    pub space: SearchSpace,
    n_obs_cap: usize,
    dim_pad: usize,
    n_cand: usize,
    /// GP hyperparameters: [log lengthscale, log sigma_f, log noise, xi]
    pub gp_params: [f32; 4],
}

impl BayesOpt {
    /// Bind the loop to the runtime's `gp_propose` artifact; fails when
    /// the search space is wider than the artifact's compiled dimension.
    pub fn new(engine: EngineHandle, space: SearchSpace) -> Result<BayesOpt> {
        let spec = engine.spec("gp_propose").context("gp_propose artifact")?;
        let n_obs_cap = spec.consts["n_obs"] as usize;
        let dim_pad = spec.consts["dim"] as usize;
        let n_cand = spec.consts["n_cand"] as usize;
        anyhow::ensure!(
            space.dims.len() <= dim_pad,
            "search space has {} dims, artifact supports {}",
            space.dims.len(),
            dim_pad
        );
        Ok(BayesOpt {
            engine,
            space,
            n_obs_cap,
            dim_pad,
            n_cand,
            gp_params: [(0.3f32).ln(), 0.0, (1e-4f32).ln(), 0.01],
        })
    }

    /// Propose the next point: sample a candidate batch, score with the GP
    /// artifact, return the EI-argmax (normalized coordinates).
    pub fn propose(&self, history: &[Evaluated], rng: &mut Rng) -> Result<Vec<f64>> {
        let d = self.space.dims.len();
        // candidate batch (uniform in normalized space)
        let mut x_cand = vec![0.0f32; self.n_cand * self.dim_pad];
        for c in 0..self.n_cand {
            for j in 0..d {
                x_cand[c * self.dim_pad + j] = rng.f64() as f32;
            }
        }
        if history.is_empty() {
            // no surrogate yet: return the first candidate (uniform)
            return Ok((0..d).map(|j| x_cand[j] as f64).collect());
        }
        // observation window: most recent n_obs_cap points
        let start = history.len().saturating_sub(self.n_obs_cap);
        let window = &history[start..];
        let mut x_obs = vec![0.0f32; self.n_obs_cap * self.dim_pad];
        let mut y_obs = vec![0.0f32; self.n_obs_cap];
        let mut mask = vec![0.0f32; self.n_obs_cap];
        // normalize losses to zero-mean unit-ish scale for GP stability
        let mean = window.iter().map(|e| e.loss).sum::<f64>() / window.len() as f64;
        let sd = (window
            .iter()
            .map(|e| (e.loss - mean).powi(2))
            .sum::<f64>()
            / window.len() as f64)
            .sqrt()
            .max(1e-9);
        for (i, ev) in window.iter().enumerate() {
            for j in 0..d {
                x_obs[i * self.dim_pad + j] = ev.x[j] as f32;
            }
            y_obs[i] = ((ev.loss - mean) / sd) as f32;
            mask[i] = 1.0;
        }
        let prop = self
            .engine
            .gp_propose(&x_obs, &y_obs, &mask, &x_cand, &self.gp_params)?;
        let best = prop
            .ei
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0);
        Ok((0..d)
            .map(|j| x_cand[best * self.dim_pad + j] as f64)
            .collect())
    }

    /// Evaluate a normalized point with the training payload; `seed`
    /// fixes the payload dataset across points of one task.
    pub fn evaluate(&self, x_norm: &[f64], seed: u64) -> Result<f64> {
        let phys = self.space.denormalize(x_norm);
        anyhow::ensure!(phys.len() == 4, "mlp payload expects 4 hyperparameters");
        let hp = [phys[0] as f32, phys[1] as f32, phys[2] as f32, phys[3] as f32];
        let d = payload_data(&self.engine, seed)?;
        let out = self.engine.mlp_train(
            &hp, &d.xtr, &d.ytr, &d.xval, &d.yval, &d.w1, &d.b1, &d.w2, &d.b2,
        )?;
        let loss = out.val_loss as f64;
        Ok(if loss.is_finite() { loss } else { 1e6 })
    }

    /// Run a full HPO task of `n_points` evaluations.
    pub fn run(&self, strategy: Strategy, n_points: usize, seed: u64) -> Result<HpoRunResult> {
        let mut rng = Rng::new(seed ^ 0x5EED);
        let d = self.space.dims.len();
        let mut history: Vec<Evaluated> = Vec::new();
        let mut best_curve = Vec::new();
        let mut best = f64::INFINITY;
        for _ in 0..n_points {
            let x = match strategy {
                Strategy::Random => (0..d).map(|_| rng.f64()).collect::<Vec<f64>>(),
                Strategy::Bayesian => self.propose(&history, &mut rng)?,
            };
            let loss = self.evaluate(&x, seed)?;
            best = best.min(loss);
            best_curve.push(best);
            history.push(Evaluated { x, loss });
        }
        Ok(HpoRunResult {
            strategy,
            history,
            best_curve,
        })
    }
}

pub(crate) struct PayloadData {
    pub xtr: Vec<f32>,
    pub ytr: Vec<f32>,
    pub xval: Vec<f32>,
    pub yval: Vec<f32>,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

/// Deterministic synthetic payload dataset (same generator as the daemon
/// executor so service-mode and library-mode agree).
pub(crate) fn payload_data(engine: &EngineHandle, seed: u64) -> Result<PayloadData> {
    let spec = engine.spec("mlp_train").context("mlp_train spec")?;
    let train_n = spec.consts["train_n"] as usize;
    let val_n = spec.consts["val_n"] as usize;
    let in_dim = spec.consts["in_dim"] as usize;
    let hidden = spec.consts["hidden"] as usize;
    let mut rng = Rng::new(seed);
    let mut mk = |n: usize, scale: f64| -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    };
    let xtr = mk(train_n * in_dim, 1.0);
    let xval = mk(val_n * in_dim, 1.0);
    let w1 = mk(in_dim * hidden, 0.3);
    let w2 = mk(hidden, 0.3);
    let target = |x: &[f32], i: usize| (x[i * in_dim] * 2.0).sin() + 0.5 * x[i * in_dim + 1];
    let ytr: Vec<f32> = (0..train_n).map(|i| target(&xtr, i)).collect();
    let yval: Vec<f32> = (0..val_n).map(|i| target(&xval, i)).collect();
    Ok(PayloadData {
        xtr,
        ytr,
        xval,
        yval,
        w1,
        b1: vec![0.0; hidden],
        w2,
        b2: vec![0.0; 1],
    })
}

/// The standard 4-dim payload search space (log lr, momentum, log l2,
/// log clip) matching the `mlp_train` artifact.
pub fn payload_space() -> SearchSpace {
    SearchSpace::new(vec![
        ParamDim::new("log_lr", (1e-5f64).ln(), (1.0f64).ln()),
        ParamDim::new("momentum", 0.0, 0.99),
        ParamDim::new("log_l2", (1e-8f64).ln(), (1e-2f64).ln()),
        ParamDim::new("log_clip", (0.1f64).ln(), (10.0f64).ln()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifacts_dir;

    fn opt() -> Option<BayesOpt> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts missing; run `make artifacts`");
            return None;
        }
        let engine = EngineHandle::start(&dir).unwrap();
        Some(BayesOpt::new(engine, payload_space()).unwrap())
    }

    #[test]
    fn random_run_produces_monotone_best_curve() {
        let Some(o) = opt() else { return };
        let r = o.run(Strategy::Random, 6, 3).unwrap();
        assert_eq!(r.best_curve.len(), 6);
        assert!(r.best_curve.windows(2).all(|w| w[1] <= w[0]));
        assert!(r.best().is_finite());
    }

    #[test]
    fn bayesian_proposals_stay_in_unit_box() {
        let Some(o) = opt() else { return };
        let mut rng = Rng::new(5);
        let mut history = Vec::new();
        for i in 0..4 {
            let x = o.propose(&history, &mut rng).unwrap();
            assert_eq!(x.len(), 4);
            assert!(x.iter().all(|v| (0.0..=1.0).contains(v)), "{x:?}");
            history.push(Evaluated {
                x,
                loss: 1.0 / (i + 1) as f64,
            });
        }
    }

    #[test]
    fn fig6_shape_bayesian_beats_random_on_budget() {
        let Some(o) = opt() else { return };
        let n = 10;
        // average over two seeds to damp noise while staying fast
        let mut bayes = 0.0;
        let mut rand = 0.0;
        for seed in [11, 17] {
            bayes += o.run(Strategy::Bayesian, n, seed).unwrap().best();
            rand += o.run(Strategy::Random, n, seed).unwrap().best();
        }
        // Bayesian should be no worse (usually strictly better)
        assert!(bayes <= rand * 1.05 + 1e-9, "bayes {bayes} vs random {rand}");
    }

    #[test]
    fn evaluate_maps_space_correctly() {
        let Some(o) = opt() else { return };
        // mid-box point must produce a finite loss
        let loss = o.evaluate(&[0.5, 0.5, 0.5, 0.5], 1).unwrap();
        assert!(loss.is_finite() && loss >= 0.0);
    }
}
