//! Hyperparameter search spaces: named continuous dimensions with
//! normalize/denormalize between physical ranges and the unit box the GP
//! surrogate operates in.

use crate::util::json::Json;

/// One continuous hyperparameter dimension with its physical range.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamDim {
    pub name: String,
    pub lo: f64,
    pub hi: f64,
}

impl ParamDim {
    /// Build a dimension; panics unless `hi > lo` (caller bug).
    pub fn new(name: &str, lo: f64, hi: f64) -> ParamDim {
        assert!(hi > lo, "dim '{name}': hi must exceed lo");
        ParamDim {
            name: name.to_string(),
            lo,
            hi,
        }
    }
}

/// An ordered set of dimensions — the box the proposal step samples.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SearchSpace {
    pub dims: Vec<ParamDim>,
}

impl SearchSpace {
    pub fn new(dims: Vec<ParamDim>) -> SearchSpace {
        SearchSpace { dims }
    }

    /// unit-box → physical coordinates (clamped).
    pub fn denormalize(&self, x: &[f64]) -> Vec<f64> {
        self.dims
            .iter()
            .zip(x.iter())
            .map(|(d, v)| d.lo + v.clamp(0.0, 1.0) * (d.hi - d.lo))
            .collect()
    }

    /// physical → unit-box coordinates (clamped).
    pub fn normalize(&self, phys: &[f64]) -> Vec<f64> {
        self.dims
            .iter()
            .zip(phys.iter())
            .map(|(d, v)| ((v - d.lo) / (d.hi - d.lo)).clamp(0.0, 1.0))
            .collect()
    }

    /// Serialize for request payloads (clients ship spaces as JSON).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.dims
                .iter()
                .map(|d| {
                    Json::obj()
                        .set("name", d.name.as_str())
                        .set("lo", d.lo)
                        .set("hi", d.hi)
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SearchSpace> {
        use anyhow::Context;
        let arr = j.as_arr().context("search space must be an array")?;
        let dims = arr
            .iter()
            .map(|d| {
                Ok(ParamDim::new(
                    d.get("name").and_then(|v| v.as_str()).context("dim.name")?,
                    d.get("lo").and_then(|v| v.as_f64()).context("dim.lo")?,
                    d.get("hi").and_then(|v| v.as_f64()).context("dim.hi")?,
                ))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(SearchSpace::new(dims))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> SearchSpace {
        SearchSpace::new(vec![
            ParamDim::new("a", -10.0, 10.0),
            ParamDim::new("b", 0.0, 1.0),
        ])
    }

    #[test]
    fn roundtrip_normalize() {
        let s = space();
        let phys = vec![5.0, 0.25];
        let n = s.normalize(&phys);
        assert_eq!(n, vec![0.75, 0.25]);
        assert_eq!(s.denormalize(&n), phys);
    }

    #[test]
    fn clamping() {
        let s = space();
        assert_eq!(s.denormalize(&[-0.5, 2.0]), vec![-10.0, 1.0]);
        assert_eq!(s.normalize(&[-100.0, 100.0]), vec![0.0, 1.0]);
    }

    #[test]
    fn json_roundtrip() {
        let s = space();
        let back = SearchSpace::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    #[should_panic(expected = "hi must exceed lo")]
    fn rejects_empty_range() {
        ParamDim::new("x", 1.0, 1.0);
    }
}
