//! Work leases for the distributed worker fleet (ISSUE 8 tentpole).
//!
//! The paper's iDDS never executes payloads itself — it hands processing
//! to a fleet of backends. This module is the head's side of that
//! protocol: a [`WorkerRegistry`] through which remote worker processes
//! register capabilities, *lease* queued work, renew their leases by
//! heartbeat, and report completions idempotently.
//!
//! # A lease IS a broker in-flight delivery
//!
//! There is no second timeout machine. Each work kind gets one **shared**
//! claim queue: a single durable subscription on the topic
//! `idds.work.queue.<kind>` that *all* workers poll through the registry.
//! Because the broker's in-flight set blocks redelivery of a polled
//! message until its deadline passes, each message is held by exactly one
//! worker at a time — work-queue semantics built from the existing
//! pub/sub primitives:
//!
//! * **claim**   = [`Broker::poll`] on the shared subscription,
//! * **renew**   = [`Broker::renew`] (deadline → now + timeout),
//! * **release** = do nothing and let the deadline expire — the next
//!   poll redelivers the message to whichever worker asks first,
//! * **settle**  = [`Broker::ack`], once the Carrier has consumed the
//!   buffered result.
//!
//! Durability rides along for free: the subscription, its backlog and
//! the in-flight set are exactly the state PR 4 made durable
//! (`BrokerSubscribe`/`BrokerPublish`/`BrokerDeliver`/`BrokerAck`), so a
//! head restart recovers every queued and leased message, re-arming
//! lease deadlines at `now + timeout` just like any other in-flight
//! delivery. No new [`crate::persist::PersistEvent`] variants exist for
//! the worker protocol.
//!
//! # What is deliberately NOT durable
//!
//! The registry itself — worker ids, epochs, lease *bindings* (which
//! worker holds which message) and buffered results — is in-memory.
//! After a head restart workers simply re-register (same name → same id,
//! epoch + 1) and lease again; completions referencing unknown bindings
//! are no-ops; the *work itself* survives in the broker. Losing a
//! binding can only delay a message by one lease timeout, never lose it.
//!
//! # Idempotent completion
//!
//! A completion is accepted iff its (worker, epoch, lease, handle) tuple
//! matches the registry's *current* binding for that lease and the
//! worker's *current* epoch. Everything else — duplicate reports,
//! reports from a worker whose lease expired and was re-leased
//! elsewhere, reports from a previous epoch of a rejoined worker — falls
//! through as a rejected no-op. Accepted results are buffered under the
//! executor handle; the Carrier's poll consumes the buffer and only then
//! acks the broker message, so a head crash between completion and
//! Carrier-poll redelivers the work (at-least-once) instead of dropping
//! the result on the floor.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::metrics::Registry;
use crate::util::clock::Clock;
use crate::util::json::Json;

use super::{Broker, MsgId, SubId};

/// Topic prefix for per-kind shared claim queues.
pub const QUEUE_TOPIC_PREFIX: &str = "idds.work.queue.";

fn queue_topic(kind: &str) -> String {
    format!("{QUEUE_TOPIC_PREFIX}{kind}")
}

/// One granted lease, as returned to a worker.
#[derive(Debug, Clone)]
pub struct LeaseGrant {
    /// Lease id — the broker message id; quote it in heartbeats and the
    /// completion report.
    pub lease: MsgId,
    /// Executor handle minted at submit time; echoed in the completion so
    /// the head can match the result to the waiting processing.
    pub handle: u64,
    pub kind: String,
    /// The serialized Work (template params under `params`).
    pub work: Json,
    /// True when a previous holder's lease expired — the work may have
    /// been partially executed before.
    pub redelivered: bool,
}

struct WorkerInfo {
    name: String,
    epoch: u64,
    kinds: Vec<String>,
    registered_at: f64,
    last_seen: f64,
    /// lifetime counters, for `/api/health`
    leased: u64,
    completed: u64,
}

/// Current holder of one in-flight claim-queue message. Overwritten
/// whenever the message is (re)leased, which is what invalidates every
/// stale holder's heartbeat and completion in one move.
struct Lease {
    worker: u64,
    epoch: u64,
    handle: u64,
    kind: String,
    sub: SubId,
}

/// A completion accepted but not yet consumed by the Carrier's poll. The
/// broker ack is deferred to consumption so the message redelivers if the
/// head dies with the result still buffered in memory.
struct Done {
    msg: MsgId,
    sub: SubId,
    result: Json,
}

#[derive(Default)]
struct Inner {
    workers: HashMap<u64, WorkerInfo>,
    names: HashMap<String, u64>,
    /// kind → the shared claim-queue subscription.
    subs: HashMap<String, SubId>,
    leases: HashMap<MsgId, Lease>,
    /// executor handle → buffered completion.
    results: HashMap<u64, Done>,
}

/// Head-side state of the worker protocol. Clone-shareable; clones share
/// all registry state. One registry per head process, attached to the
/// REST layer (worker routes) and to the Carrier's `RemoteExecutor`s.
#[derive(Clone)]
pub struct WorkerRegistry {
    broker: Broker,
    clock: Arc<dyn Clock>,
    metrics: Registry,
    inner: Arc<Mutex<Inner>>,
}

impl WorkerRegistry {
    pub fn new(broker: Broker, clock: Arc<dyn Clock>, metrics: Registry) -> Self {
        WorkerRegistry {
            broker,
            clock,
            metrics,
            inner: Arc::new(Mutex::new(Inner::default())),
        }
    }

    /// The lease timeout workers must heartbeat within — the broker's
    /// redelivery timeout, because a lease *is* an in-flight delivery.
    pub fn lease_timeout(&self) -> f64 {
        self.broker.redelivery_timeout()
    }

    /// Resolve (or create) the shared claim-queue subscription for a
    /// kind. After a head restart the durable subscription already exists
    /// in the recovered broker — adopt the lowest-id one instead of
    /// subscribing anew, which would orphan the recovered backlog.
    fn ensure_queue(inner: &mut Inner, broker: &Broker, kind: &str) -> SubId {
        if let Some(&sub) = inner.subs.get(kind) {
            return sub;
        }
        let topic = queue_topic(kind);
        let sub = match broker.subscriptions_of_topic(&topic).first() {
            Some(&recovered) => recovered,
            None => broker.subscribe(&topic),
        };
        inner.subs.insert(kind.to_string(), sub);
        sub
    }

    /// Enqueue one work payload on a kind's claim queue — the
    /// `RemoteExecutor` submit path. Ensures the shared subscription
    /// exists *before* publishing (a publish with no subscribers is
    /// dropped by design).
    pub fn enqueue(&self, kind: &str, handle: u64, work: &Json) {
        let mut inner = self.inner.lock().unwrap();
        Self::ensure_queue(&mut inner, &self.broker, kind);
        drop(inner);
        self.broker.publish(
            &queue_topic(kind),
            Json::obj().set("handle", handle).set("work", work.clone()),
        );
        self.metrics.counter("workers.enqueued").inc();
    }

    /// Register a worker (or re-register after a crash). Same name →
    /// same worker id with a bumped epoch; every lease binding taken
    /// under the previous epoch is dead from this moment (its messages
    /// redeliver after their deadlines). Returns `(worker_id, epoch)`.
    pub fn register(&self, name: &str, kinds: &[String]) -> (u64, u64) {
        let now = self.clock.now();
        let mut inner = self.inner.lock().unwrap();
        for kind in kinds {
            Self::ensure_queue(&mut inner, &self.broker, kind);
        }
        let id = match inner.names.get(name).copied() {
            Some(id) => id,
            None => {
                let id = crate::util::next_id();
                inner.names.insert(name.to_string(), id);
                inner.workers.insert(
                    id,
                    WorkerInfo {
                        name: name.to_string(),
                        epoch: 0,
                        kinds: Vec::new(),
                        registered_at: now,
                        last_seen: now,
                        leased: 0,
                        completed: 0,
                    },
                );
                id
            }
        };
        let w = inner.workers.get_mut(&id).expect("names/workers in sync");
        w.epoch += 1;
        w.kinds = kinds.to_vec();
        w.registered_at = now;
        w.last_seen = now;
        let epoch = w.epoch;
        drop(inner);
        self.metrics.counter("workers.registered").inc();
        (id, epoch)
    }

    /// Lease up to `max` messages across the worker's kinds. `None` for
    /// an unknown worker id (the REST layer turns that into a 404 — the
    /// worker must re-register). Malformed queue payloads are acked away.
    pub fn lease(&self, worker_id: u64, max: usize) -> Option<Vec<LeaseGrant>> {
        let mut inner = self.inner.lock().unwrap();
        let w = inner.workers.get_mut(&worker_id)?;
        w.last_seen = self.clock.now();
        let epoch = w.epoch;
        let kinds = w.kinds.clone();
        let mut grants = Vec::new();
        for kind in &kinds {
            if grants.len() >= max {
                break;
            }
            let sub = Self::ensure_queue(&mut inner, &self.broker, kind);
            for d in self.broker.poll(sub, max - grants.len()) {
                let (handle, work) = match (
                    d.payload.get("handle").and_then(Json::as_u64),
                    d.payload.get("work"),
                ) {
                    (Some(h), Some(wk)) => (h, wk.clone()),
                    _ => {
                        self.broker.ack(sub, d.id); // foreign junk: drop it
                        continue;
                    }
                };
                // (Re)binding the lease to this worker is what invalidates
                // any previous holder: their epoch/worker no longer match.
                inner.leases.insert(
                    d.id,
                    Lease { worker: worker_id, epoch, handle, kind: kind.clone(), sub },
                );
                if d.redelivered {
                    self.metrics.counter("workers.leases_redelivered").inc();
                }
                grants.push(LeaseGrant {
                    lease: d.id,
                    handle,
                    kind: kind.clone(),
                    work,
                    redelivered: d.redelivered,
                });
            }
        }
        if let Some(w) = inner.workers.get_mut(&worker_id) {
            w.leased += grants.len() as u64;
        }
        self.metrics.counter("workers.leases_granted").add(grants.len() as u64);
        Some(grants)
    }

    /// Heartbeat: extend the deadline of every lease this worker still
    /// holds. Returns how many renewed — a lease that expired and was
    /// re-leased elsewhere (or was completed) silently drops out, telling
    /// the worker its claim is gone. `None` for an unknown worker.
    pub fn heartbeat(&self, worker_id: u64, lease_ids: &[MsgId]) -> Option<usize> {
        let mut inner = self.inner.lock().unwrap();
        let w = inner.workers.get_mut(&worker_id)?;
        w.last_seen = self.clock.now();
        let epoch = w.epoch;
        let mut renewed = 0;
        for &id in lease_ids {
            let Some(l) = inner.leases.get(&id) else { continue };
            if l.worker != worker_id || l.epoch != epoch {
                continue; // stale holder: never resurrect its claim
            }
            if self.broker.renew(l.sub, id) {
                renewed += 1;
            }
        }
        self.metrics.counter("workers.heartbeats_renewed").add(renewed as u64);
        Some(renewed)
    }

    /// Report a completion. Accepted iff `(worker, epoch, lease, handle)`
    /// matches the current binding *and* the worker's current epoch —
    /// anything else (duplicate report, expired-and-re-leased claim,
    /// previous epoch of a rejoined worker, unknown worker after a head
    /// restart) is a rejected no-op, which is what makes worker-side
    /// retries of this call safe. The result is buffered; the broker ack
    /// waits for [`WorkerRegistry::take_result`].
    pub fn complete(
        &self,
        worker_id: u64,
        epoch: u64,
        lease: MsgId,
        handle: u64,
        result: Json,
    ) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let current_epoch = match inner.workers.get(&worker_id) {
            Some(w) => w.epoch,
            None => {
                self.metrics.counter("workers.completions_rejected").inc();
                return false;
            }
        };
        let ok = matches!(
            inner.leases.get(&lease),
            Some(l)
                if l.worker == worker_id
                    && l.epoch == epoch
                    && l.handle == handle
                    && epoch == current_epoch
        );
        if !ok {
            self.metrics.counter("workers.completions_rejected").inc();
            return false;
        }
        let l = inner.leases.remove(&lease).unwrap();
        inner.results.insert(handle, Done { msg: lease, sub: l.sub, result });
        if let Some(w) = inner.workers.get_mut(&worker_id) {
            w.completed += 1;
            w.last_seen = self.clock.now();
        }
        self.metrics.counter("workers.completions_accepted").inc();
        true
    }

    /// Consume a buffered completion — the `RemoteExecutor` poll path.
    /// Acks the underlying broker message, which is the durable point of
    /// no return: from here the work can never redeliver.
    pub fn take_result(&self, handle: u64) -> Option<Json> {
        let done = self.inner.lock().unwrap().results.remove(&handle)?;
        self.broker.ack(done.sub, done.msg);
        Some(done.result)
    }

    /// The `workers` section of `/api/health`: per-worker rows plus
    /// fleet totals and queue backlogs.
    pub fn health_json(&self) -> Json {
        let now = self.clock.now();
        let inner = self.inner.lock().unwrap();
        let mut active_per_worker: HashMap<u64, u64> = HashMap::new();
        for l in inner.leases.values() {
            *active_per_worker.entry(l.worker).or_insert(0) += 1;
        }
        let mut ids: Vec<&u64> = inner.workers.keys().collect();
        ids.sort_unstable();
        let rows: Vec<Json> = ids
            .iter()
            .map(|id| {
                let w = &inner.workers[id];
                Json::obj()
                    .set("id", **id)
                    .set("name", w.name.as_str())
                    .set("epoch", w.epoch)
                    .set(
                        "kinds",
                        Json::Arr(w.kinds.iter().map(|k| Json::Str(k.clone())).collect()),
                    )
                    .set("active_leases", active_per_worker.get(id).copied().unwrap_or(0))
                    .set("leased_total", w.leased)
                    .set("completed_total", w.completed)
                    .set("registered_age_s", now - w.registered_at)
                    .set("last_seen_age_s", now - w.last_seen)
            })
            .collect();
        let mut kinds: Vec<&String> = inner.subs.keys().collect();
        kinds.sort();
        let queues: Vec<Json> = kinds
            .iter()
            .map(|kind| {
                let sub = inner.subs[*kind];
                Json::obj()
                    .set("kind", kind.as_str())
                    .set("backlog", self.broker.backlog(sub) as u64)
            })
            .collect();
        Json::obj()
            .set("lease_timeout_s", self.lease_timeout())
            .set("registered", inner.workers.len() as u64)
            .set("active_leases", inner.leases.len() as u64)
            .set("buffered_results", inner.results.len() as u64)
            .set("workers", Json::Arr(rows))
            .set("queues", Json::Arr(queues))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::SimClock;

    fn registry(timeout: f64) -> (WorkerRegistry, Arc<SimClock>) {
        let clock = SimClock::new();
        let broker =
            Broker::new(clock.clone() as Arc<dyn Clock>).with_redelivery_timeout(timeout);
        (WorkerRegistry::new(broker, clock.clone(), Registry::default()), clock)
    }

    fn work(x: f64) -> Json {
        Json::obj().set("params", Json::obj().set("x", x))
    }

    #[test]
    fn register_lease_complete_roundtrip() {
        let (r, _clock) = registry(10.0);
        let (w, epoch) = r.register("alpha", &["Noop".into()]);
        assert_eq!(epoch, 1);
        r.enqueue("Noop", 77, &work(1.0));
        let grants = r.lease(w, 10).unwrap();
        assert_eq!(grants.len(), 1);
        assert_eq!(grants[0].handle, 77);
        assert!(!grants[0].redelivered);
        assert_eq!(grants[0].work.get_path(&["params", "x"]).unwrap().as_f64(), Some(1.0));
        assert!(r.complete(w, epoch, grants[0].lease, 77, Json::obj().set("ok", true)));
        let res = r.take_result(77).unwrap();
        assert_eq!(res.get("ok").unwrap().as_bool(), Some(true));
        assert!(r.take_result(77).is_none(), "result consumed");
    }

    #[test]
    fn reregister_same_name_keeps_id_and_bumps_epoch() {
        let (r, _clock) = registry(10.0);
        let (w1, e1) = r.register("alpha", &["Noop".into()]);
        let (w2, e2) = r.register("alpha", &["Noop".into()]);
        assert_eq!(w1, w2, "same name, same id");
        assert_eq!(e2, e1 + 1, "rejoin bumps the epoch");
        let (w3, e3) = r.register("beta", &["Noop".into()]);
        assert_ne!(w3, w1);
        assert_eq!(e3, 1);
    }

    #[test]
    fn heartbeat_renewal_extends_deadline() {
        let (r, clock) = registry(10.0);
        let (w, _e) = r.register("alpha", &["Noop".into()]);
        r.enqueue("Noop", 1, &work(1.0));
        let g = r.lease(w, 10).unwrap();
        // heartbeat at t=8 pushes the deadline to 18; without it the lease
        // would expire at 10
        clock.advance_by(8.0);
        assert_eq!(r.heartbeat(w, &[g[0].lease]).unwrap(), 1);
        clock.advance_by(9.0); // t=17 < 18: still held
        let (w2, _e2) = r.register("beta", &["Noop".into()]);
        assert!(r.lease(w2, 10).unwrap().is_empty(), "lease still held by alpha");
        clock.advance_by(2.0); // t=19 > 18: expired
        let g2 = r.lease(w2, 10).unwrap();
        assert_eq!(g2.len(), 1);
        assert!(g2[0].redelivered);
    }

    #[test]
    fn expiry_reclaims_exactly_once_under_heartbeat_race() {
        // Round 1: expiry wins — B leases the expired message, then A's
        // late heartbeat must NOT renew (its binding is gone).
        let (r, clock) = registry(10.0);
        let (a, ea) = r.register("a", &["Noop".into()]);
        let (b, _eb) = r.register("b", &["Noop".into()]);
        r.enqueue("Noop", 1, &work(1.0));
        let ga = r.lease(a, 10).unwrap();
        clock.advance_by(11.0);
        let gb = r.lease(b, 10).unwrap();
        assert_eq!(gb.len(), 1, "expired lease reclaimed");
        assert_eq!(gb[0].lease, ga[0].lease, "same message");
        assert_eq!(r.heartbeat(a, &[ga[0].lease]).unwrap(), 0, "stale holder cannot renew");
        assert!(r.lease(a, 10).unwrap().is_empty(), "no double reclaim");
        assert!(
            !r.complete(a, ea, ga[0].lease, ga[0].handle, Json::obj()),
            "stale holder cannot complete"
        );

        // Round 2: heartbeat wins — renewal lands before anyone re-polls,
        // so the original holder keeps the claim past the old deadline.
        let (r, clock) = registry(10.0);
        let (a, ea) = r.register("a", &["Noop".into()]);
        let (b, _eb) = r.register("b", &["Noop".into()]);
        r.enqueue("Noop", 2, &work(2.0));
        let ga = r.lease(a, 10).unwrap();
        clock.advance_by(11.0); // past the deadline, but nobody polled yet
        assert_eq!(
            r.heartbeat(a, &[ga[0].lease]).unwrap(),
            1,
            "un-repolled expiry: the holder reclaims its own lease"
        );
        assert!(r.lease(b, 10).unwrap().is_empty(), "renewal landed first");
        assert!(r.complete(a, ea, ga[0].lease, ga[0].handle, Json::obj()));
    }

    #[test]
    fn stale_epoch_completion_rejected() {
        let (r, _clock) = registry(10.0);
        let (w, e1) = r.register("alpha", &["Noop".into()]);
        r.enqueue("Noop", 5, &work(1.0));
        let g = r.lease(w, 10).unwrap();
        // the worker dies and rejoins: epoch bumps, old leases are dead
        let (w2, e2) = r.register("alpha", &["Noop".into()]);
        assert_eq!(w, w2);
        assert!(
            !r.complete(w, e1, g[0].lease, g[0].handle, Json::obj()),
            "completion from the previous epoch is a no-op"
        );
        assert!(
            !r.complete(w, e2, g[0].lease, g[0].handle, Json::obj()),
            "claiming the new epoch against an old binding is a no-op too"
        );
        assert!(r.take_result(g[0].handle).is_none(), "nothing buffered");
    }

    #[test]
    fn duplicate_completion_is_idempotent() {
        let (r, _clock) = registry(10.0);
        let (w, e) = r.register("alpha", &["Noop".into()]);
        r.enqueue("Noop", 9, &work(1.0));
        let g = r.lease(w, 10).unwrap();
        assert!(r.complete(w, e, g[0].lease, 9, Json::obj().set("n", 1u64)));
        assert!(!r.complete(w, e, g[0].lease, 9, Json::obj().set("n", 2u64)), "duplicate no-op");
        let res = r.take_result(9).unwrap();
        assert_eq!(res.get("n").unwrap().as_u64(), Some(1), "first result wins");
        // ... and the message is settled: nothing left to lease
        let (w2, _e2) = r.register("beta", &["Noop".into()]);
        assert!(r.lease(w2, 10).unwrap().is_empty());
    }

    #[test]
    fn completion_with_wrong_handle_or_worker_rejected() {
        let (r, _clock) = registry(10.0);
        let (a, ea) = r.register("a", &["Noop".into()]);
        let (b, eb) = r.register("b", &["Noop".into()]);
        r.enqueue("Noop", 3, &work(1.0));
        let g = r.lease(a, 10).unwrap();
        assert!(!r.complete(b, eb, g[0].lease, 3, Json::obj()), "not b's lease");
        assert!(!r.complete(a, ea, g[0].lease, 999, Json::obj()), "wrong handle");
        assert!(!r.complete(12345, 1, g[0].lease, 3, Json::obj()), "unknown worker");
        assert!(r.complete(a, ea, g[0].lease, 3, Json::obj()), "the real one still lands");
    }

    #[test]
    fn unacked_result_keeps_message_leasable_until_taken() {
        // A completion buffers the result but does NOT ack: until the
        // Carrier consumes it, the message is still in flight and would
        // redeliver if the deadline passed (head-crash window). Once
        // taken, the ack settles it for good.
        let (r, clock) = registry(10.0);
        let (w, e) = r.register("alpha", &["Noop".into()]);
        r.enqueue("Noop", 4, &work(1.0));
        let g = r.lease(w, 10).unwrap();
        assert!(r.complete(w, e, g[0].lease, 4, Json::obj()));
        clock.advance_by(11.0);
        let (w2, _e2) = r.register("beta", &["Noop".into()]);
        let g2 = r.lease(w2, 10).unwrap();
        assert_eq!(g2.len(), 1, "un-consumed completion still redelivers after timeout");
        assert!(g2[0].redelivered);
        // the buffered result is still there; consuming it acks
        assert!(r.take_result(4).is_some());
        clock.advance_by(11.0);
        assert!(r.lease(w, 10).unwrap().is_empty(), "acked: gone for good");
    }

    #[test]
    fn leases_route_by_kind() {
        let (r, _clock) = registry(10.0);
        let (noop_w, _) = r.register("n", &["Noop".into()]);
        let (dec_w, _) = r.register("d", &["Decision".into()]);
        r.enqueue("Noop", 1, &work(1.0));
        r.enqueue("Decision", 2, &work(2.0));
        let gn = r.lease(noop_w, 10).unwrap();
        assert_eq!(gn.len(), 1);
        assert_eq!(gn[0].kind, "Noop");
        let gd = r.lease(dec_w, 10).unwrap();
        assert_eq!(gd.len(), 1);
        assert_eq!(gd[0].kind, "Decision");
    }

    #[test]
    fn lease_respects_max() {
        let (r, _clock) = registry(10.0);
        let (w, _) = r.register("alpha", &["Noop".into()]);
        for h in 0..5 {
            r.enqueue("Noop", h, &work(h as f64));
        }
        assert_eq!(r.lease(w, 2).unwrap().len(), 2);
        assert_eq!(r.lease(w, 10).unwrap().len(), 3);
    }

    #[test]
    fn unknown_worker_gets_none() {
        let (r, _clock) = registry(10.0);
        assert!(r.lease(42, 10).is_none());
        assert!(r.heartbeat(42, &[1]).is_none());
    }

    #[test]
    fn registry_readopts_recovered_subscription() {
        // Simulate a head restart: the durable broker still holds the
        // claim-queue subscription and its backlog; a fresh registry must
        // adopt it rather than subscribe anew and strand the backlog.
        let clock = SimClock::new();
        let broker = Broker::new(clock.clone() as Arc<dyn Clock>).with_redelivery_timeout(10.0);
        let r1 = WorkerRegistry::new(broker.clone(), clock.clone(), Registry::default());
        let (w, _e) = r1.register("alpha", &["Noop".into()]);
        r1.enqueue("Noop", 8, &work(8.0));
        let _held = r1.lease(w, 10).unwrap(); // in flight at the "crash"

        // head restarts: same broker (recovered), fresh registry
        let r2 = WorkerRegistry::new(broker.clone(), clock.clone(), Registry::default());
        let (w2, _e2) = r2.register("alpha", &["Noop".into()]);
        assert!(r2.lease(w2, 10).unwrap().is_empty(), "deadline re-armed, not yet expired");
        clock.advance_by(11.0);
        let g = r2.lease(w2, 10).unwrap();
        assert_eq!(g.len(), 1, "recovered backlog leases from the adopted subscription");
        assert_eq!(g[0].handle, 8);
        assert!(g[0].redelivered);
        assert_eq!(
            broker.subscriptions_of_topic(&queue_topic("Noop")).len(),
            1,
            "no duplicate subscription"
        );
    }

    #[test]
    fn malformed_queue_payload_is_dropped() {
        let (r, clock) = registry(10.0);
        let (w, _e) = r.register("alpha", &["Noop".into()]);
        // junk straight onto the topic, bypassing enqueue
        r.broker.publish(&queue_topic("Noop"), Json::Str("junk".into()));
        r.enqueue("Noop", 6, &work(6.0));
        let g = r.lease(w, 10).unwrap();
        assert_eq!(g.len(), 1, "junk skipped, real work granted");
        assert_eq!(g[0].handle, 6);
        clock.advance_by(11.0);
        // the junk was acked away, not left to redeliver forever
        let g2 = r.lease(w, 10).unwrap();
        assert_eq!(g2.len(), 1, "only the un-completed real lease redelivers");
        assert_eq!(g2[0].handle, 6);
    }

    #[test]
    fn health_json_reports_fleet_state() {
        let (r, _clock) = registry(7.5);
        let (w, e) = r.register("alpha", &["Noop".into(), "Decision".into()]);
        r.register("beta", &["Noop".into()]);
        r.enqueue("Noop", 1, &work(1.0));
        r.enqueue("Noop", 2, &work(2.0));
        let g = r.lease(w, 1).unwrap();
        assert!(r.complete(w, e, g[0].lease, g[0].handle, Json::obj()));
        let h = r.health_json();
        assert_eq!(h.get("lease_timeout_s").unwrap().as_f64(), Some(7.5));
        assert_eq!(h.get("registered").unwrap().as_u64(), Some(2));
        assert_eq!(h.get("active_leases").unwrap().as_u64(), Some(0));
        assert_eq!(h.get("buffered_results").unwrap().as_u64(), Some(1));
        let rows = h.get("workers").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        let alpha = rows.iter().find(|r| r.get("name").unwrap().as_str() == Some("alpha")).unwrap();
        assert_eq!(alpha.get("epoch").unwrap().as_u64(), Some(1));
        assert_eq!(alpha.get("leased_total").unwrap().as_u64(), Some(1));
        assert_eq!(alpha.get("completed_total").unwrap().as_u64(), Some(1));
        let queues = h.get("queues").unwrap().as_arr().unwrap();
        // Decision queue (empty) + Noop queue (1 pending + 1 in-flight-completed)
        assert_eq!(queues.len(), 2);
        let noop = queues.iter().find(|q| q.get("kind").unwrap().as_str() == Some("Noop")).unwrap();
        assert_eq!(noop.get("backlog").unwrap().as_u64(), Some(2));
    }
}
