//! In-process message broker (ActiveMQ stand-in).
//!
//! The Conductor publishes availability notifications here; consumers
//! (WFM jobs, downstream Works, the Rubin incremental-release path)
//! subscribe. Semantics match what iDDS needs from its production broker:
//!
//! * topics with independent subscriber queues (fan-out),
//! * at-least-once delivery: a message stays "in flight" per subscriber
//!   until acked; unacked messages past the redelivery timeout are
//!   redelivered (pinned down in `rust/tests/broker_semantics.rs`),
//! * bounded queues with backpressure signalling (publish returns the
//!   queue depth so producers can throttle),
//! * batched `publish_many`/`ack_many` so high-rate producers/consumers
//!   (the Conductor's per-tick fan-out) take a topic's lock once per
//!   batch instead of once per message.
//!
//! # Striping model
//!
//! There is no broker-wide mutex. The topic map is sharded across
//! `STRIPES` `RwLock`ed hash maps keyed by a topic-name hash, and every
//! topic owns its state — subscriber list plus all per-subscriber queues —
//! behind its *own* `Mutex`. Publishers and pollers on different topics
//! therefore never serialize on a shared lock; within one topic, fan-out
//! and per-subscriber FIFO still happen atomically under the topic lock,
//! which is what keeps delivery order and redelivery semantics identical
//! to the old single-mutex broker (`bench_broker` carries the
//! before/after). A second striped index maps subscriber id → its topic,
//! so `poll`/`ack`/`backlog` reach the right topic lock in O(1). Flow
//! counters are plain atomics. Lock order: shard lock (topics or subs),
//! *then* one topic mutex — never two topic mutexes, never a shard lock
//! acquired while a topic mutex is held.
//!
//! # Durability
//!
//! Like the store, the broker emits one [`PersistEvent`] per applied
//! mutation — subscribe, unsubscribe, publish fan-out (recording the
//! fan-out set at publish time), delivery/redelivery, ack — through an
//! optional [`Persister`] hook, logged *while still holding the
//! topic lock that applied the mutation* (the same log-after-apply rule
//! the store follows; see DESIGN.md, "Durability model"). Checkpoints
//! embed [`Broker::snapshot_json`] as the `broker` section of snapshot
//! format v3, and recovery rebuilds topics, subscriptions, backlogs and
//! in-flight sets via [`Broker::restore`] + [`Broker::apply_event`], so
//! consumers resume exactly where the previous process died. In-flight
//! deadlines are deliberately *not* persisted: recovery re-arms every
//! in-flight message at `now + redelivery_timeout`, so work that was
//! unacked at the crash redelivers one timeout after the restart.

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use anyhow::{Context, Result};

use crate::persist::{PersistEvent, Persister};
use crate::util::clock::Clock;
use crate::util::json::Json;

pub mod lease;

pub type MsgId = u64;
pub type SubId = u64;

/// Number of lock stripes for the topic map and the subscriber index
/// (power of two, mirroring the store's table striping).
const STRIPES: usize = 16;

fn topic_stripe(topic: &str) -> usize {
    // FNV-1a over the name; topics are few and named, ids are not
    let mut h = crate::util::FNV1A_OFFSET;
    crate::util::fnv1a(&mut h, topic.as_bytes());
    (h as usize) & (STRIPES - 1)
}

fn sub_stripe(sub: SubId) -> usize {
    (sub as usize) & (STRIPES - 1)
}

#[derive(Debug, Clone)]
pub struct Delivery {
    pub id: MsgId,
    pub topic: String,
    pub payload: Json,
    pub redelivered: bool,
}

struct InFlight {
    msg: Arc<QueuedMsg>,
    deadline: f64,
}

struct QueuedMsg {
    id: MsgId,
    topic: String,
    payload: Json,
}

#[derive(Default)]
struct SubQueue {
    pending: VecDeque<Arc<QueuedMsg>>,
    in_flight: HashMap<MsgId, InFlight>,
    /// Ids delivered at least once — sets the `redelivered` flag should a
    /// message ever re-enter `pending`. Pruned on ack (an acked id can
    /// never come back: ids are unique and per-topic WAL order means no
    /// event about it follows its ack), so the set is bounded by the
    /// un-acked backlog, not by lifetime traffic.
    delivered_once: HashSet<MsgId>,
    /// Every id currently known to this subscriber (enqueued and not yet
    /// acked). WAL replay of a publish whose effect the checkpoint
    /// already captured dedupes against this (replay is insert-if-absent,
    /// exactly like the store's row inserts). Pruned on ack like
    /// `delivered_once`, and for the same reason.
    seen: HashSet<MsgId>,
}

impl SubQueue {
    fn take_pending(&mut self, id: MsgId) -> Option<Arc<QueuedMsg>> {
        let pos = self.pending.iter().position(|m| m.id == id)?;
        self.pending.remove(pos)
    }
}

/// Everything one topic owns, behind that topic's own lock: the
/// subscriber list (fan-out set) and each subscriber's queue.
struct TopicState {
    name: String,
    subs: Vec<SubId>,
    queues: HashMap<SubId, SubQueue>,
    /// Set (under both the shard write lock and this topic's lock) when
    /// the last subscriber left and the shell was removed from the topic
    /// map — a racing subscribe that already holds the `Arc` must retry
    /// against the map instead of inserting into an unmapped shell.
    dead: bool,
}

impl TopicState {
    fn new(name: &str) -> Self {
        TopicState {
            name: name.to_string(),
            subs: Vec::new(),
            queues: HashMap::new(),
            dead: false,
        }
    }
}

type TopicArc = Arc<Mutex<TopicState>>;

/// Canonical per-topic snapshot entry (shared by full sections and delta
/// sections): message union sorted by id, subscribers by id, pending in
/// queue order, in-flight sorted. `None` for a subscriber-less shell — a
/// subscribe caught between topic-map insert and queue creation, or a
/// just-GC'd arc — which holds nothing recoverable.
fn topic_json(t: &TopicState) -> Option<Json> {
    if t.queues.is_empty() {
        return None;
    }
    // union of every message still referenced by some queue
    let mut msgs: BTreeMap<MsgId, Json> = BTreeMap::new();
    let mut subs: Vec<&SubId> = t.queues.keys().collect();
    subs.sort_unstable();
    let mut sub_rows = Vec::new();
    for &sub in subs {
        let q = &t.queues[&sub];
        for m in &q.pending {
            msgs.entry(m.id).or_insert_with(|| m.payload.clone());
        }
        for f in q.in_flight.values() {
            msgs.entry(f.msg.id).or_insert_with(|| f.msg.payload.clone());
        }
        let in_flight: BTreeSet<MsgId> = q.in_flight.keys().copied().collect();
        sub_rows.push(
            Json::obj()
                .set("id", sub)
                .set(
                    "pending",
                    Json::Arr(q.pending.iter().map(|m| Json::from(m.id)).collect()),
                )
                .set(
                    "in_flight",
                    Json::Arr(in_flight.into_iter().map(Json::from).collect()),
                ),
        )
    }
    Some(
        Json::obj()
            .set("name", t.name.as_str())
            .set(
                "messages",
                Json::Arr(
                    msgs.into_iter()
                        .map(|(id, payload)| Json::obj().set("id", id).set("payload", payload))
                        .collect(),
                ),
            )
            .set("subs", Json::Arr(sub_rows)),
    )
}

struct BrokerInner {
    /// topic name → topic state, sharded by topic-name hash.
    topics: Vec<RwLock<HashMap<String, TopicArc>>>,
    /// subscriber id → owning topic, sharded by subscriber id.
    subs: Vec<RwLock<HashMap<SubId, TopicArc>>>,
    published: AtomicU64,
    delivered: AtomicU64,
    redelivered: AtomicU64,
    acked: AtomicU64,
    /// Topic names touched since the last delta-checkpoint drain — marked
    /// inside the topic-lock critical section, before the mutation's event
    /// can get an LSN (same fuzzy-cut ordering rule as the store's dirty
    /// sets). A drained name whose topic no longer exists encodes as a
    /// removal in the delta section.
    dirty_topics: Mutex<HashSet<String>>,
    /// Gate for the set above: off by default (non-durable brokers accrete
    /// nothing), flipped once by `Persist::open_with_broker` between the
    /// checkpoint install and WAL replay — installed topics are already
    /// durable in the loaded files; replayed events must mark.
    dirty_enabled: AtomicBool,
    /// optional durability hook; attach-once, after recovery
    persister: OnceLock<Arc<dyn Persister>>,
}

/// The broker. Clone-shareable; clones share all topic state.
#[derive(Clone)]
pub struct Broker {
    inner: Arc<BrokerInner>,
    clock: Arc<dyn Clock>,
    redelivery_timeout: f64,
    max_queue: usize,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BrokerStats {
    pub published: u64,
    pub delivered: u64,
    pub redelivered: u64,
    pub acked: u64,
}

/// Fully decoded `broker` snapshot section — phase 1 of restore. Building
/// this validates every record without touching the broker, so a snapshot
/// that fails to decode leaves both broker and store untouched (crash
/// recovery relies on that to fall back to an older checkpoint cleanly).
pub(crate) struct DecodedBroker {
    topics: Vec<DecodedTopic>,
    max_id: u64,
}

impl DecodedBroker {
    /// Largest subscriber/message id in the section — recovery advances
    /// the process-wide id counter past it even when the section is only
    /// carried through opaquely (store-only opens), so a store-only
    /// writer can never mint ids colliding with persisted broker ids.
    pub(crate) fn max_id(&self) -> u64 {
        self.max_id
    }
}

struct DecodedTopic {
    name: String,
    msgs: HashMap<MsgId, Json>,
    subs: Vec<DecodedSub>,
}

struct DecodedSub {
    id: SubId,
    pending: Vec<MsgId>,
    in_flight: Vec<MsgId>,
}

impl Broker {
    pub fn new(clock: Arc<dyn Clock>) -> Self {
        Broker {
            inner: Arc::new(BrokerInner {
                topics: (0..STRIPES).map(|_| RwLock::new(HashMap::new())).collect(),
                subs: (0..STRIPES).map(|_| RwLock::new(HashMap::new())).collect(),
                published: AtomicU64::new(0),
                delivered: AtomicU64::new(0),
                redelivered: AtomicU64::new(0),
                acked: AtomicU64::new(0),
                dirty_topics: Mutex::new(HashSet::new()),
                dirty_enabled: AtomicBool::new(false),
                persister: OnceLock::new(),
            }),
            clock,
            redelivery_timeout: 30.0,
            max_queue: 1_000_000,
        }
    }

    pub fn with_redelivery_timeout(mut self, secs: f64) -> Self {
        self.redelivery_timeout = secs;
        self
    }

    /// The in-flight redelivery timeout, in seconds. Work leases
    /// ([`lease::WorkerRegistry`]) are broker in-flight deliveries, so this
    /// is also the lease timeout the worker protocol advertises.
    pub fn redelivery_timeout(&self) -> f64 {
        self.redelivery_timeout
    }

    // -- durability hook ------------------------------------------------------

    /// Attach the durability hook. Attach-once, and only *after* recovery
    /// has finished replaying into this broker (replay must not re-log).
    /// Returns false if a persister was already attached.
    pub fn set_persister(&self, p: Arc<dyn Persister>) -> bool {
        self.inner.persister.set(p).is_ok()
    }

    /// Build the event only when a persister is attached — the disabled
    /// path pays one atomic load and no clones.
    #[inline]
    fn log(&self, f: impl FnOnce() -> PersistEvent) {
        if let Some(p) = self.inner.persister.get() {
            p.log(f());
        }
    }

    /// Turn touched-topic tracking on (see `dirty_enabled`); called by
    /// `Persist::open_with_broker` after the checkpoint install, before
    /// WAL replay.
    pub(crate) fn enable_dirty_tracking(&self) {
        self.inner.dirty_enabled.store(true, Ordering::Relaxed);
    }

    /// Mark a topic touched for the next delta checkpoint. Call inside the
    /// topic-lock critical section that applied the mutation (before its
    /// event can receive an LSN — the fuzzy-cut ordering rule).
    fn mark_dirty(&self, topic: &str) {
        if !self.inner.dirty_enabled.load(Ordering::Relaxed) {
            return;
        }
        let mut d = self.inner.dirty_topics.lock().unwrap();
        if !d.contains(topic) {
            d.insert(topic.to_string());
        }
    }

    // -- topic / subscriber resolution ---------------------------------------

    /// Get or create the topic's state. Read-locks the shard on the fast
    /// path; only the first subscriber of a topic takes the write lock.
    fn topic_entry(&self, topic: &str) -> TopicArc {
        let shard = &self.inner.topics[topic_stripe(topic)];
        if let Some(t) = shard.read().unwrap().get(topic) {
            return Arc::clone(t);
        }
        let mut w = shard.write().unwrap();
        Arc::clone(
            w.entry(topic.to_string())
                .or_insert_with(|| Arc::new(Mutex::new(TopicState::new(topic)))),
        )
    }

    fn topic_of(&self, topic: &str) -> Option<TopicArc> {
        self.inner.topics[topic_stripe(topic)].read().unwrap().get(topic).map(Arc::clone)
    }

    fn topic_of_sub(&self, sub: SubId) -> Option<TopicArc> {
        self.inner.subs[sub_stripe(sub)].read().unwrap().get(&sub).map(Arc::clone)
    }

    // -- core operations ------------------------------------------------------

    /// Subscribe to a topic; returns the subscriber handle.
    pub fn subscribe(&self, topic: &str) -> SubId {
        let id = crate::util::next_id();
        let topic_arc = loop {
            let arc = self.topic_entry(topic);
            let mut t = arc.lock().unwrap();
            if t.dead {
                // raced the last-subscriber GC: this shell just left the
                // map; retry resolves (or re-creates) the mapped entry
                drop(t);
                continue;
            }
            t.subs.push(id);
            t.queues.insert(id, SubQueue::default());
            self.mark_dirty(topic);
            self.log(|| PersistEvent::BrokerSubscribe { sub: id, topic: topic.to_string() });
            drop(t);
            break arc;
        };
        self.inner.subs[sub_stripe(id)].write().unwrap().insert(id, topic_arc);
        id
    }

    /// Drop a subscription: the subscriber leaves its topic's fan-out set
    /// and its queue (backlog included) is discarded. Idempotent — false
    /// for an unknown or already-dropped subscriber. With durability on
    /// this is how an abandoned consumer stops accreting queue state
    /// across checkpoints and restarts.
    pub fn unsubscribe(&self, sub: SubId) -> bool {
        let Some(topic_arc) = self.topic_of_sub(sub) else { return false };
        {
            let mut t = topic_arc.lock().unwrap();
            if t.queues.remove(&sub).is_none() {
                return false; // raced another unsubscribe of the same id
            }
            t.subs.retain(|&s| s != sub);
            self.mark_dirty(&t.name);
            self.log(|| PersistEvent::BrokerUnsubscribe { sub });
        }
        self.inner.subs[sub_stripe(sub)].write().unwrap().remove(&sub);
        self.gc_topic_if_empty(&topic_arc);
        true
    }

    /// Remove `topic_arc` from the topic map if its last subscriber left
    /// — otherwise empty shells would accrete in the map (and in every
    /// snapshot) forever under dynamic topic naming. The shell is marked
    /// `dead` while holding both the shard write lock and the topic lock,
    /// which is what makes the racing-subscribe retry in
    /// [`Broker::subscribe`] sound.
    fn gc_topic_if_empty(&self, topic_arc: &TopicArc) {
        let name = topic_arc.lock().unwrap().name.clone();
        let mut shard = self.inner.topics[topic_stripe(&name)].write().unwrap();
        let Some(mapped) = shard.get(&name) else { return };
        if !Arc::ptr_eq(mapped, topic_arc) {
            return; // the topic was already re-created under this name
        }
        let mut t = topic_arc.lock().unwrap();
        if t.subs.is_empty() {
            t.dead = true;
            drop(t);
            shard.remove(&name);
        }
    }

    /// Publish to a topic, fanning out to all subscribers. Returns the max
    /// subscriber queue depth (backpressure signal) — 0 if no subscribers.
    pub fn publish(&self, topic: &str, payload: Json) -> usize {
        self.publish_many(topic, vec![payload])
    }

    /// Publish a whole batch to a topic under **one topic-lock
    /// acquisition** — the Conductor's per-tick fan-out takes the lock
    /// once instead of once per message, and publishers on *other* topics
    /// are untouched. Returns the max subscriber queue depth after the
    /// batch (backpressure signal) — 0 if no subscribers.
    pub fn publish_many(&self, topic: &str, payloads: Vec<Json>) -> usize {
        if payloads.is_empty() {
            return 0;
        }
        self.inner.published.fetch_add(payloads.len() as u64, Ordering::Relaxed);
        let mut sp = crate::obs::span("broker.publish");
        sp.attr("topic", topic);
        sp.attr("n", payloads.len());
        // topics come into being on first subscribe; a publish to a topic
        // nobody ever subscribed to fans out to zero queues and is dropped
        let Some(topic_arc) = self.topic_of(topic) else { return 0 };
        let mut t = topic_arc.lock().unwrap();
        if t.subs.is_empty() {
            return 0;
        }
        let topic_name = t.name.clone();
        let msgs: Vec<Arc<QueuedMsg>> = payloads
            .into_iter()
            .map(|payload| {
                Arc::new(QueuedMsg {
                    id: crate::util::next_id(),
                    topic: topic_name.clone(),
                    payload,
                })
            })
            .collect();
        let TopicState { subs, queues, .. } = &mut *t;
        let mut depth = 0;
        let mut targets: Vec<SubId> = Vec::with_capacity(subs.len());
        let mut enqueued = vec![false; msgs.len()];
        for sub in subs.iter() {
            if let Some(q) = queues.get_mut(sub) {
                targets.push(*sub);
                for (i, msg) in msgs.iter().enumerate() {
                    if q.pending.len() < self.max_queue {
                        q.seen.insert(msg.id);
                        q.pending.push_back(Arc::clone(msg));
                        enqueued[i] = true;
                    }
                }
                depth = depth.max(q.pending.len());
            }
        }
        // Applied effects only: a message every queue dropped at the
        // max_queue bound never made it into broker state, so it must not
        // be resurrected by replay. (A message dropped by only *some*
        // full queues can still replay into them if the checkpoint caught
        // those queues drained — a spurious extra delivery, inside the
        // at-least-once contract consumers already tolerate.) The event
        // records the fan-out set too: a snapshot taken after the cut may
        // already hold a later-joining subscriber, and replay must not
        // hand it messages published before it subscribed.
        if enqueued.iter().any(|&e| e) {
            self.mark_dirty(&topic_name);
            self.log(|| PersistEvent::BrokerPublish {
                topic: topic_name,
                subs: targets,
                msgs: msgs
                    .iter()
                    .zip(&enqueued)
                    .filter(|(_, &e)| e)
                    .map(|(m, _)| (m.id, m.payload.clone()))
                    .collect(),
            });
        }
        depth
    }

    /// Poll up to `max` messages for a subscriber. Redelivers expired
    /// in-flight messages first.
    pub fn poll(&self, sub: SubId, max: usize) -> Vec<Delivery> {
        let now = self.clock.now();
        let timeout = self.redelivery_timeout;
        let Some(topic_arc) = self.topic_of_sub(sub) else { return Vec::new() };
        // cancelled below when the queue turns out to be empty, so consumer
        // poll loops don't flood the trace ring with no-op deliveries
        let mut sp = crate::obs::span("broker.deliver");
        let mut t = topic_arc.lock().unwrap();
        let mut out = Vec::new();
        let mut redelivered_n = 0u64;
        let mut delivered_n = 0u64;
        if let Some(q) = t.queues.get_mut(&sub) {
            // expire in-flight
            let expired: Vec<MsgId> = q
                .in_flight
                .iter()
                .filter(|(_, f)| f.deadline <= now)
                .map(|(id, _)| *id)
                .collect();
            for id in expired {
                if out.len() >= max {
                    break;
                }
                let mut f = q.in_flight.remove(&id).unwrap();
                f.deadline = now + timeout;
                out.push(Delivery {
                    id,
                    topic: f.msg.topic.clone(),
                    payload: f.msg.payload.clone(),
                    redelivered: true,
                });
                redelivered_n += 1;
                q.in_flight.insert(id, f);
            }
            // fresh messages
            while out.len() < max {
                let Some(msg) = q.pending.pop_front() else { break };
                let redelivered = !q.delivered_once.insert(msg.id);
                out.push(Delivery {
                    id: msg.id,
                    topic: msg.topic.clone(),
                    payload: msg.payload.clone(),
                    redelivered,
                });
                delivered_n += 1;
                q.in_flight.insert(msg.id, InFlight { msg, deadline: now + timeout });
            }
        }
        if !out.is_empty() {
            self.mark_dirty(&t.name);
            self.log(|| PersistEvent::BrokerDeliver {
                sub,
                ids: out.iter().map(|d| d.id).collect(),
            });
        }
        drop(t);
        if out.is_empty() {
            sp.cancel();
        } else {
            sp.attr("n", out.len());
            sp.attr("redelivered", redelivered_n);
        }
        self.inner.delivered.fetch_add(delivered_n, Ordering::Relaxed);
        self.inner.redelivered.fetch_add(redelivered_n, Ordering::Relaxed);
        out
    }

    /// Acknowledge a delivery; the message will not be redelivered.
    pub fn ack(&self, sub: SubId, msg: MsgId) -> bool {
        self.ack_many(sub, &[msg]) == 1
    }

    /// Acknowledge a batch of deliveries under one topic-lock acquisition.
    /// Returns how many were actually in flight (already-acked or unknown
    /// ids are skipped, matching [`Broker::ack`]).
    pub fn ack_many(&self, sub: SubId, msgs: &[MsgId]) -> usize {
        if msgs.is_empty() {
            return 0;
        }
        let Some(topic_arc) = self.topic_of_sub(sub) else { return 0 };
        let mut t = topic_arc.lock().unwrap();
        let mut removed: Vec<MsgId> = Vec::new();
        if let Some(q) = t.queues.get_mut(&sub) {
            for msg in msgs {
                if q.in_flight.remove(msg).is_some() {
                    // acked ids never come back — prune the history sets
                    // so they stay bounded by the un-acked backlog
                    q.delivered_once.remove(msg);
                    q.seen.remove(msg);
                    removed.push(*msg);
                }
            }
        }
        if !removed.is_empty() {
            // applied effects only: the event carries the ids that
            // actually left the in-flight set
            self.mark_dirty(&t.name);
            self.log(|| PersistEvent::BrokerAck { sub, ids: removed.clone() });
        }
        drop(t);
        let n = removed.len();
        self.inner.acked.fetch_add(n as u64, Ordering::Relaxed);
        n
    }

    /// Extend an in-flight delivery's deadline to `now + redelivery_timeout`
    /// — the worker heartbeat path. Returns false when the message is not in
    /// flight for this subscriber (already acked, expired back to pending and
    /// re-leased, or never delivered): the caller's claim on it is gone and a
    /// renewal must not resurrect it. Durable via the same `BrokerDeliver`
    /// event a redelivery logs — replay's move-or-renew arm re-arms the
    /// deadline, so renewals survive restarts like deliveries do.
    pub fn renew(&self, sub: SubId, msg: MsgId) -> bool {
        let deadline = self.clock.now() + self.redelivery_timeout;
        let Some(topic_arc) = self.topic_of_sub(sub) else { return false };
        let mut t = topic_arc.lock().unwrap();
        let renewed = match t.queues.get_mut(&sub).and_then(|q| q.in_flight.get_mut(&msg)) {
            Some(f) => {
                f.deadline = deadline;
                true
            }
            None => false,
        };
        if renewed {
            self.mark_dirty(&t.name);
            self.log(|| PersistEvent::BrokerDeliver { sub, ids: vec![msg] });
        }
        renewed
    }

    /// Current subscriber ids of a topic, sorted — `None`-safe (empty for an
    /// unknown topic). The worker registry uses this to re-adopt a durable
    /// shared claim queue after a head restart instead of subscribing anew
    /// (which would orphan the recovered queue's backlog).
    pub fn subscriptions_of_topic(&self, topic: &str) -> Vec<SubId> {
        let Some(topic_arc) = self.topic_of(topic) else { return Vec::new() };
        let t = topic_arc.lock().unwrap();
        let mut subs: Vec<SubId> = t.queues.keys().copied().collect();
        subs.sort_unstable();
        subs
    }

    /// Outstanding (pending + in-flight) for a subscriber.
    pub fn backlog(&self, sub: SubId) -> usize {
        let Some(topic_arc) = self.topic_of_sub(sub) else { return 0 };
        let t = topic_arc.lock().unwrap();
        t.queues.get(&sub).map(|q| q.pending.len() + q.in_flight.len()).unwrap_or(0)
    }

    pub fn stats(&self) -> BrokerStats {
        BrokerStats {
            published: self.inner.published.load(Ordering::Relaxed),
            delivered: self.inner.delivered.load(Ordering::Relaxed),
            redelivered: self.inner.redelivered.load(Ordering::Relaxed),
            acked: self.inner.acked.load(Ordering::Relaxed),
        }
    }

    // -- observability --------------------------------------------------------

    /// Live broker state for `/api/health`: topology counts, total
    /// backlog, and the flow counters.
    pub fn health_json(&self) -> Json {
        let mut topics = 0u64;
        let mut subscriptions = 0u64;
        let mut pending = 0u64;
        let mut in_flight = 0u64;
        for (_, arc) in self.all_topics() {
            let t = arc.lock().unwrap();
            topics += 1;
            subscriptions += t.subs.len() as u64;
            for q in t.queues.values() {
                pending += q.pending.len() as u64;
                in_flight += q.in_flight.len() as u64;
            }
        }
        let st = self.stats();
        Json::obj()
            .set("topics", topics)
            .set("subscriptions", subscriptions)
            .set("pending", pending)
            .set("in_flight", in_flight)
            .set("published", st.published)
            .set("delivered", st.delivered)
            .set("redelivered", st.redelivered)
            .set("acked", st.acked)
    }

    // -- snapshot / restore / replay -----------------------------------------

    fn all_topics(&self) -> Vec<(String, TopicArc)> {
        let mut out: Vec<(String, TopicArc)> = Vec::new();
        for shard in &self.inner.topics {
            for (name, arc) in shard.read().unwrap().iter() {
                out.push((name.clone(), Arc::clone(arc)));
            }
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Serialize topics, subscriptions, backlogs and in-flight sets — the
    /// `broker` section of snapshot format v3+. Deterministic: topics
    /// sorted by name, subscribers by id, messages by id, pending in queue
    /// order. Deadlines are not captured (recovery re-arms them), so this
    /// is also the canonical form recovery tests compare against.
    pub fn snapshot_json(&self) -> Json {
        let mut topics = Vec::new();
        for (_, arc) in self.all_topics() {
            let t = arc.lock().unwrap();
            if let Some(j) = topic_json(&t) {
                topics.push(j);
            }
        }
        Json::obj().set("topics", Json::Arr(topics))
    }

    // -- delta checkpoints ----------------------------------------------------

    /// Drain the touched-topic names (sorted). Called by `Persist` after
    /// the checkpoint cut; on failure the names must go back via
    /// [`Broker::restore_dirty_topics`].
    pub(crate) fn take_dirty_topics(&self) -> Vec<String> {
        let mut v: Vec<String> =
            std::mem::take(&mut *self.inner.dirty_topics.lock().unwrap()).into_iter().collect();
        v.sort();
        v
    }

    pub(crate) fn restore_dirty_topics(&self, names: Vec<String>) {
        self.inner.dirty_topics.lock().unwrap().extend(names);
    }

    /// Topics touched since the last drain — the `/api/health` delta gauge.
    pub fn dirty_topic_count(&self) -> usize {
        self.inner.dirty_topics.lock().unwrap().len()
    }

    /// Encode the broker delta section for a drained touched-name list:
    /// the full current state of each touched topic that still exists
    /// (same per-topic format as [`Broker::snapshot_json`]) plus the
    /// `removed` names whose topics are gone (last-unsubscribe GC) or
    /// shrank to subscriber-less shells. Folding a chain of these onto a
    /// base section is replace-by-name + remove.
    pub(crate) fn delta_json(&self, touched: &[String]) -> Json {
        let mut topics = Vec::new();
        let mut removed = Vec::new();
        for name in touched {
            match self.topic_of(name) {
                Some(arc) => {
                    let t = arc.lock().unwrap();
                    match topic_json(&t) {
                        Some(j) => topics.push(j),
                        None => removed.push(Json::Str(name.clone())),
                    }
                }
                None => removed.push(Json::Str(name.clone())),
            }
        }
        Json::obj()
            .set("topics", Json::Arr(topics))
            .set("removed", Json::Arr(removed))
    }

    /// Validate a broker delta section without touching any broker;
    /// returns the largest id referenced (id-counter advance). Fallback
    /// and chain validation use this.
    pub(crate) fn validate_delta(j: &Json) -> Result<u64> {
        let d = Self::decode_snapshot(j)?;
        for v in j.get("removed").and_then(|a| a.as_arr()).unwrap_or(&[]) {
            anyhow::ensure!(v.as_str().is_some(), "removed entry is not a topic name");
        }
        Ok(d.max_id)
    }

    /// Fold a broker delta section into a base `broker` snapshot section
    /// (both JSON): touched topics replace their base entries wholesale,
    /// removed names drop out, and the result stays in canonical
    /// name-sorted order. A `Null`/absent base folds from empty. Purely
    /// structural — recovery decodes the folded result once at the end.
    pub(crate) fn fold_snapshot_section(base: &mut Json, delta: &Json) {
        let mut topics: Vec<Json> = base
            .get("topics")
            .and_then(|a| a.as_arr())
            .map(|a| a.to_vec())
            .unwrap_or_default();
        let gone: HashSet<&str> = delta
            .get("removed")
            .and_then(|a| a.as_arr())
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_str())
            .collect();
        let fresh = delta.get("topics").and_then(|a| a.as_arr()).unwrap_or(&[]);
        let replaced: HashSet<&str> =
            fresh.iter().filter_map(|t| t.get("name").and_then(|n| n.as_str())).collect();
        topics.retain(|t| {
            let name = t.get("name").and_then(|n| n.as_str()).unwrap_or("");
            !gone.contains(name) && !replaced.contains(name)
        });
        topics.extend(fresh.iter().cloned());
        topics.sort_by(|a, b| {
            let an = a.get("name").and_then(|n| n.as_str()).unwrap_or("");
            let bn = b.get("name").and_then(|n| n.as_str()).unwrap_or("");
            an.cmp(bn)
        });
        *base = Json::obj().set("topics", Json::Arr(topics));
    }

    /// Phase 1 of restore: decode and validate a `broker` section without
    /// touching any broker. Crash recovery decodes *before* restoring the
    /// store so a half-bad checkpoint is set aside with nothing mutated.
    pub(crate) fn decode_snapshot(j: &Json) -> Result<DecodedBroker> {
        let mut d = DecodedBroker { topics: Vec::new(), max_id: 0 };
        for tj in j.get("topics").and_then(|a| a.as_arr()).unwrap_or(&[]) {
            let name = tj.get("name").and_then(|v| v.as_str()).context("topic.name")?.to_string();
            let mut msgs = HashMap::new();
            for mj in tj.get("messages").and_then(|a| a.as_arr()).unwrap_or(&[]) {
                let id = mj.get("id").and_then(|v| v.as_u64()).context("message.id")?;
                d.max_id = d.max_id.max(id);
                msgs.insert(id, mj.get("payload").cloned().unwrap_or(Json::Null));
            }
            let mut subs = Vec::new();
            for sj in tj.get("subs").and_then(|a| a.as_arr()).unwrap_or(&[]) {
                let id = sj.get("id").and_then(|v| v.as_u64()).context("sub.id")?;
                d.max_id = d.max_id.max(id);
                let ids = |key: &str| -> Result<Vec<MsgId>> {
                    sj.get(key)
                        .and_then(|a| a.as_arr())
                        .unwrap_or(&[])
                        .iter()
                        .map(|v| {
                            let id = v.as_u64().with_context(|| format!("sub.{key} id"))?;
                            anyhow::ensure!(
                                msgs.contains_key(&id),
                                "sub {key} references unknown message {id}"
                            );
                            Ok(id)
                        })
                        .collect()
                };
                subs.push(DecodedSub {
                    id,
                    pending: ids("pending")?,
                    in_flight: ids("in_flight")?,
                });
            }
            d.topics.push(DecodedTopic { name, msgs, subs });
        }
        Ok(d)
    }

    /// Phase 2 of restore: install a decoded snapshot into this (empty)
    /// broker and advance the process-wide id counter past every restored
    /// subscriber/message id. In-flight deadlines re-arm at
    /// `now + redelivery_timeout`. Returns the max id seen.
    pub(crate) fn install_decoded(&self, d: DecodedBroker) -> u64 {
        let deadline = self.clock.now() + self.redelivery_timeout;
        for topic in d.topics {
            if topic.subs.is_empty() {
                continue; // never reinstall a subscriber-less shell
            }
            let topic_arc = self.topic_entry(&topic.name);
            let mut installed: Vec<SubId> = Vec::with_capacity(topic.subs.len());
            {
                let mut t = topic_arc.lock().unwrap();
                let arcs: HashMap<MsgId, Arc<QueuedMsg>> = topic
                    .msgs
                    .into_iter()
                    .map(|(id, payload)| {
                        (id, Arc::new(QueuedMsg { id, topic: topic.name.clone(), payload }))
                    })
                    .collect();
                for sub in topic.subs {
                    if t.queues.contains_key(&sub.id) {
                        continue; // insert-if-absent, like the store's rec paths
                    }
                    let mut q = SubQueue::default();
                    for id in &sub.pending {
                        q.seen.insert(*id);
                        q.pending.push_back(Arc::clone(&arcs[id]));
                    }
                    for id in &sub.in_flight {
                        q.seen.insert(*id);
                        q.delivered_once.insert(*id);
                        q.in_flight.insert(*id, InFlight { msg: Arc::clone(&arcs[id]), deadline });
                    }
                    t.subs.push(sub.id);
                    t.queues.insert(sub.id, q);
                    installed.push(sub.id);
                }
            }
            // subscriber index after the topic lock is released (lock
            // order: shard lock, then topic mutex — never the reverse)
            for sub in installed {
                self.inner.subs[sub_stripe(sub)]
                    .write()
                    .unwrap()
                    .entry(sub)
                    .or_insert_with(|| Arc::clone(&topic_arc));
            }
        }
        crate::util::advance_next_id(d.max_id);
        d.max_id
    }

    /// Restore a `broker` snapshot section (decode + install). The broker
    /// must be freshly created and not yet shared with daemons/handlers.
    pub fn restore(&self, j: &Json) -> Result<u64> {
        Ok(self.install_decoded(Self::decode_snapshot(j)?))
    }

    /// Apply one replayed broker event. Replay semantics mirror the
    /// store's: subscribes and publishes are insert-if-absent, delivers
    /// move-or-renew, acks remove-if-present — so replaying a WAL suffix
    /// that partially overlaps a checkpoint converges to the live state.
    /// Unknown subscribers/ids are skipped; replay never fails. Must run
    /// *before* a persister is attached (replay must not re-log).
    pub fn apply_event(&self, ev: &PersistEvent) {
        match ev {
            PersistEvent::BrokerSubscribe { sub, topic } => {
                let topic_arc = self.topic_entry(topic);
                {
                    let mut t = topic_arc.lock().unwrap();
                    if !t.queues.contains_key(sub) {
                        t.subs.push(*sub);
                        t.queues.insert(*sub, SubQueue::default());
                    }
                    self.mark_dirty(topic);
                }
                self.inner.subs[sub_stripe(*sub)]
                    .write()
                    .unwrap()
                    .entry(*sub)
                    .or_insert(topic_arc);
            }
            PersistEvent::BrokerUnsubscribe { sub } => {
                if let Some(topic_arc) = self.topic_of_sub(*sub) {
                    {
                        let mut t = topic_arc.lock().unwrap();
                        t.queues.remove(sub);
                        t.subs.retain(|s| s != sub);
                        self.mark_dirty(&t.name);
                    }
                    self.inner.subs[sub_stripe(*sub)].write().unwrap().remove(sub);
                    self.gc_topic_if_empty(&topic_arc);
                }
            }
            PersistEvent::BrokerPublish { topic, subs, msgs } => {
                let Some(topic_arc) = self.topic_of(topic) else { return };
                let mut t = topic_arc.lock().unwrap();
                self.mark_dirty(&t.name);
                let arcs: Vec<Arc<QueuedMsg>> = msgs
                    .iter()
                    .map(|(id, payload)| {
                        Arc::new(QueuedMsg {
                            id: *id,
                            topic: topic.clone(),
                            payload: payload.clone(),
                        })
                    })
                    .collect();
                // enqueue into the recorded fan-out set, not the current
                // subscriber list: a subscriber restored from a snapshot
                // taken after this event must not receive messages
                // published before it joined
                for sub in subs {
                    if let Some(q) = t.queues.get_mut(sub) {
                        for msg in &arcs {
                            if q.pending.len() < self.max_queue && !q.seen.contains(&msg.id) {
                                q.seen.insert(msg.id);
                                q.pending.push_back(Arc::clone(msg));
                            }
                        }
                    }
                }
            }
            PersistEvent::BrokerDeliver { sub, ids } => {
                let deadline = self.clock.now() + self.redelivery_timeout;
                let Some(topic_arc) = self.topic_of_sub(*sub) else { return };
                let mut t = topic_arc.lock().unwrap();
                self.mark_dirty(&t.name);
                let Some(q) = t.queues.get_mut(sub) else { return };
                for id in ids {
                    // in-flight first: renewals are O(1) there, and an id
                    // can never be in both sets — probing pending first
                    // would pay a linear deque scan per redelivery event
                    if let Some(f) = q.in_flight.get_mut(id) {
                        f.deadline = deadline;
                    } else if let Some(msg) = q.take_pending(*id) {
                        q.delivered_once.insert(*id);
                        q.in_flight.insert(*id, InFlight { msg, deadline });
                    }
                }
            }
            PersistEvent::BrokerAck { sub, ids } => {
                let Some(topic_arc) = self.topic_of_sub(*sub) else { return };
                let mut t = topic_arc.lock().unwrap();
                self.mark_dirty(&t.name);
                let Some(q) = t.queues.get_mut(sub) else { return };
                for id in ids {
                    q.in_flight.remove(id);
                    q.delivered_once.remove(id);
                    q.seen.remove(id);
                }
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::{SimClock, WallClock};

    #[test]
    fn fanout_to_all_subscribers() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s1 = b.subscribe("t");
        let s2 = b.subscribe("t");
        b.publish("t", Json::Num(1.0));
        assert_eq!(b.poll(s1, 10).len(), 1);
        assert_eq!(b.poll(s2, 10).len(), 1);
    }

    #[test]
    fn topics_are_isolated() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s1 = b.subscribe("a");
        b.publish("b", Json::Num(1.0));
        assert!(b.poll(s1, 10).is_empty());
    }

    #[test]
    fn ack_stops_redelivery() {
        let clock = SimClock::new();
        let b = Broker::new(clock.clone()).with_redelivery_timeout(10.0);
        let s = b.subscribe("t");
        b.publish("t", Json::Num(1.0));
        let d = b.poll(s, 10);
        assert_eq!(d.len(), 1);
        assert!(b.ack(s, d[0].id));
        clock.advance_by(100.0);
        assert!(b.poll(s, 10).is_empty());
        assert_eq!(b.backlog(s), 0);
    }

    #[test]
    fn unacked_messages_redeliver_after_timeout() {
        let clock = SimClock::new();
        let b = Broker::new(clock.clone()).with_redelivery_timeout(10.0);
        let s = b.subscribe("t");
        b.publish("t", Json::Str("x".into()));
        let d1 = b.poll(s, 10);
        assert_eq!(d1.len(), 1);
        assert!(!d1[0].redelivered);
        // before timeout: nothing
        clock.advance_by(5.0);
        assert!(b.poll(s, 10).is_empty());
        // after timeout: redelivered flag set
        clock.advance_by(6.0);
        let d2 = b.poll(s, 10);
        assert_eq!(d2.len(), 1);
        assert!(d2[0].redelivered);
        assert_eq!(d2[0].id, d1[0].id);
    }

    #[test]
    fn renew_extends_inflight_deadline() {
        let clock = SimClock::new();
        let b = Broker::new(clock.clone()).with_redelivery_timeout(10.0);
        let s = b.subscribe("t");
        b.publish("t", Json::Num(1.0));
        let d = b.poll(s, 10);
        assert_eq!(d.len(), 1);
        // renew at t=8 → new deadline t=18; the original would have fired at 10
        clock.advance_by(8.0);
        assert!(b.renew(s, d[0].id));
        clock.advance_by(9.0); // t=17 < 18
        assert!(b.poll(s, 10).is_empty(), "renewed message must not redeliver yet");
        clock.advance_by(2.0); // t=19 > 18
        let d2 = b.poll(s, 10);
        assert_eq!(d2.len(), 1);
        assert!(d2[0].redelivered);
    }

    #[test]
    fn renew_rejects_acked_expired_and_unknown() {
        let clock = SimClock::new();
        let b = Broker::new(clock.clone()).with_redelivery_timeout(10.0);
        let s = b.subscribe("t");
        let s2 = b.subscribe("t");
        b.publish("t", Json::Num(1.0));
        let d = b.poll(s, 10);
        assert!(!b.renew(s, d[0].id + 1_000_000), "unknown id");
        assert!(!b.renew(s2, d[0].id), "delivered to s, not s2: per-subscriber state");
        assert!(b.ack(s, d[0].id));
        assert!(!b.renew(s, d[0].id), "acked is not renewable");
        // expiry + re-poll hands the claim back out; only the *current*
        // in-flight entry is renewable, and ack after renew still works
        let e = b.poll(s2, 10);
        clock.advance_by(11.0);
        let e2 = b.poll(s2, 10);
        assert!(e2[0].redelivered);
        assert!(b.renew(s2, e[0].id), "the re-delivered claim renews");
        assert!(b.ack(s2, e[0].id));
        assert!(!b.renew(s2, e[0].id));
    }

    #[test]
    fn subscriptions_of_topic_lists_current_subs() {
        let b = Broker::new(Arc::new(WallClock::new()));
        assert!(b.subscriptions_of_topic("t").is_empty());
        let s1 = b.subscribe("t");
        let s2 = b.subscribe("t");
        let mut want = vec![s1, s2];
        want.sort_unstable();
        assert_eq!(b.subscriptions_of_topic("t"), want);
        b.unsubscribe(s1);
        assert_eq!(b.subscriptions_of_topic("t"), vec![s2]);
    }

    #[test]
    fn poll_respects_max() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s = b.subscribe("t");
        for i in 0..25 {
            b.publish("t", Json::Num(i as f64));
        }
        assert_eq!(b.poll(s, 10).len(), 10);
        assert_eq!(b.poll(s, 10).len(), 10);
        assert_eq!(b.poll(s, 10).len(), 5);
    }

    #[test]
    fn double_ack_is_noop() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s = b.subscribe("t");
        b.publish("t", Json::Null);
        let d = b.poll(s, 1);
        assert!(b.ack(s, d[0].id));
        assert!(!b.ack(s, d[0].id));
        assert_eq!(b.stats().acked, 1);
    }

    #[test]
    fn publish_many_matches_per_message_path() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s1 = b.subscribe("t");
        let s2 = b.subscribe("t");
        let depth = b.publish_many("t", (0..10).map(|i| Json::Num(i as f64)).collect());
        assert_eq!(depth, 10);
        for sub in [s1, s2] {
            let ds = b.poll(sub, 100);
            assert_eq!(ds.len(), 10, "fan-out must reach every subscriber");
            let payloads: Vec<f64> = ds.iter().filter_map(|d| d.payload.as_f64()).collect();
            assert_eq!(payloads, (0..10).map(|i| i as f64).collect::<Vec<_>>(), "order kept");
        }
        assert_eq!(b.stats().published, 10);
        // empty batch is a no-op
        assert_eq!(b.publish_many("t", Vec::new()), 0);
        assert_eq!(b.stats().published, 10);
    }

    #[test]
    fn ack_many_acks_batch_and_skips_unknown() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s = b.subscribe("t");
        b.publish_many("t", (0..5).map(|i| Json::Num(i as f64)).collect());
        let ds = b.poll(s, 10);
        let mut ids: Vec<MsgId> = ds.iter().map(|d| d.id).collect();
        ids.push(999_999_999); // unknown: skipped, not an error
        assert_eq!(b.ack_many(s, &ids), 5);
        assert_eq!(b.ack_many(s, &ids), 0, "double ack is a no-op");
        assert_eq!(b.stats().acked, 5);
        assert_eq!(b.backlog(s), 0);
    }

    #[test]
    fn stats_track_flow() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s = b.subscribe("t");
        for _ in 0..5 {
            b.publish("t", Json::Null);
        }
        let ds = b.poll(s, 100);
        for d in &ds {
            b.ack(s, d.id);
        }
        let st = b.stats();
        assert_eq!(st.published, 5);
        assert_eq!(st.delivered, 5);
        assert_eq!(st.acked, 5);
        assert_eq!(st.redelivered, 0);
    }

    #[test]
    fn snapshot_roundtrip_preserves_backlogs_and_inflight() {
        let clock = SimClock::new();
        let b = Broker::new(clock.clone()).with_redelivery_timeout(10.0);
        let s1 = b.subscribe("alpha");
        let s2 = b.subscribe("alpha");
        let s3 = b.subscribe("beta");
        b.publish_many("alpha", (0..6).map(|i| Json::Num(i as f64)).collect());
        b.publish("beta", Json::Str("b".into()));
        // s1: 2 in flight (unacked), 1 acked, 3 pending; s2: untouched
        let ds = b.poll(s1, 3);
        assert!(b.ack(s1, ds[2].id));
        let snap = b.snapshot_json();

        let clock2 = SimClock::new();
        let b2 = Broker::new(clock2.clone()).with_redelivery_timeout(10.0);
        b2.restore(&snap).unwrap();
        assert_eq!(b2.backlog(s1), 5, "2 in flight + 3 pending");
        assert_eq!(b2.backlog(s2), 6);
        assert_eq!(b2.backlog(s3), 1);
        // the canonical form is stable across the round trip
        assert_eq!(snap, b2.snapshot_json());
        // in-flight stays invisible until the re-armed timeout passes,
        // then comes back flagged as redelivered
        assert_eq!(b2.poll(s1, 2).len(), 2, "pending still polls (fresh)");
        clock2.advance_by(11.0);
        let redelivered: Vec<_> =
            b2.poll(s1, 10).into_iter().filter(|d| d.redelivered).collect();
        assert_eq!(redelivered.len(), 4, "2 restored in-flight + 2 just-delivered");
        assert_eq!(
            redelivered.iter().filter(|d| ds.iter().any(|o| o.id == d.id)).count(),
            2,
            "the pre-snapshot in-flight ids survive verbatim"
        );
    }

    #[test]
    fn restore_rejects_dangling_message_refs() {
        let bad = Json::obj().set(
            "topics",
            Json::Arr(vec![Json::obj()
                .set("name", "t")
                .set("messages", Json::Arr(vec![]))
                .set(
                    "subs",
                    Json::Arr(vec![Json::obj()
                        .set("id", 7u64)
                        .set("pending", Json::Arr(vec![Json::from(99u64)]))
                        .set("in_flight", Json::Arr(vec![]))]),
                )]),
        );
        let b = Broker::new(Arc::new(WallClock::new()));
        assert!(b.restore(&bad).is_err());
        // nothing was installed
        assert_eq!(b.health_json().get("topics").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn replay_converges_over_a_snapshot_overlap() {
        // live sequence: subscribe, publish 3, deliver 2, ack 1
        let sub = 1_000_001u64;
        let msgs: Vec<(u64, Json)> =
            (0..3).map(|i| (2_000_000 + i, Json::Num(i as f64))).collect();
        let subscribe = PersistEvent::BrokerSubscribe { sub, topic: "t".into() };
        let publish = PersistEvent::BrokerPublish {
            topic: "t".into(),
            subs: vec![sub],
            msgs: msgs.clone(),
        };
        let deliver = PersistEvent::BrokerDeliver { sub, ids: vec![msgs[0].0, msgs[1].0] };
        let ack = PersistEvent::BrokerAck { sub, ids: vec![msgs[0].0] };

        let live = Broker::new(Arc::new(WallClock::new()));
        for ev in [&subscribe, &publish, &deliver, &ack] {
            live.apply_event(ev);
        }
        // a recovered broker restores the snapshot, then replays a suffix
        // that overlaps it — each replayed event must be idempotent
        let recovered = Broker::new(Arc::new(WallClock::new()));
        recovered.restore(&live.snapshot_json()).unwrap();
        for ev in [&subscribe, &publish, &deliver, &ack] {
            recovered.apply_event(ev);
        }
        assert_eq!(live.snapshot_json(), recovered.snapshot_json());
        assert_eq!(recovered.backlog(sub), 2, "1 in flight + 1 pending");
    }

    #[test]
    fn unsubscribe_drops_queue_and_fanout() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s1 = b.subscribe("t");
        let s2 = b.subscribe("t");
        b.publish("t", Json::Num(1.0));
        assert!(b.unsubscribe(s1));
        assert!(!b.unsubscribe(s1), "idempotent");
        assert_eq!(b.backlog(s1), 0, "backlog discarded");
        assert!(b.poll(s1, 10).is_empty(), "unknown subscriber polls empty");
        b.publish("t", Json::Num(2.0));
        assert_eq!(b.poll(s2, 10).len(), 2, "remaining subscriber unaffected");
        let h = b.health_json();
        assert_eq!(h.get("subscriptions").unwrap().as_u64(), Some(1));
        // the dropped queue leaves the snapshot too
        let snap = b.snapshot_json();
        let b2 = Broker::new(Arc::new(WallClock::new()));
        b2.restore(&snap).unwrap();
        assert_eq!(b2.health_json().get("subscriptions").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn last_unsubscribe_garbage_collects_the_topic() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let solo = b.subscribe("ephemeral");
        b.publish("ephemeral", Json::Num(1.0));
        assert_eq!(b.health_json().get("topics").unwrap().as_u64(), Some(1));
        assert!(b.unsubscribe(solo));
        let h = b.health_json();
        assert_eq!(h.get("topics").unwrap().as_u64(), Some(0), "empty shell must be GC'd");
        assert_eq!(h.get("subscriptions").unwrap().as_u64(), Some(0));
        // GC'd topics leave the snapshot too
        assert_eq!(b.snapshot_json().get("topics").unwrap().as_arr().unwrap().len(), 0);
        // the name is immediately reusable
        let again = b.subscribe("ephemeral");
        b.publish("ephemeral", Json::Num(2.0));
        assert_eq!(b.poll(again, 10).len(), 1);
        assert!(b.poll(again, 10).is_empty(), "no stale messages from the old shell");
    }

    #[test]
    fn replayed_publish_skips_subscribers_that_joined_later() {
        // the snapshot may already contain a subscriber that joined AFTER
        // a suffix publish; the event's recorded fan-out set must win
        let early = 3_000_001u64;
        let late = 3_000_002u64;
        let b = Broker::new(Arc::new(WallClock::new()));
        b.apply_event(&PersistEvent::BrokerSubscribe { sub: early, topic: "t".into() });
        b.apply_event(&PersistEvent::BrokerSubscribe { sub: late, topic: "t".into() });
        b.apply_event(&PersistEvent::BrokerPublish {
            topic: "t".into(),
            subs: vec![early], // late was not subscribed at publish time
            msgs: vec![(3_000_010, Json::Num(1.0))],
        });
        assert_eq!(b.backlog(early), 1);
        assert_eq!(b.backlog(late), 0, "fan-out is at publish time, even on replay");
    }

    #[test]
    fn delta_section_tracks_touched_topics_and_removals() {
        let clock = SimClock::new();
        let b = Broker::new(clock.clone()).with_redelivery_timeout(10.0);
        b.enable_dirty_tracking();
        let s1 = b.subscribe("alpha");
        let _s2 = b.subscribe("beta");
        let doomed = b.subscribe("gamma");
        b.publish_many("alpha", (0..3).map(|i| Json::Num(i as f64)).collect());
        b.publish("beta", Json::Num(9.0));
        let base_names = b.take_dirty_topics();
        assert_eq!(base_names, vec!["alpha", "beta", "gamma"]);
        let base = b.snapshot_json();
        assert!(b.take_dirty_topics().is_empty(), "drain resets the set");

        // churn: alpha polls+acks, gamma's last subscriber leaves, beta idle
        let ds = b.poll(s1, 2);
        assert!(b.ack(s1, ds[0].id));
        assert!(b.unsubscribe(doomed));
        let touched = b.take_dirty_topics();
        assert_eq!(touched, vec!["alpha", "gamma"], "beta was not touched");
        let delta = b.delta_json(&touched);
        assert_eq!(delta.get("topics").unwrap().as_arr().unwrap().len(), 1, "alpha only");
        assert_eq!(
            delta.get("removed").unwrap().as_arr().unwrap().to_vec(),
            vec![Json::Str("gamma".into())],
            "GC'd topics encode as removals"
        );
        Broker::validate_delta(&delta).unwrap();

        // fold base + delta → decodes to exactly the live broker
        let mut folded = base;
        Broker::fold_snapshot_section(&mut folded, &delta);
        assert_eq!(folded, b.snapshot_json(), "base+delta fold must equal live");
        let b2 = Broker::new(SimClock::new()).with_redelivery_timeout(10.0);
        b2.restore(&folded).unwrap();
        assert_eq!(b2.snapshot_json(), b.snapshot_json());
        assert_eq!(b2.backlog(s1), 2, "1 pending + 1 un-acked in-flight");
        assert_eq!(b2.backlog(doomed), 0);
        // a failed checkpoint hands the names back
        b.restore_dirty_topics(touched.clone());
        assert_eq!(b.dirty_topic_count(), 2);
        assert_eq!(b.take_dirty_topics(), touched);
    }

    #[test]
    fn fold_snapshot_section_handles_recreated_topics() {
        // delta1 removes X; delta2 re-creates it — sequential folds win
        let base = Json::obj().set(
            "topics",
            Json::Arr(vec![Json::obj()
                .set("name", "x")
                .set("messages", Json::Arr(vec![]))
                .set(
                    "subs",
                    Json::Arr(vec![Json::obj()
                        .set("id", 1u64)
                        .set("pending", Json::Arr(vec![]))
                        .set("in_flight", Json::Arr(vec![]))]),
                )]),
        );
        let mut folded = base.clone();
        let d1 = Json::obj()
            .set("topics", Json::Arr(vec![]))
            .set("removed", Json::Arr(vec![Json::Str("x".into())]));
        Broker::fold_snapshot_section(&mut folded, &d1);
        assert!(folded.get("topics").unwrap().as_arr().unwrap().is_empty());
        let d2 = Json::obj()
            .set(
                "topics",
                Json::Arr(vec![Json::obj()
                    .set("name", "x")
                    .set("messages", Json::Arr(vec![]))
                    .set(
                        "subs",
                        Json::Arr(vec![Json::obj()
                            .set("id", 2u64)
                            .set("pending", Json::Arr(vec![]))
                            .set("in_flight", Json::Arr(vec![]))]),
                    )]),
            )
            .set("removed", Json::Arr(vec![]));
        Broker::fold_snapshot_section(&mut folded, &d2);
        let topics = folded.get("topics").unwrap().as_arr().unwrap();
        assert_eq!(topics.len(), 1);
        assert_eq!(
            topics[0].get_path(&["subs"]).unwrap().as_arr().unwrap()[0]
                .get("id")
                .unwrap()
                .as_u64(),
            Some(2),
            "the re-created topic's state wins"
        );
    }

    #[test]
    fn health_json_reports_topology_and_backlog() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s = b.subscribe("t");
        b.subscribe("t");
        b.subscribe("u");
        b.publish_many("t", (0..4).map(|i| Json::Num(i as f64)).collect());
        b.poll(s, 1);
        let h = b.health_json();
        assert_eq!(h.get("topics").unwrap().as_u64(), Some(2));
        assert_eq!(h.get("subscriptions").unwrap().as_u64(), Some(3));
        assert_eq!(h.get("pending").unwrap().as_u64(), Some(7), "3 + 4 still queued");
        assert_eq!(h.get("in_flight").unwrap().as_u64(), Some(1));
        assert_eq!(h.get("published").unwrap().as_u64(), Some(4));
    }
}
