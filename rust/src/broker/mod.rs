//! In-process message broker (ActiveMQ stand-in).
//!
//! The Conductor publishes availability notifications here; consumers
//! (WFM jobs, downstream Works, the Rubin incremental-release path)
//! subscribe. Semantics match what iDDS needs from its production broker:
//!
//! * topics with independent subscriber queues (fan-out),
//! * at-least-once delivery: a message stays "in flight" per subscriber
//!   until acked; unacked messages past the redelivery timeout are
//!   redelivered (property-tested in `rust/tests`),
//! * bounded queues with backpressure signalling (publish returns the
//!   queue depth so producers can throttle),
//! * batched `publish_many`/`ack_many` so high-rate producers/consumers
//!   (the Conductor's per-tick fan-out) take the broker mutex once per
//!   batch instead of once per message.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::util::clock::Clock;
use crate::util::json::Json;

pub type MsgId = u64;
pub type SubId = u64;

#[derive(Debug, Clone)]
pub struct Delivery {
    pub id: MsgId,
    pub topic: String,
    pub payload: Json,
    pub redelivered: bool,
}

struct InFlight {
    msg: Arc<QueuedMsg>,
    deadline: f64,
}

struct QueuedMsg {
    id: MsgId,
    topic: String,
    payload: Json,
}

struct SubQueue {
    pending: VecDeque<Arc<QueuedMsg>>,
    in_flight: HashMap<MsgId, InFlight>,
    delivered_once: std::collections::HashSet<MsgId>,
}

struct TopicState {
    subs: Vec<SubId>,
}

struct Inner {
    topics: HashMap<String, TopicState>,
    queues: HashMap<SubId, SubQueue>,
    published: u64,
    delivered: u64,
    redelivered: u64,
    acked: u64,
}

/// The broker. Clone-shareable.
#[derive(Clone)]
pub struct Broker {
    inner: Arc<Mutex<Inner>>,
    clock: Arc<dyn Clock>,
    redelivery_timeout: f64,
    max_queue: usize,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BrokerStats {
    pub published: u64,
    pub delivered: u64,
    pub redelivered: u64,
    pub acked: u64,
}

impl Broker {
    pub fn new(clock: Arc<dyn Clock>) -> Self {
        Broker {
            inner: Arc::new(Mutex::new(Inner {
                topics: HashMap::new(),
                queues: HashMap::new(),
                published: 0,
                delivered: 0,
                redelivered: 0,
                acked: 0,
            })),
            clock,
            redelivery_timeout: 30.0,
            max_queue: 1_000_000,
        }
    }

    pub fn with_redelivery_timeout(mut self, secs: f64) -> Self {
        self.redelivery_timeout = secs;
        self
    }

    /// Subscribe to a topic; returns the subscriber handle.
    pub fn subscribe(&self, topic: &str) -> SubId {
        let id = crate::util::next_id();
        let mut inner = self.inner.lock().unwrap();
        inner
            .topics
            .entry(topic.to_string())
            .or_insert_with(|| TopicState { subs: Vec::new() })
            .subs
            .push(id);
        inner.queues.insert(
            id,
            SubQueue {
                pending: VecDeque::new(),
                in_flight: HashMap::new(),
                delivered_once: std::collections::HashSet::new(),
            },
        );
        id
    }

    /// Publish to a topic, fanning out to all subscribers. Returns the max
    /// subscriber queue depth (backpressure signal) — 0 if no subscribers.
    pub fn publish(&self, topic: &str, payload: Json) -> usize {
        self.publish_many(topic, vec![payload])
    }

    /// Publish a whole batch to a topic under **one lock acquisition** —
    /// the Conductor's per-tick fan-out takes the broker mutex once
    /// instead of once per message. Returns the max subscriber queue
    /// depth after the batch (backpressure signal) — 0 if no subscribers.
    pub fn publish_many(&self, topic: &str, payloads: Vec<Json>) -> usize {
        if payloads.is_empty() {
            return 0;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.published += payloads.len() as u64;
        let msgs: Vec<Arc<QueuedMsg>> = payloads
            .into_iter()
            .map(|payload| {
                Arc::new(QueuedMsg {
                    id: crate::util::next_id(),
                    topic: topic.to_string(),
                    payload,
                })
            })
            .collect();
        let subs = inner
            .topics
            .get(topic)
            .map(|t| t.subs.clone())
            .unwrap_or_default();
        let mut depth = 0;
        for sub in subs {
            if let Some(q) = inner.queues.get_mut(&sub) {
                for msg in &msgs {
                    if q.pending.len() < self.max_queue {
                        q.pending.push_back(Arc::clone(msg));
                    }
                }
                depth = depth.max(q.pending.len());
            }
        }
        depth
    }

    /// Poll up to `max` messages for a subscriber. Redelivers expired
    /// in-flight messages first.
    pub fn poll(&self, sub: SubId, max: usize) -> Vec<Delivery> {
        let now = self.clock.now();
        let mut inner = self.inner.lock().unwrap();
        let timeout = self.redelivery_timeout;
        let mut out = Vec::new();
        let mut redelivered_n = 0;
        let mut delivered_n = 0;
        if let Some(q) = inner.queues.get_mut(&sub) {
            // expire in-flight
            let expired: Vec<MsgId> = q
                .in_flight
                .iter()
                .filter(|(_, f)| f.deadline <= now)
                .map(|(id, _)| *id)
                .collect();
            for id in expired {
                if out.len() >= max {
                    break;
                }
                let mut f = q.in_flight.remove(&id).unwrap();
                f.deadline = now + timeout;
                out.push(Delivery {
                    id,
                    topic: f.msg.topic.clone(),
                    payload: f.msg.payload.clone(),
                    redelivered: true,
                });
                redelivered_n += 1;
                q.in_flight.insert(id, f);
            }
            // fresh messages
            while out.len() < max {
                let Some(msg) = q.pending.pop_front() else { break };
                let redelivered = !q.delivered_once.insert(msg.id);
                out.push(Delivery {
                    id: msg.id,
                    topic: msg.topic.clone(),
                    payload: msg.payload.clone(),
                    redelivered,
                });
                delivered_n += 1;
                q.in_flight.insert(
                    msg.id,
                    InFlight {
                        msg,
                        deadline: now + timeout,
                    },
                );
            }
        }
        inner.delivered += delivered_n;
        inner.redelivered += redelivered_n;
        out
    }

    /// Acknowledge a delivery; the message will not be redelivered.
    pub fn ack(&self, sub: SubId, msg: MsgId) -> bool {
        self.ack_many(sub, &[msg]) == 1
    }

    /// Acknowledge a batch of deliveries under one lock acquisition.
    /// Returns how many were actually in flight (already-acked or unknown
    /// ids are skipped, matching [`Broker::ack`]).
    pub fn ack_many(&self, sub: SubId, msgs: &[MsgId]) -> usize {
        if msgs.is_empty() {
            return 0;
        }
        let mut inner = self.inner.lock().unwrap();
        let mut n = 0u64;
        if let Some(q) = inner.queues.get_mut(&sub) {
            for msg in msgs {
                if q.in_flight.remove(msg).is_some() {
                    n += 1;
                }
            }
        }
        inner.acked += n;
        n as usize
    }

    /// Outstanding (pending + in-flight) for a subscriber.
    pub fn backlog(&self, sub: SubId) -> usize {
        let inner = self.inner.lock().unwrap();
        inner
            .queues
            .get(&sub)
            .map(|q| q.pending.len() + q.in_flight.len())
            .unwrap_or(0)
    }

    pub fn stats(&self) -> BrokerStats {
        let inner = self.inner.lock().unwrap();
        BrokerStats {
            published: inner.published,
            delivered: inner.delivered,
            redelivered: inner.redelivered,
            acked: inner.acked,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::{SimClock, WallClock};

    #[test]
    fn fanout_to_all_subscribers() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s1 = b.subscribe("t");
        let s2 = b.subscribe("t");
        b.publish("t", Json::Num(1.0));
        assert_eq!(b.poll(s1, 10).len(), 1);
        assert_eq!(b.poll(s2, 10).len(), 1);
    }

    #[test]
    fn topics_are_isolated() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s1 = b.subscribe("a");
        b.publish("b", Json::Num(1.0));
        assert!(b.poll(s1, 10).is_empty());
    }

    #[test]
    fn ack_stops_redelivery() {
        let clock = SimClock::new();
        let b = Broker::new(clock.clone()).with_redelivery_timeout(10.0);
        let s = b.subscribe("t");
        b.publish("t", Json::Num(1.0));
        let d = b.poll(s, 10);
        assert_eq!(d.len(), 1);
        assert!(b.ack(s, d[0].id));
        clock.advance_by(100.0);
        assert!(b.poll(s, 10).is_empty());
        assert_eq!(b.backlog(s), 0);
    }

    #[test]
    fn unacked_messages_redeliver_after_timeout() {
        let clock = SimClock::new();
        let b = Broker::new(clock.clone()).with_redelivery_timeout(10.0);
        let s = b.subscribe("t");
        b.publish("t", Json::Str("x".into()));
        let d1 = b.poll(s, 10);
        assert_eq!(d1.len(), 1);
        assert!(!d1[0].redelivered);
        // before timeout: nothing
        clock.advance_by(5.0);
        assert!(b.poll(s, 10).is_empty());
        // after timeout: redelivered flag set
        clock.advance_by(6.0);
        let d2 = b.poll(s, 10);
        assert_eq!(d2.len(), 1);
        assert!(d2[0].redelivered);
        assert_eq!(d2[0].id, d1[0].id);
    }

    #[test]
    fn poll_respects_max() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s = b.subscribe("t");
        for i in 0..25 {
            b.publish("t", Json::Num(i as f64));
        }
        assert_eq!(b.poll(s, 10).len(), 10);
        assert_eq!(b.poll(s, 10).len(), 10);
        assert_eq!(b.poll(s, 10).len(), 5);
    }

    #[test]
    fn double_ack_is_noop() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s = b.subscribe("t");
        b.publish("t", Json::Null);
        let d = b.poll(s, 1);
        assert!(b.ack(s, d[0].id));
        assert!(!b.ack(s, d[0].id));
        assert_eq!(b.stats().acked, 1);
    }

    #[test]
    fn publish_many_matches_per_message_path() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s1 = b.subscribe("t");
        let s2 = b.subscribe("t");
        let depth = b.publish_many("t", (0..10).map(|i| Json::Num(i as f64)).collect());
        assert_eq!(depth, 10);
        for sub in [s1, s2] {
            let ds = b.poll(sub, 100);
            assert_eq!(ds.len(), 10, "fan-out must reach every subscriber");
            let payloads: Vec<f64> = ds.iter().filter_map(|d| d.payload.as_f64()).collect();
            assert_eq!(payloads, (0..10).map(|i| i as f64).collect::<Vec<_>>(), "order kept");
        }
        assert_eq!(b.stats().published, 10);
        // empty batch is a no-op
        assert_eq!(b.publish_many("t", Vec::new()), 0);
        assert_eq!(b.stats().published, 10);
    }

    #[test]
    fn ack_many_acks_batch_and_skips_unknown() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s = b.subscribe("t");
        b.publish_many("t", (0..5).map(|i| Json::Num(i as f64)).collect());
        let ds = b.poll(s, 10);
        let mut ids: Vec<MsgId> = ds.iter().map(|d| d.id).collect();
        ids.push(999_999_999); // unknown: skipped, not an error
        assert_eq!(b.ack_many(s, &ids), 5);
        assert_eq!(b.ack_many(s, &ids), 0, "double ack is a no-op");
        assert_eq!(b.stats().acked, 5);
        assert_eq!(b.backlog(s), 0);
    }

    #[test]
    fn stats_track_flow() {
        let b = Broker::new(Arc::new(WallClock::new()));
        let s = b.subscribe("t");
        for _ in 0..5 {
            b.publish("t", Json::Null);
        }
        let ds = b.poll(s, 100);
        for d in &ds {
            b.ack(s, d.id);
        }
        let st = b.stats();
        assert_eq!(st.published, 5);
        assert_eq!(st.delivered, 5);
        assert_eq!(st.acked, 5);
        assert_eq!(st.redelivered, 0);
    }
}
