//! Workload generators + scenario presets shared by examples and benches
//! (paper section 3: the use-case portfolio iDDS was deployed against).
//!
//! Everything the paper's production environment supplied (reprocessing
//! campaigns on tape, Rubin payload DAGs, HPO task mixes) is synthesized
//! here with explicit seeds so every figure is regenerable bit-for-bit.
//! A [`Scenario`] names a campaign preset; `idds carousel --scenario NAME`
//! and the bench targets map their arguments onto these.

use crate::carousel::{CampaignSpec, CarouselConfig, Granularity};

/// Named campaign scenarios (bench arguments map onto these).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// quick CI-sized run
    Smoke,
    /// the Fig. 4 / Fig. 5 default: a mid-size reprocessing slice
    Reprocessing,
    /// stress: many small files (granularity matters most here)
    SmallFiles,
    /// few huge files (tape bandwidth dominated)
    BigFiles,
}

impl Scenario {
    /// Parse a CLI scenario name (`smoke`, `reprocessing`, `smallfiles`,
    /// `bigfiles`).
    pub fn parse(s: &str) -> Option<Scenario> {
        match s {
            "smoke" => Some(Scenario::Smoke),
            "reprocessing" => Some(Scenario::Reprocessing),
            "smallfiles" => Some(Scenario::SmallFiles),
            "bigfiles" => Some(Scenario::BigFiles),
            _ => None,
        }
    }

    /// The campaign shape (datasets, files, sizes, tape layout, seed)
    /// this scenario drives through the carousel.
    pub fn campaign(&self) -> CampaignSpec {
        match self {
            Scenario::Smoke => CampaignSpec {
                datasets: 2,
                files_per_dataset: 100,
                mean_file_mb: 1000.0,
                cartridges_per_dataset: 2,
                seed: 7,
            },
            Scenario::Reprocessing => CampaignSpec {
                datasets: 6,
                files_per_dataset: 800,
                mean_file_mb: 2000.0,
                cartridges_per_dataset: 4,
                seed: 7,
            },
            Scenario::SmallFiles => CampaignSpec {
                datasets: 4,
                files_per_dataset: 3000,
                mean_file_mb: 200.0,
                cartridges_per_dataset: 6,
                seed: 7,
            },
            Scenario::BigFiles => CampaignSpec {
                datasets: 2,
                files_per_dataset: 150,
                mean_file_mb: 20000.0,
                cartridges_per_dataset: 3,
                seed: 7,
            },
        }
    }

    /// Carousel configuration for this scenario at the given staging
    /// granularity (the smoke preset shrinks the substrate for CI).
    pub fn config(&self, granularity: Granularity) -> CarouselConfig {
        let mut cfg = CarouselConfig {
            granularity,
            ..Default::default()
        };
        if *self == Scenario::Smoke {
            cfg.tape_drives = 2;
            cfg.sites = 2;
            cfg.slots_per_site = 16;
        }
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carousel::run_campaign;

    #[test]
    fn scenario_parse_roundtrip() {
        for (name, s) in [
            ("smoke", Scenario::Smoke),
            ("reprocessing", Scenario::Reprocessing),
            ("smallfiles", Scenario::SmallFiles),
            ("bigfiles", Scenario::BigFiles),
        ] {
            assert_eq!(Scenario::parse(name), Some(s));
        }
        assert_eq!(Scenario::parse("nope"), None);
    }

    #[test]
    fn smoke_scenario_runs_both_modes() {
        let spec = Scenario::Smoke.campaign();
        for g in [Granularity::Coarse, Granularity::Fine] {
            let r = run_campaign(&Scenario::Smoke.config(g), &spec);
            assert_eq!(r.files, 200);
            assert!(r.makespan_s > 0.0);
        }
    }
}
