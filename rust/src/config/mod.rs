//! Layered configuration system: compiled defaults ← JSON config file ←
//! `--set key=value` CLI overrides.
//!
//! Every tunable in the service (daemon poll intervals, REST bind address,
//! simulator parameters, HPO settings) resolves through one [`Config`] so
//! examples/benches/tests can express scenarios declaratively.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{parse, Json};

/// Flat dotted-key configuration. Values are stored as [`Json`] scalars.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, Json>,
}

impl Config {
    /// Compiled-in defaults for the full service.
    pub fn defaults() -> Self {
        let mut c = Config::default();
        // REST head service
        c.put("rest.bind", Json::Str("127.0.0.1:0".into()));
        c.put("rest.workers", Json::Num(8.0));
        c.put("rest.auth_tokens", Json::Arr(vec![Json::Str("dev-token".into())]));
        // connection admission + deadlines (see rest::http::ServerOptions)
        c.put("rest.max_connections", Json::Num(10_240.0));
        c.put("rest.max_inflight", Json::Num(512.0));
        c.put("rest.header_timeout_s", Json::Num(10.0));
        c.put("rest.body_timeout_s", Json::Num(30.0));
        c.put("rest.idle_timeout_s", Json::Num(60.0));
        // daemons
        c.put("daemons.poll_interval_s", Json::Num(0.01));
        c.put("daemons.batch_size", Json::Num(256.0));
        // durability (persist/): empty data_dir = in-memory only
        c.put("persist.data_dir", Json::Str(String::new()));
        c.put("persist.segment_bytes", Json::Num(8.0 * 1024.0 * 1024.0));
        c.put("persist.checkpoint_interval_s", Json::Num(300.0));
        c.put("persist.checkpoint_keep", Json::Num(2.0));
        c.put("persist.fsync", Json::Str("group".into()));
        c.put("persist.flush_idle_ms", Json::Num(50.0));
        // delta checkpoints: auto-compact to a base past either bound
        c.put("persist.delta_chain_max", Json::Num(8.0));
        c.put("persist.delta_dirty_ratio", Json::Num(0.5));
        // synchronous submits: POST /api/requests returns 201 only after
        // the group-commit flusher fsynced the submit's LSN
        c.put("persist.sync_submit", Json::Bool(false));
        // fault injection (tests/chaos drills): comma-separated
        // `site=always|<count>` entries, e.g. "wal.fsync=always" — see
        // persist::failpoints for the site table; empty = disabled
        c.put("persist.failpoints", Json::Str(String::new()));
        // replication (persist/replicate): primary address for standby
        // mode (empty = standalone; `idds serve --replica-of ADDR` sets it)
        c.put("replication.primary", Json::Str(String::new()));
        c.put("replication.poll_interval_ms", Json::Num(50.0));
        c.put("replication.batch_bytes", Json::Num(1024.0 * 1024.0));
        c.put("replication.retry_ms", Json::Num(200.0));
        // event bus (persist/bus + GET /api/events): per-subscriber queue
        // bound, daemon heartbeat when bus-armed (idle safety-net poll),
        // and the per-round byte cap for SSE catch-up reads from the WAL
        c.put("events.queue", Json::Num(1024.0));
        c.put("events.heartbeat_ms", Json::Num(500.0));
        c.put("events.catchup_batch_bytes", Json::Num(1024.0 * 1024.0));
        // broker: in-flight deliveries (and therefore work leases —
        // broker::lease rides the same machinery) redeliver after this
        // many seconds without an ack or a renewal
        c.put("broker.redelivery_timeout_s", Json::Num(30.0));
        // distributed workers: comma-separated Work kinds the head
        // delegates to the remote fleet via RemoteExecutor (empty = all
        // kinds execute in-process, no registry attached); the heartbeat
        // cadence and lease batch size are the `idds work` loop's knobs
        c.put("workers.remote_kinds", Json::Str(String::new()));
        c.put("workers.heartbeat_s", Json::Num(1.0));
        c.put("workers.lease_batch", Json::Num(4.0));
        // observability (obs/): span tracing, JSON-lines logging, and
        // the timeline recorder's per-series memory bound
        c.put("obs.trace.enabled", Json::Bool(true));
        c.put("obs.trace.ring_capacity", Json::Num(4096.0));
        c.put("obs.trace.slow_us", Json::Num(100_000.0));
        c.put("obs.log.level", Json::Str("info".into()));
        c.put("obs.log.repeat_window_s", Json::Num(5.0));
        c.put("obs.timeline.max_points", Json::Num(65536.0));
        // artifacts / runtime
        c.put("runtime.artifacts_dir", Json::Str("artifacts".into()));
        // DDM / tape simulator
        c.put("ddm.tape_bandwidth_mbps", Json::Num(400.0));
        c.put("ddm.disk_bandwidth_mbps", Json::Num(2000.0));
        c.put("tape.drives", Json::Num(8.0));
        c.put("tape.mount_latency_s", Json::Num(90.0));
        c.put("tape.seek_latency_s", Json::Num(20.0));
        // WFM simulator
        c.put("wfm.sites", Json::Num(16.0));
        c.put("wfm.slots_per_site", Json::Num(64.0));
        c.put("wfm.job_wall_s", Json::Num(3600.0));
        c.put("wfm.max_attempts", Json::Num(6.0));
        // HPO service
        c.put("hpo.max_points", Json::Num(64.0));
        c.put("hpo.candidates", Json::Num(256.0));
        c.put("hpo.workers", Json::Num(4.0));
        c
    }

    pub fn put(&mut self, key: &str, val: Json) {
        self.values.insert(key.to_string(), val);
    }

    /// Merge a JSON object file (nested objects flatten to dotted keys).
    pub fn load_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let json = parse(&text).with_context(|| format!("parsing config {}", path.display()))?;
        let obj = json
            .as_obj()
            .context("config root must be a JSON object")?;
        let mut stack: Vec<(String, &Json)> = obj
            .iter()
            .map(|(k, v)| (k.clone(), v))
            .collect();
        while let Some((key, val)) = stack.pop() {
            match val {
                Json::Obj(m) => {
                    for (k, v) in m {
                        stack.push((format!("{key}.{k}"), v));
                    }
                }
                v => self.put(&key, v.clone()),
            }
        }
        Ok(())
    }

    /// Apply a `key=value` override; value parsed as JSON, falling back to
    /// a plain string.
    pub fn apply_override(&mut self, kv: &str) -> Result<()> {
        let (k, v) = match kv.split_once('=') {
            Some(p) => p,
            None => bail!("override '{kv}' is not key=value"),
        };
        let val = parse(v).unwrap_or_else(|_| Json::Str(v.to_string()));
        self.put(k, val);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.values.get(key)
    }

    pub fn str(&self, key: &str) -> Result<String> {
        self.get(key)
            .and_then(|j| j.as_str())
            .map(str::to_string)
            .with_context(|| format!("config key '{key}' missing or not a string"))
    }

    pub fn f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(|j| j.as_f64())
            .with_context(|| format!("config key '{key}' missing or not a number"))
    }

    pub fn u64(&self, key: &str) -> Result<u64> {
        self.get(key)
            .and_then(|j| j.as_u64())
            .with_context(|| format!("config key '{key}' missing or not a u64"))
    }

    pub fn usize(&self, key: &str) -> Result<usize> {
        Ok(self.u64(key)? as usize)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_present() {
        let c = Config::defaults();
        assert_eq!(c.u64("tape.drives").unwrap(), 8);
        assert!(c.str("rest.bind").unwrap().starts_with("127."));
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::defaults();
        c.apply_override("tape.drives=2").unwrap();
        assert_eq!(c.u64("tape.drives").unwrap(), 2);
        c.apply_override("rest.bind=\"0.0.0.0:8443\"").unwrap();
        assert_eq!(c.str("rest.bind").unwrap(), "0.0.0.0:8443");
        // non-JSON value falls back to string
        c.apply_override("foo.bar=hello").unwrap();
        assert_eq!(c.str("foo.bar").unwrap(), "hello");
    }

    #[test]
    fn bad_override_rejected() {
        let mut c = Config::defaults();
        assert!(c.apply_override("no-equals").is_err());
    }

    #[test]
    fn file_flattening() {
        let dir = std::env::temp_dir().join(format!("idds-cfg-{}", crate::util::next_id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        std::fs::write(&p, r#"{"tape": {"drives": 3}, "top": 1}"#).unwrap();
        let mut c = Config::defaults();
        c.load_file(&p).unwrap();
        assert_eq!(c.u64("tape.drives").unwrap(), 3);
        assert_eq!(c.u64("top").unwrap(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_key_errors() {
        let c = Config::defaults();
        assert!(c.str("nope").is_err());
        assert!(c.f64("rest.bind").is_err());
    }
}
