//! Append-only write-ahead log with length+CRC-framed records, group
//! commit, and segment rotation.
//!
//! On-disk layout (`<data_dir>/wal/`):
//!
//! ```text
//! wal-00000001.log := MAGIC frame*            MAGIC = b"IDDSWAL1"
//! frame            := len:u32le crc:u32le payload
//! payload          := lsn:u64le event-json-utf8
//! ```
//!
//! `crc` is CRC-32 (IEEE) over the payload; `len` is the payload length. A
//! reader stops at the first frame whose header, length bound, or CRC does
//! not check out — that is the torn tail a crash can leave, and recovery
//! physically truncates it.
//!
//! **Group commit**: writers (store mutators holding row/index locks) only
//! enqueue `(lsn, event)` pairs under the queue mutex — LSNs are assigned
//! at enqueue time, so queue order is exactly application order for any
//! single id (the store logs while holding the lock that ordered the
//! mutation). A single flusher thread drains the queue, encodes all
//! pending frames, issues **one write + one fsync** for the whole batch,
//! then publishes the new durable LSN to [`Wal::sync`] waiters. Encoding
//! happens on the flusher thread, off the store's hot path.

use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use anyhow::{Context, Result};

use crate::metrics::{Counter, Gauge, Registry};
use crate::util::json::parse;

use super::bus::EventBus;
use super::events::{PersistEvent, Persister};
use super::FsyncMode;

pub(crate) const SEGMENT_MAGIC: &[u8; 8] = b"IDDSWAL1";
const FRAME_HEADER: usize = 8;
/// Upper bound on a single frame payload — anything larger is treated as
/// a torn/corrupt header during scans.
pub(crate) const MAX_FRAME: u32 = 256 * 1024 * 1024;
/// Backpressure bound on the group-commit queue: when the flusher cannot
/// keep up (stalled disk), writers block here instead of growing memory
/// without limit until an OOM kill loses everything. Generous — normal
/// bursts never come close.
const MAX_PENDING: usize = 1 << 20;

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3), table-driven; no external crates offline.
// ---------------------------------------------------------------------------

pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        let mut i = 0u32;
        while i < 256 {
            let mut c = i;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            t[i as usize] = c;
            i += 1;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Frame codec
// ---------------------------------------------------------------------------

/// Append one framed record (`lsn` + serialized event) to `out`.
pub(crate) fn encode_frame(lsn: u64, event_json: &str, out: &mut Vec<u8>) {
    let payload_len = 8 + event_json.len();
    out.reserve(FRAME_HEADER + payload_len);
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
    // crc computed over the payload; stage it after the header, then patch
    let crc_pos = out.len();
    out.extend_from_slice(&[0u8; 4]);
    let payload_pos = out.len();
    out.extend_from_slice(&lsn.to_le_bytes());
    out.extend_from_slice(event_json.as_bytes());
    let crc = crc32(&out[payload_pos..]);
    out[crc_pos..crc_pos + 4].copy_from_slice(&crc.to_le_bytes());
}

/// Why a segment scan stopped.
#[derive(Debug, Clone, PartialEq)]
pub enum ScanEnd {
    /// Every byte consumed, all frames valid.
    Clean,
    /// A bad header/length/CRC at `valid_len` — the torn tail starts there.
    Torn { valid_len: u64, reason: String },
}

/// Decoded frames of one segment plus how the scan ended.
pub struct SegmentScan {
    pub events: Vec<(u64, PersistEvent)>,
    pub end: ScanEnd,
    pub file_len: u64,
}

/// Strictly decode a buffer of shipped frames (no segment magic prefix).
/// Unlike [`scan_segment`], which tolerates a torn tail on a crashed
/// writer's own disk, a replication batch travels over TCP after being
/// read from fully-durable bytes — anything short or corrupt means the
/// transfer itself is damaged, so the whole batch is rejected.
pub fn decode_frames(buf: &[u8]) -> Result<Vec<(u64, PersistEvent)>> {
    let mut events = Vec::new();
    let mut off = 0usize;
    while off < buf.len() {
        anyhow::ensure!(buf.len() - off >= FRAME_HEADER, "partial frame header at {off}");
        let len = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
        let crc = u32::from_le_bytes(buf[off + 4..off + 8].try_into().unwrap());
        anyhow::ensure!(
            len >= 8 && len <= MAX_FRAME && buf.len() - off - FRAME_HEADER >= len as usize,
            "implausible frame length {len} at {off}"
        );
        let payload = &buf[off + FRAME_HEADER..off + FRAME_HEADER + len as usize];
        anyhow::ensure!(crc32(payload) == crc, "frame crc mismatch at {off}");
        let lsn = u64::from_le_bytes(payload[..8].try_into().unwrap());
        let text = std::str::from_utf8(&payload[8..]).context("frame payload not utf-8")?;
        let ev = parse(text)
            .map_err(anyhow::Error::from)
            .and_then(|j| PersistEvent::from_json(&j))
            .with_context(|| format!("undecodable event at lsn {lsn}"))?;
        events.push((lsn, ev));
        off += FRAME_HEADER + len as usize;
    }
    Ok(events)
}

/// Read and validate one segment file front to back.
pub fn scan_segment(path: &Path) -> Result<SegmentScan> {
    let mut bytes = Vec::new();
    File::open(path)
        .and_then(|mut f| f.read_to_end(&mut bytes))
        .with_context(|| format!("reading wal segment {}", path.display()))?;
    let file_len = bytes.len() as u64;
    if bytes.len() < SEGMENT_MAGIC.len() || &bytes[..SEGMENT_MAGIC.len()] != SEGMENT_MAGIC {
        return Ok(SegmentScan {
            events: Vec::new(),
            end: ScanEnd::Torn { valid_len: 0, reason: "bad segment magic".into() },
            file_len,
        });
    }
    let mut events = Vec::new();
    let mut off = SEGMENT_MAGIC.len();
    let end = loop {
        if off == bytes.len() {
            break ScanEnd::Clean;
        }
        if bytes.len() - off < FRAME_HEADER {
            break ScanEnd::Torn {
                valid_len: off as u64,
                reason: "partial frame header".into(),
            };
        }
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        let crc = u32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap());
        if len < 8 || len > MAX_FRAME || bytes.len() - off - FRAME_HEADER < len as usize {
            break ScanEnd::Torn {
                valid_len: off as u64,
                reason: format!("implausible frame length {len}"),
            };
        }
        let payload = &bytes[off + FRAME_HEADER..off + FRAME_HEADER + len as usize];
        if crc32(payload) != crc {
            break ScanEnd::Torn { valid_len: off as u64, reason: "crc mismatch".into() };
        }
        let lsn = u64::from_le_bytes(payload[..8].try_into().unwrap());
        let text = match std::str::from_utf8(&payload[8..]) {
            Ok(t) => t,
            Err(_) => {
                break ScanEnd::Torn { valid_len: off as u64, reason: "payload not utf-8".into() }
            }
        };
        let decoded =
            parse(text).map_err(anyhow::Error::from).and_then(|j| PersistEvent::from_json(&j));
        let ev = match decoded {
            Ok(ev) => ev,
            Err(e) => {
                break ScanEnd::Torn {
                    valid_len: off as u64,
                    reason: format!("undecodable event: {e}"),
                }
            }
        };
        events.push((lsn, ev));
        off += FRAME_HEADER + len as usize;
    };
    Ok(SegmentScan { events, end, file_len })
}

pub(crate) fn segment_path(wal_dir: &Path, seq: u64) -> PathBuf {
    wal_dir.join(format!("wal-{seq:08}.log"))
}

/// Parse a `wal-<seq>.log` file name back to its sequence number.
pub(crate) fn segment_seq(name: &str) -> Option<u64> {
    name.strip_prefix("wal-")?.strip_suffix(".log")?.parse().ok()
}

/// Best-effort directory fsync (makes created/renamed files durable).
pub(crate) fn sync_dir(dir: &Path) {
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
}

// ---------------------------------------------------------------------------
// Writer side
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub(crate) struct SegmentInfo {
    pub seq: u64,
    pub first_lsn: Option<u64>,
    pub last_lsn: Option<u64>,
}

struct Queue {
    pending: Vec<(u64, PersistEvent)>,
    next_lsn: u64,
}

struct Durable {
    lsn: u64,
    io_error: Option<String>,
}

struct WriterState {
    dir: PathBuf,
    file: File,
    current: SegmentInfo,
    current_bytes: u64,
    /// Closed segments still on disk, ascending seq.
    closed: Vec<SegmentInfo>,
    segment_bytes: u64,
    fsync: FsyncMode,
}

impl WriterState {
    fn open_segment(dir: &Path, seq: u64, fsync: FsyncMode) -> Result<(File, u64)> {
        let path = segment_path(dir, seq);
        let mut file = OpenOptions::new()
            .create_new(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("creating wal segment {}", path.display()))?;
        file.write_all(SEGMENT_MAGIC)?;
        if fsync != FsyncMode::Never {
            file.sync_data()?;
            sync_dir(dir);
        }
        Ok((file, SEGMENT_MAGIC.len() as u64))
    }

    fn rotate(&mut self) -> Result<()> {
        let next_seq = self.current.seq + 1;
        let (file, bytes) = Self::open_segment(&self.dir, next_seq, self.fsync)?;
        let old = std::mem::replace(
            &mut self.current,
            SegmentInfo { seq: next_seq, first_lsn: None, last_lsn: None },
        );
        self.closed.push(old);
        self.file = file;
        self.current_bytes = bytes;
        Ok(())
    }
}

struct WalMetrics {
    appends: Arc<Counter>,
    flushes: Arc<Counter>,
    fsyncs: Arc<Counter>,
    bytes: Arc<Counter>,
    rotations: Arc<Counter>,
    lag: Arc<Gauge>,
}

struct WalInner {
    q: Mutex<Queue>,
    q_cv: Condvar,
    /// signalled after every drain; writers blocked on the MAX_PENDING
    /// bound wait here
    q_space: Condvar,
    d: Mutex<Durable>,
    d_cv: Condvar,
    writer: Mutex<WriterState>,
    stop: AtomicBool,
    /// Epoch fencing (see `persist/replicate.rs`): once a node learns a
    /// higher cluster epoch exists, its WAL refuses every further append —
    /// checked on the hot path so a fenced old primary cannot durably
    /// acknowledge writes even if a request slips past the REST gate.
    fenced: AtomicBool,
    wal_bytes_total: AtomicU64,
    /// closed + live segment files, mirrored atomically so stats/health
    /// never wait behind the writer mutex (held across write+fsync)
    segments: AtomicUsize,
    idle_wait: std::time::Duration,
    /// Event bus fed from the group-commit path: `flush_batch` publishes
    /// every batch *after* advancing the durable mark, making the
    /// subscriber-visible prefix of the log exactly the durable prefix.
    /// Covers both append paths — primary `log()` and the standby's
    /// `append_shipped` drain through the same flusher.
    bus: OnceLock<EventBus>,
    m: WalMetrics,
}

/// Handle to the write-ahead log; cheap to clone. Implements
/// [`Persister`] so it can be attached directly to a [`crate::store::Store`].
#[derive(Clone)]
pub struct Wal {
    inner: Arc<WalInner>,
}

impl Persister for Wal {
    fn log(&self, ev: PersistEvent) {
        // epoch check on append: a fenced node (superseded by a promoted
        // standby) must never extend its log — two heads both writing is
        // exactly the split brain fencing exists to prevent. Dropped
        // loudly and recorded as the sticky io_error so health and
        // sync_submit surface it.
        if self.inner.fenced.load(Ordering::Acquire) {
            log::error!("wal.log on fenced node: event dropped ({})", ev.op());
            self.inner.d.lock().unwrap().io_error.get_or_insert_with(|| {
                "node fenced: a newer primary epoch exists; writes dropped".to_string()
            });
            return;
        }
        let wake = {
            let mut q = self.inner.q.lock().unwrap();
            // bounded queue: block (durability-preserving backpressure)
            // rather than grow without limit when the disk stalls. The
            // flusher needs no store locks, so it can always drain us.
            while q.pending.len() >= MAX_PENDING && !self.inner.stop.load(Ordering::Acquire) {
                self.inner.q_cv.notify_one();
                q = self
                    .inner
                    .q_space
                    .wait_timeout(q, std::time::Duration::from_millis(100))
                    .unwrap()
                    .0;
            }
            // stop is checked UNDER the queue lock: the flusher's final
            // empty-check holds the same lock, so either it sees this
            // event (and flushes it) or we see stop here — an event can
            // never be accepted after the last drain. After stop, no
            // flusher will ever run again; drop loudly instead of
            // enqueueing into a queue nobody reads.
            if self.inner.stop.load(Ordering::Acquire) {
                drop(q);
                log::error!("wal.log after shutdown: event dropped ({})", ev.op());
                self.inner
                    .d
                    .lock()
                    .unwrap()
                    .io_error
                    .get_or_insert_with(|| {
                        "events logged after shutdown were dropped".to_string()
                    });
                return;
            }
            let lsn = q.next_lsn;
            q.next_lsn += 1;
            q.pending.push((lsn, ev));
            // signal only on the empty→nonempty transition: the flusher
            // re-checks `pending` under the queue lock before parking (and
            // parks with a timeout), so no wakeup is lost, and a burst
            // pays one futex wake instead of one per event
            q.pending.len() == 1
        };
        self.inner.m.appends.inc();
        if wake {
            self.inner.q_cv.notify_one();
        }
    }
}

impl Wal {
    /// Arm the writer: continue LSNs after `next_lsn - 1`, write into a
    /// fresh segment `next_seq`, remember already-on-disk segments in
    /// `closed` so checkpoints can prune them later. Spawns the flusher.
    pub(crate) fn create(
        wal_dir: &Path,
        segment_bytes: u64,
        fsync: FsyncMode,
        idle_wait_ms: u64,
        next_lsn: u64,
        next_seq: u64,
        closed: Vec<SegmentInfo>,
        on_disk_bytes: u64,
        metrics: &Registry,
    ) -> Result<(Wal, std::thread::JoinHandle<()>)> {
        std::fs::create_dir_all(wal_dir)
            .with_context(|| format!("creating wal dir {}", wal_dir.display()))?;
        let (file, bytes) = WriterState::open_segment(wal_dir, next_seq, fsync)?;
        let closed_count = closed.len();
        let inner = Arc::new(WalInner {
            q: Mutex::new(Queue { pending: Vec::new(), next_lsn: next_lsn.max(1) }),
            q_cv: Condvar::new(),
            q_space: Condvar::new(),
            d: Mutex::new(Durable { lsn: next_lsn.max(1) - 1, io_error: None }),
            d_cv: Condvar::new(),
            writer: Mutex::new(WriterState {
                dir: wal_dir.to_path_buf(),
                file,
                current: SegmentInfo { seq: next_seq, first_lsn: None, last_lsn: None },
                current_bytes: bytes,
                closed,
                segment_bytes,
                fsync,
            }),
            stop: AtomicBool::new(false),
            fenced: AtomicBool::new(false),
            wal_bytes_total: AtomicU64::new(on_disk_bytes + bytes),
            segments: AtomicUsize::new(closed_count + 1),
            idle_wait: std::time::Duration::from_millis(idle_wait_ms.max(1)),
            bus: OnceLock::new(),
            m: WalMetrics {
                appends: metrics.counter("persist.wal.appends"),
                flushes: metrics.counter("persist.wal.flushes"),
                fsyncs: metrics.counter("persist.wal.fsyncs"),
                bytes: metrics.counter("persist.wal.bytes_written"),
                rotations: metrics.counter("persist.wal.rotations"),
                lag: metrics.gauge("persist.wal.lag_events"),
            },
        });
        let wal = Wal { inner: Arc::clone(&inner) };
        let flusher = {
            let wal = wal.clone();
            std::thread::Builder::new()
                .name("idds-wal-flush".into())
                .spawn(move || wal.flusher_loop())
                .context("spawning wal flusher")?
        };
        Ok((wal, flusher))
    }

    fn flusher_loop(&self) {
        let inner = &*self.inner;
        loop {
            let batch = {
                let mut q = inner.q.lock().unwrap();
                while q.pending.is_empty() && !inner.stop.load(Ordering::Acquire) {
                    q = inner.q_cv.wait_timeout(q, inner.idle_wait).unwrap().0;
                }
                if q.pending.is_empty() {
                    break; // stop requested and nothing left to drain
                }
                std::mem::take(&mut q.pending)
            };
            self.inner.q_space.notify_all();
            self.flush_batch(&batch);
        }
    }

    fn flush_batch(&self, batch: &[(u64, PersistEvent)]) {
        let inner = &*self.inner;
        // root span on the flusher thread: one per group commit, so the
        // trace ring shows write+fsync cost per batch, not per event
        let mut sp = crate::obs::span("persist.wal.flush");
        sp.attr("frames", batch.len());
        let mut buf = Vec::with_capacity(batch.len() * 128);
        let mut dropped: Vec<u64> = Vec::new();
        for (lsn, ev) in batch {
            let mut text = String::new();
            ev.to_json().write_to(&mut text);
            // defense in depth: a frame the scanner would reject as
            // implausible must never be written — it would poison the
            // whole segment tail at recovery. (The store already chunks
            // its one unbounded event, AddContents.)
            if text.len() + 8 > MAX_FRAME as usize {
                log::error!(
                    "wal event {} at lsn {lsn} is {} bytes, over the {} frame limit: dropped",
                    ev.op(),
                    text.len(),
                    MAX_FRAME
                );
                let mut d = inner.d.lock().unwrap();
                d.io_error.get_or_insert_with(|| "oversized wal event dropped".to_string());
                dropped.push(*lsn);
                continue;
            }
            encode_frame(*lsn, &text, &mut buf);
        }
        let last_lsn = batch.last().map(|(lsn, _)| *lsn).unwrap_or(0);
        let first_lsn = batch.first().map(|(lsn, _)| *lsn).unwrap_or(0);
        let mut io_error = None;
        let mut wrote_ok = false;
        {
            let mut w = inner.writer.lock().unwrap();
            let res = super::failpoints::check("wal.write")
                .and_then(|_| w.file.write_all(&buf))
                .and_then(|_| {
                    if w.fsync == FsyncMode::Group {
                        inner.m.fsyncs.inc();
                        let _fsync_sp = crate::obs::span("persist.wal.fsync");
                        // the fsync failpoint fires AFTER the write: bytes
                        // are in the file (recoverable) but durability is
                        // unacknowledged — the degraded-write shape the
                        // sync_submit 503 path is tested against
                        super::failpoints::check("wal.fsync")?;
                        w.file.sync_data()
                    } else {
                        Ok(())
                    }
                });
            match res {
                Ok(()) => {
                    wrote_ok = true;
                    w.current_bytes += buf.len() as u64;
                    if w.current.first_lsn.is_none() {
                        w.current.first_lsn = Some(first_lsn);
                    }
                    w.current.last_lsn = Some(last_lsn);
                    if w.current_bytes >= w.segment_bytes {
                        match w.rotate() {
                            Ok(()) => {
                                inner.m.rotations.inc();
                                inner.segments.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(e) => io_error = Some(format!("wal rotation failed: {e}")),
                        }
                    }
                }
                Err(e) => {
                    io_error = Some(format!("wal write failed: {e}"));
                    // the segment may now end in a partial frame; anything
                    // appended after it would be unreachable at replay
                    // (scans stop at the first bad frame), so move to a
                    // fresh segment before the next batch
                    match w.rotate() {
                        Ok(()) => {
                            inner.m.rotations.inc();
                            inner.segments.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e2) => log::error!("wal rotation after write error failed: {e2}"),
                    }
                }
            }
        }
        if wrote_ok {
            inner.wal_bytes_total.fetch_add(buf.len() as u64, Ordering::Relaxed);
            inner.m.bytes.add(buf.len() as u64);
        }
        sp.attr("bytes", buf.len());
        inner.m.flushes.inc();
        {
            // advance the durable mark even on I/O error (recorded and
            // surfaced via stats/health) so sync() waiters never hang on a
            // dead disk — durability becomes best-effort at that point.
            let mut d = inner.d.lock().unwrap();
            if let Some(e) = io_error {
                log::error!("{e}");
                d.io_error.get_or_insert(e);
            }
            d.lsn = d.lsn.max(last_lsn);
            inner.d_cv.notify_all();
        }
        // publish-after-durable: the bus sees a batch only once the
        // durable mark covers it, so nothing a crash could revoke is ever
        // delivered to a subscriber. Oversized frames never reached the
        // disk, so they are not published either.
        if let Some(bus) = inner.bus.get() {
            if dropped.is_empty() {
                bus.publish(batch);
            } else {
                let kept: Vec<(u64, PersistEvent)> =
                    batch.iter().filter(|(lsn, _)| !dropped.contains(lsn)).cloned().collect();
                bus.publish(&kept);
            }
        }
        let lag = {
            let q = inner.q.lock().unwrap();
            (q.next_lsn - 1).saturating_sub(last_lsn)
        };
        inner.m.lag.set(lag as i64);
    }

    /// LSN the next logged event will get.
    pub fn next_lsn(&self) -> u64 {
        self.inner.q.lock().unwrap().next_lsn
    }

    /// Attach the event bus (one-shot; returns false if already set).
    /// From this point every flushed batch is published after its durable
    /// mark advances.
    pub fn set_bus(&self, bus: EventBus) -> bool {
        self.inner.bus.set(bus).is_ok()
    }

    /// Standby append path: enqueue a frame shipped from the primary,
    /// *preserving its LSN* — the standby's WAL is a logical copy of the
    /// primary's, so on promotion `log()` continues the same dense LSN
    /// sequence and a restarted standby recovers its position from its own
    /// files. Shipped LSNs arrive in order from the pull loop; gaps or
    /// replays are the caller's to filter.
    pub fn append_shipped(&self, lsn: u64, ev: PersistEvent) {
        // Same fence check as `Persister::log`: a fenced standby's
        // timeline has been superseded, so extending its local WAL with
        // further shipped frames would grow a log nothing should ever
        // recover from. Dropped loudly with the sticky io_error so health
        // surfaces it (the pull loop also exits on the fence).
        if self.inner.fenced.load(Ordering::Acquire) {
            log::error!("wal.append_shipped on fenced node: frame {lsn} dropped");
            self.inner.d.lock().unwrap().io_error.get_or_insert_with(|| {
                "node fenced: a newer primary epoch exists; writes dropped".to_string()
            });
            return;
        }
        let wake = {
            let mut q = self.inner.q.lock().unwrap();
            while q.pending.len() >= MAX_PENDING && !self.inner.stop.load(Ordering::Acquire) {
                self.inner.q_cv.notify_one();
                q = self
                    .inner
                    .q_space
                    .wait_timeout(q, std::time::Duration::from_millis(100))
                    .unwrap()
                    .0;
            }
            if self.inner.stop.load(Ordering::Acquire) {
                drop(q);
                log::error!("wal.append_shipped after shutdown: frame {lsn} dropped");
                return;
            }
            q.next_lsn = q.next_lsn.max(lsn + 1);
            q.pending.push((lsn, ev));
            q.pending.len() == 1
        };
        self.inner.m.appends.inc();
        if wake {
            self.inner.q_cv.notify_one();
        }
    }

    /// Jump the LSN counter forward (snapshot bootstrap: a standby seeded
    /// from a primary snapshot cut at `to` starts logging there). No-op
    /// when the counter is already past `to`.
    pub fn advance_next_lsn(&self, to: u64) {
        let mut q = self.inner.q.lock().unwrap();
        q.next_lsn = q.next_lsn.max(to);
        // the durable mark must not trail below the synthetic start or
        // wait_durable(cut-1) would block forever on a fresh standby
        let mut d = self.inner.d.lock().unwrap();
        d.lsn = d.lsn.max(to.saturating_sub(1));
    }

    /// Refuse every further append (see `persist/replicate.rs`).
    pub fn fence(&self) {
        self.inner.fenced.store(true, Ordering::Release);
    }

    pub fn is_fenced(&self) -> bool {
        self.inner.fenced.load(Ordering::Acquire)
    }

    /// Snapshot of the on-disk segment catalog (closed segments plus the
    /// live one) for the replication ship reader. The writer lock is held
    /// only to clone the metadata, never across I/O.
    pub(crate) fn catalog(&self) -> (PathBuf, Vec<SegmentInfo>) {
        let w = self.inner.writer.lock().unwrap();
        let mut segs = w.closed.clone();
        segs.push(w.current.clone());
        (w.dir.clone(), segs)
    }

    /// Last LSN known durable on disk.
    pub fn durable_lsn(&self) -> u64 {
        self.inner.d.lock().unwrap().lsn
    }

    /// First I/O error the flusher hit, if any.
    pub fn io_error(&self) -> Option<String> {
        self.inner.d.lock().unwrap().io_error.clone()
    }

    /// Total bytes ever written to the WAL directory by this process run
    /// (plus what was on disk at open).
    pub fn bytes_on_disk(&self) -> u64 {
        self.inner.wal_bytes_total.load(Ordering::Relaxed)
    }

    /// Block until everything enqueued *before this call* is durable.
    pub fn flush(&self) {
        let target = {
            let q = self.inner.q.lock().unwrap();
            q.next_lsn - 1
        };
        self.sync(target);
    }

    /// Block until `lsn` is durable (no-op if it already is). If the WAL
    /// was stopped before `lsn` became durable, returns without waiting
    /// but says so loudly — the data is NOT durable at that point.
    pub fn sync(&self, lsn: u64) {
        self.inner.q_cv.notify_one();
        let mut d = self.inner.d.lock().unwrap();
        while d.lsn < lsn && !self.inner.stop.load(Ordering::Acquire) {
            let (guard, _timeout) = self
                .inner
                .d_cv
                .wait_timeout(d, std::time::Duration::from_millis(50))
                .unwrap();
            d = guard;
            self.inner.q_cv.notify_one();
        }
        if d.lsn < lsn {
            log::warn!(
                "wal.sync({lsn}) returned after shutdown with durable_lsn {} — not durable",
                d.lsn
            );
        }
    }

    /// Block until `lsn` is durable and report honestly: `true` only when
    /// the durable mark passed `lsn` *and* no write error has been
    /// recorded (the mark advances past failed flushes by design so
    /// waiters never hang — see [`Wal::sync`]). The synchronous-submit
    /// REST path (`persist.sync_submit`) gates its `201` on this, still
    /// riding group commit: every waiter of one flush batch shares its
    /// single fsync.
    pub fn wait_durable(&self, lsn: u64) -> bool {
        self.sync(lsn);
        let d = self.inner.d.lock().unwrap();
        d.lsn >= lsn && d.io_error.is_none()
    }

    /// Rotate the live segment (if it has frames) and delete closed
    /// segments that only contain LSNs below `start_lsn` — called after a
    /// successful checkpoint. Returns how many segment files were removed.
    pub(crate) fn prune_below(&self, start_lsn: u64) -> usize {
        let mut w = self.inner.writer.lock().unwrap();
        if w.current.first_lsn.is_some() {
            match w.rotate() {
                Ok(()) => {
                    self.inner.m.rotations.inc();
                    self.inner.segments.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => {
                    log::error!("wal rotation during prune failed: {e}");
                    return 0;
                }
            }
        }
        let dir = w.dir.clone();
        let mut deleted = 0;
        w.closed.retain(|seg| {
            let disposable = match seg.last_lsn {
                Some(last) => last < start_lsn,
                None => true, // never held a frame
            };
            if disposable {
                let path = segment_path(&dir, seg.seq);
                match std::fs::remove_file(&path) {
                    Ok(()) => deleted += 1,
                    Err(e) => log::warn!("could not remove {}: {e}", path.display()),
                }
            }
            !disposable
        });
        if deleted > 0 {
            sync_dir(&dir);
            self.inner.segments.fetch_sub(deleted, Ordering::Relaxed);
        }
        deleted
    }

    /// Segment count currently tracked (closed + the live one). Lock-free:
    /// health probes must not wait behind the writer's write+fsync.
    pub fn segment_count(&self) -> usize {
        self.inner.segments.load(Ordering::Relaxed)
    }

    pub(crate) fn stop(&self) {
        self.inner.stop.store(true, Ordering::Release);
        self.inner.q_cv.notify_all();
        self.inner.q_space.notify_all();
        self.inner.d_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::RequestKind;
    use crate::util::json::Json;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "idds-wal-{tag}-{}-{}",
            std::process::id(),
            crate::util::next_id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn ev(i: u64) -> PersistEvent {
        PersistEvent::AddRequest {
            id: i,
            name: format!("r{i}"),
            requester: "u".into(),
            kind: RequestKind::Workflow,
            workflow: Json::Null,
            at: i as f64,
        }
    }

    #[test]
    fn crc32_known_vector() {
        // the canonical IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_roundtrip_via_scan() {
        let dir = tmp_dir("frame");
        let path = segment_path(&dir, 1);
        let mut bytes: Vec<u8> = SEGMENT_MAGIC.to_vec();
        for lsn in 1..=5u64 {
            let text = ev(lsn).to_json().to_string();
            encode_frame(lsn, &text, &mut bytes);
        }
        std::fs::write(&path, &bytes).unwrap();
        let scan = scan_segment(&path).unwrap();
        assert_eq!(scan.end, ScanEnd::Clean);
        assert_eq!(scan.events.len(), 5);
        assert_eq!(scan.events[0].0, 1);
        assert_eq!(scan.events[4].0, 5);
        assert_eq!(scan.events[2].1, ev(3));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_detected_and_prefix_kept() {
        let dir = tmp_dir("torn");
        let path = segment_path(&dir, 1);
        let mut bytes: Vec<u8> = SEGMENT_MAGIC.to_vec();
        for lsn in 1..=3u64 {
            encode_frame(lsn, &ev(lsn).to_json().to_string(), &mut bytes);
        }
        let valid = bytes.len() as u64;
        // torn tail: half a frame
        let mut tail = Vec::new();
        encode_frame(4, &ev(4).to_json().to_string(), &mut tail);
        bytes.extend_from_slice(&tail[..tail.len() / 2]);
        std::fs::write(&path, &bytes).unwrap();
        let scan = scan_segment(&path).unwrap();
        assert_eq!(scan.events.len(), 3);
        match scan.end {
            ScanEnd::Torn { valid_len, .. } => assert_eq!(valid_len, valid),
            ScanEnd::Clean => panic!("torn tail not detected"),
        }
        // corrupted byte inside a frame body → crc catches it
        let mut flipped = bytes[..valid as usize].to_vec();
        let n = flipped.len();
        flipped[n - 3] ^= 0xFF;
        std::fs::write(&path, &flipped).unwrap();
        let scan = scan_segment(&path).unwrap();
        assert_eq!(scan.events.len(), 2, "frame with flipped byte must be dropped");
        assert!(matches!(scan.end, ScanEnd::Torn { .. }));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn group_commit_persists_all_events_in_lsn_order() {
        let dir = tmp_dir("group");
        let metrics = Registry::default();
        let (wal, flusher) =
            Wal::create(&dir, 1 << 30, FsyncMode::Never, 5, 1, 1, Vec::new(), 0, &metrics).unwrap();
        std::thread::scope(|scope| {
            for t in 0..4 {
                let wal = wal.clone();
                scope.spawn(move || {
                    for i in 0..250u64 {
                        wal.log(ev(t * 1000 + i));
                    }
                });
            }
        });
        wal.flush();
        assert_eq!(wal.durable_lsn(), 1000);
        wal.stop();
        flusher.join().unwrap();
        let scan = scan_segment(&segment_path(&dir, 1)).unwrap();
        assert_eq!(scan.end, ScanEnd::Clean);
        assert_eq!(scan.events.len(), 1000);
        for (i, (lsn, _)) in scan.events.iter().enumerate() {
            assert_eq!(*lsn, i as u64 + 1, "lsns must be dense and ascending");
        }
        assert_eq!(metrics.counter("persist.wal.appends").get(), 1000);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flush_batch_coalesces_whole_burst_into_one_write() {
        // deterministic coalescing check: stop the flusher thread and
        // drive flush_batch directly with a 100-event burst — it must do
        // exactly one flush (and would do one fsync in Group mode)
        let dir = tmp_dir("coalesce");
        let metrics = Registry::default();
        let (wal, flusher) =
            Wal::create(&dir, 1 << 30, FsyncMode::Never, 5, 1, 1, Vec::new(), 0, &metrics).unwrap();
        wal.stop();
        flusher.join().unwrap();
        let batch: Vec<(u64, PersistEvent)> = (1..=100).map(|lsn| (lsn, ev(lsn))).collect();
        wal.flush_batch(&batch);
        assert_eq!(
            metrics.counter("persist.wal.flushes").get(),
            1,
            "one burst must be one flush"
        );
        assert_eq!(wal.durable_lsn(), 100);
        let scan = scan_segment(&segment_path(&dir, 1)).unwrap();
        assert_eq!(scan.end, ScanEnd::Clean);
        assert_eq!(scan.events.len(), 100);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wait_durable_reports_fsynced_lsns() {
        let dir = tmp_dir("waitdur");
        let metrics = Registry::default();
        let (wal, flusher) =
            Wal::create(&dir, 1 << 30, FsyncMode::Never, 5, 1, 1, Vec::new(), 0, &metrics).unwrap();
        for i in 0..10u64 {
            wal.log(ev(i));
        }
        let target = wal.next_lsn() - 1;
        assert!(wal.wait_durable(target), "a flushed lsn must report durable");
        assert!(wal.durable_lsn() >= target);
        // a stopped WAL cannot promise future durability
        wal.stop();
        flusher.join().unwrap();
        assert!(!wal.wait_durable(target + 100));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fenced_wal_drops_both_append_paths() {
        let dir = tmp_dir("fenced");
        let metrics = Registry::default();
        let (wal, flusher) =
            Wal::create(&dir, 1 << 30, FsyncMode::Never, 5, 1, 1, Vec::new(), 0, &metrics).unwrap();
        wal.log(ev(1));
        wal.flush();
        let durable = wal.durable_lsn();
        wal.fence();
        wal.log(ev(2)); // primary append path: dropped
        wal.append_shipped(durable + 1, ev(3)); // standby ship path: dropped too
        wal.flush();
        assert_eq!(wal.durable_lsn(), durable, "no frame may land after the fence");
        assert!(wal.io_error().is_some(), "the drop surfaces as the sticky io_error");
        wal.stop();
        flusher.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flush_publishes_to_the_bus_in_lsn_order() {
        let dir = tmp_dir("bus");
        let metrics = Registry::default();
        let (wal, flusher) =
            Wal::create(&dir, 1 << 30, FsyncMode::Never, 5, 1, 1, Vec::new(), 0, &metrics).unwrap();
        let bus = crate::persist::bus::EventBus::new(&metrics);
        let sub = bus.subscribe(crate::persist::bus::T_ALL, None, 1024);
        assert!(wal.set_bus(bus));
        for i in 1..=20u64 {
            wal.log(ev(i));
        }
        wal.flush();
        // flush() returns once the durable mark covers the batch; the
        // publish runs right after in the same flusher call, so a short
        // wait is enough
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let mut lsns: Vec<u64> = Vec::new();
        while lsns.len() < 20 && std::time::Instant::now() < deadline {
            sub.wait(std::time::Duration::from_millis(50));
            let (evs, _) = sub.drain(100);
            lsns.extend(evs.iter().map(|e| e.lsn));
        }
        assert_eq!(lsns, (1..=20u64).collect::<Vec<_>>());
        wal.stop();
        flusher.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segments_rotate_at_size_and_prune_below() {
        let dir = tmp_dir("rotate");
        let metrics = Registry::default();
        let (wal, flusher) =
            Wal::create(&dir, 2048, FsyncMode::Never, 5, 1, 1, Vec::new(), 0, &metrics).unwrap();
        for i in 0..200u64 {
            wal.log(ev(i));
            if i % 10 == 0 {
                wal.flush(); // force many small flush batches → rotations
            }
        }
        wal.flush();
        assert!(wal.segment_count() > 1, "expected rotation at 2 KiB segments");
        let files_before = std::fs::read_dir(&dir).unwrap().count();
        let deleted = wal.prune_below(wal.next_lsn());
        assert!(deleted > 0, "fully-covered segments must be deleted");
        let files_after = std::fs::read_dir(&dir).unwrap().count();
        assert!(files_after < files_before);
        wal.stop();
        flusher.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
