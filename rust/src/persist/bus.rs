//! In-process event bus fed from the WAL group-commit path.
//!
//! Every durable mutation already flows through [`PersistEvent`] with a
//! monotone LSN; the group-commit flusher publishes each batch *after*
//! advancing the durable mark (see `Wal::flush_batch`), so subscribers
//! never see an event a crash could revoke. Two kinds of consumers hang
//! off the bus:
//!
//! * **watchers** ([`EventBus::watch`]): latched condvar wake signals
//!   keyed by a table-interest bitmask — the daemons' event-driven
//!   replacement for interval polling. A watcher carries no payload; the
//!   woken daemon's own generation gates decide what the wakeup means.
//! * **subscribers** ([`EventBus::subscribe`]): bounded per-subscriber
//!   queues of serialized events — the feed behind `GET /api/events`
//!   (SSE) and `Client::watch_events`. A slow subscriber overflows its
//!   *own* queue and is marked for a terminal `overflow` drop; it never
//!   blocks the publisher or its peers.
//!
//! The catch-up→live-tail seam contract (no gap, no duplicate) is:
//! subscribe **first**, then read the WAL durable mark `T`, then replay
//! history up to `T`, then [`Subscriber::set_floor`]`(T)`. The floor
//! drops any queued event with `lsn <= T` (the overlap a publish racing
//! the subscribe can enqueue), while publish-after-durable guarantees
//! every event with `lsn > T` was published after the durable mark — and
//! therefore after the subscribe — so it is in the queue. Same
//! continuity rule as the replication `apply_batch` cursor; see
//! DESIGN.md "Event bus".

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::{Counter, Gauge, Registry};

use super::events::{PersistEvent, Persister};

/// Table-interest bits (one per [`PersistEvent::table`] value).
pub const T_REQUESTS: u32 = 1 << 0;
pub const T_TRANSFORMS: u32 = 1 << 1;
pub const T_PROCESSINGS: u32 = 1 << 2;
pub const T_COLLECTIONS: u32 = 1 << 3;
pub const T_CONTENTS: u32 = 1 << 4;
pub const T_MESSAGES: u32 = 1 << 5;
pub const T_BROKER: u32 = 1 << 6;
pub const T_ALL: u32 = (1 << 7) - 1;

/// Map a table name (the `filter=` axis of `GET /api/events`) to its
/// interest bit.
pub fn table_mask(table: &str) -> Option<u32> {
    Some(match table {
        "requests" => T_REQUESTS,
        "transforms" => T_TRANSFORMS,
        "processings" => T_PROCESSINGS,
        "collections" => T_COLLECTIONS,
        "contents" => T_CONTENTS,
        "messages" => T_MESSAGES,
        "broker" => T_BROKER,
        _ => return None,
    })
}

/// True if `op` is one of the [`PersistEvent::op`] tags — lets the REST
/// layer 400 an unknown `filter=` instead of serving an empty stream.
pub fn known_op(op: &str) -> bool {
    matches!(
        op,
        "add_request"
            | "request_status"
            | "request_engine"
            | "request_engine_delta"
            | "add_transform"
            | "transform_status"
            | "transform_work"
            | "transform_retries"
            | "add_processing"
            | "processing_status"
            | "processing_wfm_task"
            | "add_collection"
            | "close_collection"
            | "add_contents"
            | "content_status"
            | "content_ddm_file"
            | "add_message"
            | "message_status"
            | "broker_subscribe"
            | "broker_unsubscribe"
            | "broker_publish"
            | "broker_deliver"
            | "broker_ack"
    )
}

fn mask_of(ev: &PersistEvent) -> u32 {
    table_mask(ev.table()).unwrap_or(T_ALL)
}

// ---------------------------------------------------------------------------
// Wake signals (daemon wakeups, replication fast path)
// ---------------------------------------------------------------------------

/// A latched wakeup: [`WakeSignal::notify`] bumps an epoch and wakes
/// waiters; [`WakeSignal::wait_past`] returns immediately when the epoch
/// already moved past the caller's snapshot. Snapshot the epoch *before*
/// scanning for work and a notification that lands during the scan is
/// never lost — the next wait returns at once.
pub struct WakeSignal {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl WakeSignal {
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> Arc<WakeSignal> {
        Arc::new(WakeSignal { epoch: Mutex::new(0), cv: Condvar::new() })
    }

    /// Current epoch — snapshot this before polling for work.
    pub fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap()
    }

    pub fn notify(&self) {
        let mut e = self.epoch.lock().unwrap();
        *e += 1;
        self.cv.notify_all();
    }

    /// Block until the epoch passes `seen` or `timeout` elapses. Returns
    /// `(current_epoch, true)` on a signal, `(_, false)` on timeout.
    pub fn wait_past(&self, seen: u64, timeout: Duration) -> (u64, bool) {
        let deadline = Instant::now() + timeout;
        let mut e = self.epoch.lock().unwrap();
        while *e <= seen {
            let now = Instant::now();
            if now >= deadline {
                return (*e, false);
            }
            e = self.cv.wait_timeout(e, deadline - now).unwrap().0;
        }
        (*e, true)
    }
}

// ---------------------------------------------------------------------------
// Queued subscribers (SSE / watch feeds)
// ---------------------------------------------------------------------------

/// One published event, serialized once on the publisher and shared by
/// every subscriber queue it lands in.
#[derive(Clone)]
pub struct BusEvent {
    pub lsn: u64,
    pub op: &'static str,
    pub table: &'static str,
    pub json: Arc<str>,
}

struct SubQueue {
    items: VecDeque<BusEvent>,
    /// Events with `lsn <= floor` are duplicates of the catch-up replay
    /// and are dropped at enqueue (and purged by [`Subscriber::set_floor`]).
    floor: u64,
    /// Last LSN actually enqueued — the resume point reported on overflow.
    last_lsn: u64,
    /// The queue bound was hit: no further enqueues; once the backlog is
    /// drained the consumer sees the terminal overflow marker.
    overflowed: bool,
    /// Empty→nonempty (or overflow) callback — e.g. the epoll loop waker.
    /// Called under the queue lock; must not call back into the bus.
    notify: Option<Box<dyn Fn() + Send>>,
}

struct SubscriberInner {
    id: u64,
    mask: u32,
    op_filter: Option<String>,
    cap: usize,
    q: Mutex<SubQueue>,
    cv: Condvar,
}

impl SubscriberInner {
    /// Enqueue if the queue accepts it; returns `true` exactly when this
    /// call transitioned the queue into the overflowed state.
    fn offer(&self, ev: &BusEvent) -> bool {
        let mut q = self.q.lock().unwrap();
        if q.overflowed || ev.lsn <= q.floor {
            return false;
        }
        if q.items.len() >= self.cap {
            q.overflowed = true;
            // wake the consumer so it drains and sees the terminal marker
            if let Some(f) = &q.notify {
                f();
            }
            self.cv.notify_all();
            return true;
        }
        let was_empty = q.items.is_empty();
        q.last_lsn = ev.lsn;
        q.items.push_back(ev.clone());
        if was_empty {
            if let Some(f) = &q.notify {
                f();
            }
            self.cv.notify_all();
        }
        false
    }
}

/// Live-tail handle returned by [`EventBus::subscribe`]; unsubscribes on
/// drop (an SSE connection closing tears its queue down with it).
pub struct Subscriber {
    bus: EventBus,
    inner: Arc<SubscriberInner>,
}

impl Subscriber {
    /// Seam dedup: drop everything the catch-up replay already delivered
    /// (`lsn <= floor`) — both what is queued now and what a publish
    /// racing the subscribe enqueues later. The floor only rises.
    pub fn set_floor(&self, floor: u64) {
        let mut q = self.inner.q.lock().unwrap();
        q.floor = q.floor.max(floor);
        // queued LSNs ascend, so popping the front while it is below the
        // floor purges exactly the overlap
        while q.items.front().is_some_and(|e| e.lsn <= floor) {
            q.items.pop_front();
        }
    }

    /// Install the readiness callback, fired on empty→nonempty and on
    /// overflow. Fires immediately when something is already pending so a
    /// late installation cannot strand queued events.
    pub fn set_notifier(&self, f: impl Fn() + Send + 'static) {
        let q = self.inner.q.lock().unwrap();
        let pending = !q.items.is_empty() || q.overflowed;
        drop(q);
        if pending {
            f();
        }
        self.inner.q.lock().unwrap().notify = Some(Box::new(f));
    }

    /// Drain up to `max` queued events. The second value is the terminal
    /// overflow marker: `Some(last_enqueued_lsn)` once the queue bound
    /// was hit *and* the remaining backlog has been handed out — the LSN
    /// a resuming client passes back as `from_lsn` (+1).
    pub fn drain(&self, max: usize) -> (Vec<BusEvent>, Option<u64>) {
        let mut q = self.inner.q.lock().unwrap();
        let take = q.items.len().min(max);
        let out: Vec<BusEvent> = q.items.drain(..take).collect();
        let overflow = if q.overflowed && q.items.is_empty() { Some(q.last_lsn) } else { None };
        (out, overflow)
    }

    /// Block until events (or the overflow marker) are pending.
    pub fn wait(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut q = self.inner.q.lock().unwrap();
        while q.items.is_empty() && !q.overflowed {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            q = self.inner.cv.wait_timeout(q, deadline - now).unwrap().0;
        }
        true
    }

    pub fn overflowed(&self) -> bool {
        self.inner.q.lock().unwrap().overflowed
    }
}

impl Drop for Subscriber {
    fn drop(&mut self) {
        self.bus.unsubscribe(self.inner.id);
    }
}

// ---------------------------------------------------------------------------
// The bus
// ---------------------------------------------------------------------------

struct BusInner {
    metrics: Registry,
    subs: Mutex<Vec<Arc<SubscriberInner>>>,
    watchers: Mutex<Vec<(u32, Arc<WakeSignal>)>>,
    next_sub: AtomicU64,
    last_lsn: AtomicU64,
    published: Arc<Counter>,
    overflows: Arc<Counter>,
    subscribers: Arc<Gauge>,
}

/// Cheap-to-clone handle; one per process, wired to the WAL (durable
/// mode) or a [`BusPersister`] (no data dir) plus the daemon host, the
/// REST state, and — in-process — a standby's pull loop.
#[derive(Clone)]
pub struct EventBus {
    inner: Arc<BusInner>,
}

impl EventBus {
    pub fn new(metrics: &Registry) -> EventBus {
        EventBus {
            inner: Arc::new(BusInner {
                metrics: metrics.clone(),
                subs: Mutex::new(Vec::new()),
                watchers: Mutex::new(Vec::new()),
                next_sub: AtomicU64::new(1),
                last_lsn: AtomicU64::new(0),
                published: metrics.counter("events.published"),
                overflows: metrics.counter("events.overflows"),
                subscribers: metrics.gauge("events.subscribers"),
            }),
        }
    }

    /// The registry this bus reports into — daemon hosts hang their
    /// `pipeline.<name>.wakeups` counters here so wiring stays one handle.
    pub fn metrics(&self) -> &Registry {
        &self.inner.metrics
    }

    /// Highest LSN ever published — the live horizon when serving without
    /// a WAL to read history from.
    pub fn last_lsn(&self) -> u64 {
        self.inner.last_lsn.load(Ordering::Acquire)
    }

    /// Register a wake signal for the tables in `mask`.
    pub fn watch(&self, mask: u32) -> Arc<WakeSignal> {
        let s = WakeSignal::new();
        self.inner.watchers.lock().unwrap().push((mask, Arc::clone(&s)));
        s
    }

    /// Synthetic wakeup for non-WAL daemon inputs folded into the same
    /// interest space (the Marshaller's marshal-epoch bump, which the
    /// Clerk's finalization gate observes).
    pub fn signal(&self, mask: u32) {
        for (m, s) in self.inner.watchers.lock().unwrap().iter() {
            if m & mask != 0 {
                s.notify();
            }
        }
    }

    /// Add a bounded queue fed with events matching `mask` (and, when
    /// set, the exact `op_filter` tag).
    pub fn subscribe(&self, mask: u32, op_filter: Option<&str>, cap: usize) -> Subscriber {
        let inner = Arc::new(SubscriberInner {
            id: self.inner.next_sub.fetch_add(1, Ordering::Relaxed),
            mask,
            op_filter: op_filter.map(|s| s.to_string()),
            cap: cap.max(1),
            q: Mutex::new(SubQueue {
                items: VecDeque::new(),
                floor: 0,
                last_lsn: 0,
                overflowed: false,
                notify: None,
            }),
            cv: Condvar::new(),
        });
        self.inner.subs.lock().unwrap().push(Arc::clone(&inner));
        self.inner.subscribers.add(1);
        Subscriber { bus: self.clone(), inner }
    }

    fn unsubscribe(&self, id: u64) {
        let mut subs = self.inner.subs.lock().unwrap();
        let before = subs.len();
        subs.retain(|s| s.id != id);
        if subs.len() < before {
            self.inner.subscribers.add(-1);
        }
    }

    /// Publish one durable batch (ascending LSNs). Called by the WAL
    /// flusher *after* the durable mark advanced, and by [`BusPersister`]
    /// at apply time when serving without a data dir. Never blocks on a
    /// slow subscriber: a full queue flips to overflowed and the batch
    /// moves on.
    pub fn publish(&self, batch: &[(u64, PersistEvent)]) {
        if batch.is_empty() {
            return;
        }
        let mut union = 0u32;
        for (_, ev) in batch {
            union |= mask_of(ev);
        }
        let subs: Vec<Arc<SubscriberInner>> = {
            let subs = self.inner.subs.lock().unwrap();
            subs.iter().filter(|s| s.mask & union != 0).cloned().collect()
        };
        if !subs.is_empty() {
            for (lsn, ev) in batch {
                let mask = mask_of(ev);
                if !subs.iter().any(|s| s.mask & mask != 0) {
                    continue;
                }
                // serialize once per event, not per subscriber
                let mut text = String::new();
                ev.to_json().write_to(&mut text);
                let be =
                    BusEvent { lsn: *lsn, op: ev.op(), table: ev.table(), json: text.into() };
                for s in &subs {
                    if s.mask & mask == 0 {
                        continue;
                    }
                    if s.op_filter.as_deref().is_some_and(|f| f != be.op) {
                        continue;
                    }
                    if s.offer(&be) {
                        self.inner.overflows.inc();
                    }
                }
            }
        }
        self.inner.published.add(batch.len() as u64);
        if let Some((last, _)) = batch.last() {
            self.inner.last_lsn.fetch_max(*last, Ordering::AcqRel);
        }
        // watchers last: a woken daemon observes both the store mutation
        // and anything queued above
        self.signal(union);
    }

    /// Subscriber queues currently attached (tests / health).
    pub fn subscriber_count(&self) -> usize {
        self.inner.subs.lock().unwrap().len()
    }
}

/// [`Persister`] that publishes straight to the bus — the serve path
/// without `--data-dir`, where there is no WAL flush to hook: events
/// become visible at apply time instead of at group commit, minted from
/// a process-local LSN sequence. Bus locks are leaf locks (the publish
/// path runs under store row/index locks), matching the `Persister`
/// contract.
pub struct BusPersister {
    bus: EventBus,
    next_lsn: AtomicU64,
}

impl BusPersister {
    pub fn new(bus: EventBus) -> BusPersister {
        BusPersister { bus, next_lsn: AtomicU64::new(1) }
    }
}

impl Persister for BusPersister {
    fn log(&self, ev: PersistEvent) {
        let lsn = self.next_lsn.fetch_add(1, Ordering::Relaxed);
        self.bus.publish(&[(lsn, ev)]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{MessageStatus, RequestKind, RequestStatus};
    use crate::util::json::Json;

    fn req_ev(i: u64) -> PersistEvent {
        PersistEvent::AddRequest {
            id: i,
            name: format!("r{i}"),
            requester: "u".into(),
            kind: RequestKind::Workflow,
            workflow: Json::Null,
            at: i as f64,
        }
    }

    fn msg_ev(i: u64) -> PersistEvent {
        PersistEvent::MessageStatus { ids: vec![i], to: MessageStatus::Delivered }
    }

    #[test]
    fn every_table_has_a_mask() {
        for ev in [
            req_ev(1),
            PersistEvent::RequestStatus { ids: vec![1], to: RequestStatus::Finished, at: 0.0 },
            PersistEvent::AddTransform {
                id: 2,
                request_id: 1,
                name: "t".into(),
                work: Json::Null,
                at: 0.0,
            },
            PersistEvent::AddProcessing { id: 3, transform_id: 2, at: 0.0 },
            PersistEvent::CloseCollection { id: 4 },
            PersistEvent::AddContents { collection_id: 4, items: vec![], at: 0.0 },
            msg_ev(5),
            PersistEvent::BrokerAck { sub: 6, ids: vec![] },
        ] {
            assert!(
                table_mask(ev.table()).is_some(),
                "table '{}' of op '{}' must map to a mask",
                ev.table(),
                ev.op()
            );
        }
    }

    #[test]
    fn floor_drops_catchup_overlap() {
        let bus = EventBus::new(&Registry::default());
        let sub = bus.subscribe(T_ALL, None, 64);
        bus.publish(&(1..=5u64).map(|i| (i, req_ev(i))).collect::<Vec<_>>());
        sub.set_floor(3);
        let (evs, overflow) = sub.drain(10);
        assert_eq!(evs.iter().map(|e| e.lsn).collect::<Vec<_>>(), vec![4, 5]);
        assert!(overflow.is_none());
        // late enqueues below the floor are dropped too
        bus.publish(&[(2, req_ev(2)), (6, req_ev(6))]);
        let (evs, _) = sub.drain(10);
        assert_eq!(evs.iter().map(|e| e.lsn).collect::<Vec<_>>(), vec![6]);
    }

    #[test]
    fn overflow_is_terminal_and_reports_last_enqueued_lsn() {
        let bus = EventBus::new(&Registry::default());
        let sub = bus.subscribe(T_ALL, None, 2);
        bus.publish(&(1..=5u64).map(|i| (i, req_ev(i))).collect::<Vec<_>>());
        assert!(sub.overflowed());
        let (evs, overflow) = sub.drain(1);
        assert_eq!(evs.len(), 1);
        assert!(overflow.is_none(), "marker only after the backlog drains");
        let (evs, overflow) = sub.drain(10);
        assert_eq!(evs.len(), 1);
        assert_eq!(overflow, Some(2), "resume point is the last enqueued lsn");
        // once overflowed, nothing is ever enqueued again
        bus.publish(&[(9, req_ev(9))]);
        let (evs, overflow) = sub.drain(10);
        assert!(evs.is_empty());
        assert_eq!(overflow, Some(2));
        assert_eq!(bus.metrics().counter("events.overflows").get(), 1);
    }

    #[test]
    fn slow_subscriber_does_not_block_publisher_or_peers() {
        let bus = EventBus::new(&Registry::default());
        let slow = bus.subscribe(T_ALL, None, 1);
        let fast = bus.subscribe(T_ALL, None, 1024);
        bus.publish(&(1..=100u64).map(|i| (i, req_ev(i))).collect::<Vec<_>>());
        assert!(slow.overflowed());
        let (evs, overflow) = fast.drain(1000);
        assert_eq!(evs.len(), 100, "fast subscriber sees every event");
        assert!(overflow.is_none());
    }

    #[test]
    fn masks_and_op_filters_select_events() {
        let bus = EventBus::new(&Registry::default());
        let reqs = bus.subscribe(T_REQUESTS, None, 64);
        let acks = bus.subscribe(T_ALL, Some("message_status"), 64);
        bus.publish(&[(1, req_ev(1)), (2, msg_ev(2)), (3, req_ev(3))]);
        let (evs, _) = reqs.drain(10);
        assert_eq!(evs.iter().map(|e| e.op).collect::<Vec<_>>(), vec!["add_request"; 2]);
        let (evs, _) = acks.drain(10);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].op, "message_status");
    }

    #[test]
    fn watchers_wake_only_on_matching_tables() {
        let bus = EventBus::new(&Registry::default());
        let sig = bus.watch(T_REQUESTS);
        let seen = sig.epoch();
        bus.publish(&[(1, msg_ev(1))]);
        let (_, woke) = sig.wait_past(seen, Duration::from_millis(10));
        assert!(!woke, "a messages event must not wake a requests watcher");
        bus.publish(&[(2, req_ev(2))]);
        let (_, woke) = sig.wait_past(seen, Duration::from_secs(5));
        assert!(woke);
        // synthetic signals fold into the same space
        let seen = sig.epoch();
        bus.signal(T_REQUESTS);
        assert!(sig.wait_past(seen, Duration::from_secs(5)).1);
    }

    #[test]
    fn dropped_subscriber_detaches_from_the_bus() {
        let bus = EventBus::new(&Registry::default());
        let sub = bus.subscribe(T_ALL, None, 4);
        assert_eq!(bus.subscriber_count(), 1);
        drop(sub);
        assert_eq!(bus.subscriber_count(), 0);
    }

    #[test]
    fn bus_persister_mints_dense_lsns() {
        let bus = EventBus::new(&Registry::default());
        let sub = bus.subscribe(T_ALL, None, 64);
        let p = BusPersister::new(bus.clone());
        for i in 0..5u64 {
            p.log(req_ev(i));
        }
        let (evs, _) = sub.drain(10);
        assert_eq!(evs.iter().map(|e| e.lsn).collect::<Vec<_>>(), vec![1, 2, 3, 4, 5]);
        assert_eq!(bus.last_lsn(), 5);
    }
}
