//! Durable state for the head service: write-ahead log + checkpoints +
//! crash recovery (production iDDS keeps this state in Oracle/PostgreSQL;
//! here an append-only WAL over [`crate::store::Store`] plays that role —
//! see DESIGN.md, "Durability model").
//!
//! Layout under the data dir:
//!
//! ```text
//! <data_dir>/
//!   checkpoint-00000001.json        base: Store::snapshot() + the cut LSN
//!   checkpoint-00000003.delta.json  delta: dirty rows since the previous
//!                                   chain element + broker delta + chain
//!                                   linkage (base_seq / prev_seq)
//!   wal/wal-00000001.log            length+CRC-framed event segments
//! ```
//!
//! * **Write path** — the store *and the broker* log one [`PersistEvent`]
//!   per applied mutation through the [`Persister`] hook; the WAL
//!   group-commits them (one write+fsync per flusher batch, mirroring the
//!   store's batched transition philosophy).
//! * **Checkpoint** — flush the WAL, note the next LSN (`start_lsn`),
//!   drain the store's and broker's dirty sets, then write either a
//!   **base** (full `Store::snapshot()` + broker section) or a **delta**
//!   (`checkpoint-<seq>.delta.json`: the dirty rows' current state +
//!   touched broker topics + removals), per the compaction policy
//!   (`persist.delta_chain_max`, `persist.delta_dirty_ratio`). Bases
//!   apply retention and prune WAL segments below the *oldest retained
//!   base's* cut; deltas never move the prune horizon — checkpoint I/O
//!   scales with churn, not table size.
//! * **Recovery** — load the newest readable base, fold its delta chain
//!   in order (full-row upserts; a chain broken by a corrupt or missing
//!   link is discarded wholesale and the base + WAL suffix covers it),
//!   then replay the WAL suffix (`lsn >=` the last folded cut) through
//!   [`crate::store::Store::apply_event`] (broker events route to
//!   [`crate::broker::Broker::apply_event`]), truncate any torn tail at
//!   the first bad frame, and advance the process-wide id counter past
//!   everything seen.
//!
//! The soundness argument for the fuzzy checkpoint cut (log-after-apply
//! under the discovery lock ⇒ `lsn < start_lsn` implies the effect is in
//! the snapshot; mark-dirty-before-log ⇒ it is in the drained dirty set
//! too; replay is insert-if-absent + last-write-wins so the overlapping
//! suffix converges) lives in DESIGN.md, "Durability model" and "Delta
//! checkpoints".

pub mod bus;
pub mod events;
pub mod failpoints;
pub mod replicate;
pub mod wal;

use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::broker::{Broker, DecodedBroker};
use crate::config::Config;
use crate::metrics::Registry;
use crate::store::snapshot::DecodedSnapshot;
use crate::store::{DirtySets, Id, Store};
use crate::util::json::{parse, Json};

pub use bus::{BusPersister, EventBus, Subscriber, WakeSignal};
pub use events::{PersistEvent, Persister};
pub use replicate::{ClusterState, Replica, ReplicationOptions};
pub use wal::Wal;

use wal::{scan_segment, segment_path, segment_seq, sync_dir, ScanEnd, SegmentInfo};

/// When the flusher calls `fsync`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncMode {
    /// One `fsync` per group-commit batch (the durable default).
    Group,
    /// Never fsync — page cache only (fast, survives process crashes but
    /// not power loss; useful for tests and benches).
    Never,
}

impl FsyncMode {
    pub fn parse(s: &str) -> Option<FsyncMode> {
        match s {
            "group" => Some(FsyncMode::Group),
            "never" => Some(FsyncMode::Never),
            _ => None,
        }
    }
}

/// Tunables, resolved from the `persist.*` config keys.
#[derive(Debug, Clone)]
pub struct PersistOptions {
    pub segment_bytes: u64,
    pub fsync: FsyncMode,
    pub checkpoint_keep: usize,
    pub flush_idle_ms: u64,
    /// Auto-compaction: a delta chain longer than this forces the next
    /// checkpoint to be a base.
    pub delta_chain_max: usize,
    /// Auto-compaction: a dirty-row ratio (dirty / total rows) at or above
    /// this forces a base — a delta nearly the size of a base buys
    /// nothing and lengthens recovery.
    pub delta_dirty_ratio: f64,
    /// Fault-injection spec armed at open (`persist.failpoints`, e.g.
    /// `wal.fsync=always,checkpoint.rename=2`); empty = none. See
    /// [`failpoints`].
    pub failpoints: String,
}

impl Default for PersistOptions {
    fn default() -> Self {
        PersistOptions {
            segment_bytes: 8 * 1024 * 1024,
            fsync: FsyncMode::Group,
            checkpoint_keep: 2,
            flush_idle_ms: 50,
            delta_chain_max: 8,
            delta_dirty_ratio: 0.5,
            failpoints: String::new(),
        }
    }
}

impl PersistOptions {
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let fsync_str = cfg.str("persist.fsync")?;
        Ok(PersistOptions {
            segment_bytes: cfg.u64("persist.segment_bytes")?.max(1024),
            fsync: FsyncMode::parse(&fsync_str)
                .with_context(|| format!("persist.fsync '{fsync_str}' not one of group|never"))?,
            checkpoint_keep: cfg.usize("persist.checkpoint_keep")?.max(1),
            flush_idle_ms: cfg.u64("persist.flush_idle_ms")?,
            delta_chain_max: cfg.usize("persist.delta_chain_max")?.max(1),
            delta_dirty_ratio: cfg.f64("persist.delta_dirty_ratio")?,
            failpoints: cfg.str("persist.failpoints")?,
        })
    }
}

/// What recovery found and did.
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    /// Seq of the BASE checkpoint loaded (delta-chain elements fold onto
    /// it; see `deltas_folded`).
    pub checkpoint_seq: Option<u64>,
    /// The replay start — the last folded chain element's cut LSN (the
    /// base's own cut when no deltas folded; 0 when starting empty).
    pub start_lsn: u64,
    /// Delta checkpoints folded onto the base (0 when the chain was empty
    /// or discarded after a mid-chain corruption).
    pub deltas_folded: usize,
    pub segments_scanned: usize,
    pub events_replayed: u64,
    pub events_skipped: u64,
    /// Bytes physically truncated off a torn segment tail.
    pub torn_bytes: u64,
    pub max_id: Id,
}

#[derive(Debug, Clone)]
pub struct CheckpointReport {
    pub seq: u64,
    pub start_lsn: u64,
    pub bytes: u64,
    pub duration_ms: f64,
    pub segments_deleted: usize,
    /// True for a base checkpoint, false for a delta.
    pub full: bool,
    /// The base this element belongs to (self for a base).
    pub base_seq: u64,
    /// Delta-chain length after this checkpoint (0 right after a base).
    pub chain_len: usize,
    /// Rows written: the dirty-row count for a delta, all rows for a base.
    pub rows: u64,
    /// True when an *auto* checkpoint wrote nothing because the interval
    /// was quiescent (no dirty rows/topics, no WAL growth since the last
    /// cut) — an empty delta would only lengthen the chain until the
    /// length policy forced a pointless full base. `seq` then names the
    /// existing chain tail.
    pub skipped: bool,
}

impl CheckpointReport {
    pub fn to_json(&self) -> Json {
        let kind = if self.skipped {
            "skipped"
        } else if self.full {
            "base"
        } else {
            "delta"
        };
        Json::obj()
            .set("seq", self.seq)
            .set("start_lsn", self.start_lsn)
            .set("bytes", self.bytes)
            .set("duration_ms", self.duration_ms)
            .set("segments_deleted", self.segments_deleted)
            .set("kind", kind)
            .set("base_seq", self.base_seq)
            .set("chain_len", self.chain_len)
            .set("rows", self.rows)
    }
}

/// Live chain position: the base the next delta folds onto, the tail it
/// links from, and the current length (compaction input). Guarded by the
/// checkpoint mutex for writers; readers take the chain mutex only.
struct ChainState {
    base_seq: u64,
    tail_seq: u64,
    len: usize,
}

struct PersistInner {
    dir: PathBuf,
    opts: PersistOptions,
    /// Attached broker (see [`Persist::open_with_broker`]); checkpoints
    /// include its state as the snapshot-v3 `broker` section.
    broker: Option<Broker>,
    /// On a *store-only* open of a data dir whose checkpoint carried a
    /// broker section: the section, held opaquely so this writer's own
    /// checkpoints carry it through instead of silently destroying
    /// consumer state it never loaded. (Broker WAL-suffix events are
    /// still lost to such a checkpoint's prune — acks among them re-show
    /// as redeliveries, inside the at-least-once contract.)
    carried_broker: Option<Json>,
    wal: Wal,
    flusher: Mutex<Option<std::thread::JoinHandle<()>>>,
    checkpoint_mutex: Mutex<()>,
    checkpoint_seq: AtomicU64,
    last_checkpoint_lsn: AtomicU64,
    last_checkpoint_bytes: AtomicU64,
    /// `(seq, start_lsn)` of the BASE checkpoints still on disk, ascending
    /// — WAL segments are pruned to the *oldest* retained base's cut so
    /// every fallback (including a delta chain discarded over a corrupt
    /// link) keeps a complete replay suffix. Deltas never enter this list:
    /// pruning to a delta cut would strand exactly the fallback that a
    /// mid-chain corruption needs.
    retained: Mutex<Vec<(u64, u64)>>,
    chain: Mutex<ChainState>,
    metrics: Registry,
}

impl Drop for PersistInner {
    fn drop(&mut self) {
        self.wal.stop();
        if let Some(t) = self.flusher.lock().unwrap().take() {
            let _ = t.join();
        }
        let _ = std::fs::remove_file(self.dir.join("LOCK"));
    }
}

/// The durability subsystem handle (cheap to clone).
#[derive(Clone)]
pub struct Persist {
    inner: Arc<PersistInner>,
}

fn checkpoint_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("checkpoint-{seq:08}.json"))
}

/// Base file names only — `checkpoint-N.delta.json` does not parse here
/// (its stem still contains `.delta`).
fn checkpoint_seq_of(name: &str) -> Option<u64> {
    name.strip_prefix("checkpoint-")?.strip_suffix(".json")?.parse().ok()
}

fn delta_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("checkpoint-{seq:08}.delta.json"))
}

fn delta_seq_of(name: &str) -> Option<u64> {
    name.strip_prefix("checkpoint-")?.strip_suffix(".delta.json")?.parse().ok()
}

fn list_by<T: Ord>(dir: &Path, f: impl Fn(&str) -> Option<T>) -> Result<Vec<T>> {
    let mut out = Vec::new();
    if dir.is_dir() {
        for entry in std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
            let entry = entry?;
            if let Some(v) = entry.file_name().to_str().and_then(&f) {
                out.push(v);
            }
        }
    }
    out.sort();
    Ok(out)
}

impl Persist {
    /// Open (or initialize) a data dir: recover the newest checkpoint +
    /// WAL suffix into `store`, truncate any torn tail, advance the id
    /// counter, arm the group-commit writer on a fresh segment, and attach
    /// this WAL to the store as its persister. The store must be freshly
    /// created and not yet shared with daemons or handlers. Broker events
    /// found in the log are dropped (no broker to put them in) — `idds
    /// serve` uses [`Persist::open_with_broker`] instead.
    pub fn open(
        dir: &Path,
        opts: PersistOptions,
        store: &Store,
        metrics: Registry,
    ) -> Result<(Persist, RecoveryReport)> {
        Self::open_with_broker(dir, opts, store, None, metrics)
    }

    /// Like [`Persist::open`], but also recovers broker state — topics,
    /// subscriptions, per-subscriber backlogs and in-flight sets — from
    /// the checkpoint's snapshot-v3 `broker` section plus the WAL suffix,
    /// and attaches the WAL to the broker so subscribe/publish/deliver/ack
    /// are durable from here on. The broker must be freshly created (same
    /// contract as the store).
    pub fn open_with_broker(
        dir: &Path,
        opts: PersistOptions,
        store: &Store,
        broker: Option<&Broker>,
        metrics: Registry,
    ) -> Result<(Persist, RecoveryReport)> {
        Self::open_inner(dir, opts, store, broker, metrics, true)
    }

    /// Like [`Persist::open_with_broker`], but does NOT attach the WAL as
    /// the store/broker persister: a warm standby's only writer is its
    /// pull loop, which appends shipped primary frames explicitly
    /// ([`Wal::append_shipped`]) — locally logging the folds too would
    /// double every event and assign conflicting LSNs. Promote calls
    /// [`Persist::attach`] to turn writes on.
    pub fn open_replica(
        dir: &Path,
        opts: PersistOptions,
        store: &Store,
        broker: &Broker,
        metrics: Registry,
    ) -> Result<(Persist, RecoveryReport)> {
        Self::open_inner(dir, opts, store, Some(broker), metrics, false)
    }

    fn open_inner(
        dir: &Path,
        opts: PersistOptions,
        store: &Store,
        broker: Option<&Broker>,
        metrics: Registry,
        attach: bool,
    ) -> Result<(Persist, RecoveryReport)> {
        if !opts.failpoints.is_empty() {
            failpoints::arm_from_spec(&opts.failpoints)
                .context("parsing persist.failpoints")?;
            log::warn!("fault injection armed: {}", opts.failpoints);
        }
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating data dir {}", dir.display()))?;
        let wal_dir = dir.join("wal");
        std::fs::create_dir_all(&wal_dir)
            .with_context(|| format!("creating wal dir {}", wal_dir.display()))?;

        // single-writer guard: two live processes on one data dir would
        // assign interleaved LSNs and prune each other's segments. The
        // claim is atomic (create_new / O_EXCL); a stale lock from a
        // crashed process (pid no longer alive) is removed and the claim
        // retried — recovery after a crash is the point. Two racers both
        // removing a stale lock still serialize on create_new: exactly
        // one wins, the other re-reads a live pid and bails.
        let lock_path = dir.join("LOCK");
        let mut claimed = false;
        for _ in 0..2 {
            match OpenOptions::new().write(true).create_new(true).open(&lock_path) {
                Ok(mut f) => {
                    f.write_all(std::process::id().to_string().as_bytes())
                        .with_context(|| format!("writing {}", lock_path.display()))?;
                    claimed = true;
                    break;
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let holder = std::fs::read_to_string(&lock_path)
                        .ok()
                        .and_then(|t| t.trim().parse::<u32>().ok());
                    match holder {
                        Some(pid)
                            if pid != std::process::id()
                                && std::path::Path::new(&format!("/proc/{pid}")).exists() =>
                        {
                            anyhow::bail!(
                                "data dir {} is locked by live process {pid}; \
                                 remove {} only if that process is not an idds instance",
                                dir.display(),
                                lock_path.display()
                            );
                        }
                        Some(pid) if pid == std::process::id() => {
                            claimed = true; // same process re-opening (tests)
                            break;
                        }
                        _ => {
                            // dead holder or unreadable lock: clear and retry
                            let _ = std::fs::remove_file(&lock_path);
                        }
                    }
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("claiming {}", lock_path.display()))
                }
            }
        }
        anyhow::ensure!(claimed, "could not claim {} (lock contention)", lock_path.display());

        // sweep temp files a crash mid-checkpoint may have left — seqs
        // never repeat, so nothing else would ever clean them up
        if let Ok(entries) = std::fs::read_dir(dir) {
            for entry in entries.flatten() {
                if let Some(name) = entry.file_name().to_str() {
                    if name.starts_with("checkpoint-") && name.ends_with(".json.tmp") {
                        let _ = std::fs::remove_file(entry.path());
                    }
                }
            }
        }

        let mut report = RecoveryReport::default();

        // 1. newest *valid* BASE checkpoint anchors recovery; every valid
        //    base's cut LSN is remembered so WAL pruning can respect the
        //    oldest retained fallback, not just the newest. A base that
        //    fails any stage — read, parse, missing start_lsn, or decode —
        //    is set aside as `.corrupt` and the next older one is tried.
        //    Decoding is two-phase across both subsystems (decode
        //    everything, fold the chain, install once), so a half-bad
        //    chain fails before touching the store or the broker.
        let base_seqs = list_by(dir, checkpoint_seq_of)?;
        let delta_seqs = list_by(dir, delta_seq_of)?;

        struct Primary {
            seq: u64,
            start_lsn: u64,
            store: DecodedSnapshot,
            broker_json: Option<Json>,
            /// Step-1 decode of the base's broker section, reused at
            /// install when the chain folds no broker deltas on top (the
            /// common case) — otherwise the folded JSON is decoded once.
            broker_decoded: Option<DecodedBroker>,
        }

        let mut retained: Vec<(u64, u64)> = Vec::new(); // usable bases
        let mut primary: Option<Primary> = None;
        let mut carried_broker: Option<Json> = None;
        for &seq in base_seqs.iter().rev() {
            let path = checkpoint_path(dir, seq);
            let validated = (|| -> Result<u64> {
                let text = std::fs::read_to_string(&path)?;
                let body = parse(&text)?;
                let start_lsn = body
                    .get("start_lsn")
                    .and_then(|v| v.as_u64())
                    .context("missing start_lsn")?;
                let snap = body.get("snapshot").context("missing snapshot")?;
                if primary.is_none() {
                    let decoded = store
                        .decode_snapshot_json(snap)
                        .context("snapshot does not decode")?;
                    let mut broker_decoded = None;
                    let broker_json = match snap.get("broker") {
                        Some(bj) if broker.is_some() => {
                            broker_decoded = Some(
                                Broker::decode_snapshot(bj)
                                    .context("broker section does not decode")?,
                            );
                            Some(bj.clone())
                        }
                        // store-only open: held opaquely so this writer's
                        // own base checkpoints carry it through — decoded
                        // anyway so its sub/msg ids still advance the id
                        // counter; an undecodable section is dropped
                        // rather than propagated
                        Some(bj) => match Broker::decode_snapshot(bj) {
                            Ok(d) => {
                                report.max_id = report.max_id.max(d.max_id());
                                Some(bj.clone())
                            }
                            Err(e) => {
                                log::warn!("dropping undecodable broker section: {e}");
                                None
                            }
                        },
                        None => None,
                    };
                    primary = Some(Primary {
                        seq,
                        start_lsn,
                        store: decoded,
                        broker_json,
                        broker_decoded,
                    });
                    return Ok(start_lsn);
                }
                // fallback checkpoints get the same full decode the
                // restore path would need — a checkpoint that cannot
                // load must not be retained (the WAL is pruned to the
                // oldest *retained base's* cut, so retaining a dud would
                // leave no usable recovery point on a double fault)
                Store::validate_snapshot(snap).context("fallback snapshot does not decode")?;
                // broker-less opens ignore the broker section on the
                // primary path, so a corrupt one must not disqualify
                // an otherwise-loadable fallback either
                if broker.is_some() {
                    if let Some(bj) = snap.get("broker") {
                        Broker::decode_snapshot(bj)
                            .context("fallback broker section does not decode")?;
                    }
                }
                Ok(start_lsn)
            })();
            match validated {
                Ok(start_lsn) => retained.push((seq, start_lsn)),
                Err(e) => {
                    let aside = path.with_extension("json.corrupt");
                    log::warn!(
                        "setting aside unusable checkpoint {} ({e}); trying an older one",
                        path.display()
                    );
                    let _ = std::fs::rename(&path, &aside);
                }
            }
        }
        retained.sort_unstable();

        // 1b. fold the chosen base's delta chain: ascending seqs, each
        //     prev-linked to the previous element, every file decodable.
        //     A chain broken anywhere — unreadable file, failed decode, or
        //     a linkage gap — is discarded *wholesale* (the bad file set
        //     aside, the stale rest deleted) and recovery proceeds from
        //     the base + the WAL suffix, which pruning keeps back to the
        //     oldest retained base's cut for exactly this fallback.
        let mut chain_tail = 0u64;
        let mut chain_len = 0usize;
        if let Some(pri) = &mut primary {
            chain_tail = pri.seq;
            type ParsedDelta = (u64, u64, u64, DecodedSnapshot, Option<Json>);
            let mut parsed: Vec<ParsedDelta> = Vec::new();
            let mut chain_ok = true;
            for &dseq in delta_seqs.iter() {
                if dseq < pri.seq {
                    continue; // debris from an older base; retention clears it
                }
                let path = delta_path(dir, dseq);
                let read = (|| -> Result<Option<ParsedDelta>> {
                    let text = std::fs::read_to_string(&path)?;
                    let body = parse(&text)?;
                    let base_seq = body
                        .get("base_seq")
                        .and_then(|v| v.as_u64())
                        .context("missing base_seq")?;
                    if base_seq != pri.seq {
                        return Ok(None); // stale chain of another base
                    }
                    let prev_seq = body
                        .get("prev_seq")
                        .and_then(|v| v.as_u64())
                        .context("missing prev_seq")?;
                    let start_lsn = body
                        .get("start_lsn")
                        .and_then(|v| v.as_u64())
                        .context("missing start_lsn")?;
                    let delta = body.get("delta").context("missing delta")?;
                    let decoded = store
                        .decode_snapshot_json(delta)
                        .context("delta payload does not decode")?;
                    let bdelta = body.get("broker").cloned();
                    if let Some(bj) = &bdelta {
                        let max = Broker::validate_delta(bj)
                            .context("broker delta does not decode")?;
                        report.max_id = report.max_id.max(max);
                    }
                    Ok(Some((dseq, prev_seq, start_lsn, decoded, bdelta)))
                })();
                match read {
                    Ok(Some(d)) => parsed.push(d),
                    Ok(None) => {}
                    Err(e) => {
                        let aside = path.with_extension("json.corrupt");
                        log::warn!(
                            "unusable delta checkpoint {} ({e}): set aside; discarding \
                             the delta chain, recovering from base #{} + WAL suffix",
                            path.display(),
                            pri.seq
                        );
                        let _ = std::fs::rename(&path, &aside);
                        chain_ok = false;
                    }
                }
            }
            if chain_ok {
                let mut expected_prev = pri.seq;
                for (seq, prev, _, _, _) in &parsed {
                    if *prev != expected_prev {
                        log::warn!(
                            "delta chain of base #{} broken at #{seq} (prev {prev}, \
                             expected {expected_prev}); discarding the chain",
                            pri.seq
                        );
                        chain_ok = false;
                        break;
                    }
                    expected_prev = *seq;
                }
            }
            if chain_ok {
                let mut folded_broker = pri.broker_json.take();
                let mut store_deltas = Vec::with_capacity(parsed.len());
                for (seq, _, lsn, decoded, bdelta) in parsed {
                    store_deltas.push(decoded);
                    if let Some(bj) = &bdelta {
                        let mut base = folded_broker.take().unwrap_or(Json::Null);
                        Broker::fold_snapshot_section(&mut base, bj);
                        folded_broker = Some(base);
                        // the base's step-1 decode no longer matches the
                        // folded section; install decodes the fold once
                        pri.broker_decoded = None;
                    }
                    pri.start_lsn = lsn;
                    chain_tail = seq;
                    chain_len += 1;
                }
                // one id→position map per table for the whole chain
                pri.store.fold_chain(store_deltas);
                pri.broker_json = folded_broker;
                report.deltas_folded = chain_len;
            } else {
                // stale links would break prev-linkage for deltas written
                // this run (their prev points at the base) — remove them;
                // their effects are fully covered by the WAL suffix
                for (seq, _, _, _, _) in parsed {
                    let _ = std::fs::remove_file(delta_path(dir, seq));
                }
            }
        }

        // 1c. install the folded state — the first store/broker mutation
        //     of the whole recovery, after every decode/validation passed.
        let (start_lsn, loaded_seq) = match primary {
            Some(mut pri) => {
                let max_id = store.install_decoded(pri.store);
                report.max_id = report.max_id.max(max_id);
                match (broker, &pri.broker_json) {
                    (Some(b), Some(bj)) => {
                        // reuse the step-1 decode unless broker deltas
                        // folded on top; the re-decode of the folded
                        // section cannot fail (every component validated)
                        // but is dropped defensively if it somehow does
                        let decoded = match pri.broker_decoded.take() {
                            Some(d) => Some(d),
                            None => match Broker::decode_snapshot(bj) {
                                Ok(d) => Some(d),
                                Err(e) => {
                                    log::warn!(
                                        "folded broker section does not decode ({e}); dropped"
                                    );
                                    None
                                }
                            },
                        };
                        if let Some(d) = decoded {
                            report.max_id = report.max_id.max(b.install_decoded(d));
                        }
                    }
                    (None, Some(bj)) => carried_broker = Some(bj.clone()),
                    _ => {}
                }
                (pri.start_lsn, Some(pri.seq))
            }
            None => (0, None),
        };
        report.checkpoint_seq = loaded_seq;
        report.start_lsn = start_lsn;

        // dirty tracking on AFTER the base+chain install and BEFORE WAL
        // replay: installed rows are already durable in the very files
        // just loaded (retained until the next base supersedes them), so
        // marking them would only force the first post-boot checkpoint
        // into a full base and spike memory by O(table size); replayed
        // suffix events DO mark, because the chain continues from the
        // recovered tail and the next delta's cut moves past them — their
        // effects must ride in that delta once the old suffix stops
        // replaying.
        store.enable_dirty_tracking();
        if let Some(b) = broker {
            b.enable_dirty_tracking();
        }

        // 2. replay the WAL, truncating each torn tail at its first bad
        //    frame. Scanning CONTINUES past a torn segment: LSNs are
        //    globally monotone across segments and replay is idempotent,
        //    so later segments hold durably committed events (e.g. written
        //    after a rotate-on-write-error) that must not be thrown away —
        //    only the torn suffix of the damaged segment itself is lost.
        let segment_seqs = list_by(&wal_dir, segment_seq)?;
        let mut catalog: Vec<SegmentInfo> = Vec::new();
        let mut last_lsn = start_lsn.saturating_sub(1);
        let mut on_disk_bytes = 0u64;
        for &seq in segment_seqs.iter() {
            let path = segment_path(&wal_dir, seq);
            let scan = scan_segment(&path)?;
            report.segments_scanned += 1;
            let mut info = SegmentInfo { seq, first_lsn: None, last_lsn: None };
            for (lsn, ev) in &scan.events {
                info.first_lsn.get_or_insert(*lsn);
                info.last_lsn = Some(*lsn);
                report.max_id = report.max_id.max(ev.max_id());
                if *lsn < start_lsn {
                    report.events_skipped += 1;
                } else if ev.is_broker() {
                    match broker {
                        Some(b) => {
                            b.apply_event(ev);
                            report.events_replayed += 1;
                        }
                        // store-only open: nowhere to put broker state
                        None => report.events_skipped += 1,
                    }
                } else {
                    store.apply_event(ev);
                    report.events_replayed += 1;
                }
                last_lsn = last_lsn.max(*lsn);
            }
            match &scan.end {
                ScanEnd::Clean => {
                    on_disk_bytes += scan.file_len;
                    catalog.push(info);
                }
                ScanEnd::Torn { valid_len, reason } => {
                    report.torn_bytes += scan.file_len - valid_len;
                    if *valid_len == 0 {
                        // no valid magic: a segment abandoned mid-creation
                        // (or with a destroyed header) holds nothing
                        // recoverable, and truncation can never repair it —
                        // delete it so it stops re-tearing every boot
                        log::warn!(
                            "removing wal segment {} with no valid header ({reason})",
                            path.display()
                        );
                        std::fs::remove_file(&path).with_context(|| {
                            format!("removing headerless segment {}", path.display())
                        })?;
                    } else {
                        log::warn!(
                            "wal segment {} torn at byte {valid_len} ({reason}); truncating {} bytes",
                            path.display(),
                            scan.file_len - valid_len
                        );
                        OpenOptions::new()
                            .write(true)
                            .open(&path)
                            .and_then(|f| f.set_len(*valid_len))
                            .with_context(|| {
                                format!("truncating torn tail of {}", path.display())
                            })?;
                        on_disk_bytes += valid_len;
                        catalog.push(info);
                    }
                }
            }
        }
        crate::util::advance_next_id(report.max_id);

        // 3. arm the writer on a fresh segment
        let next_seq = segment_seqs.last().copied().unwrap_or(0) + 1;
        let (wal, flusher) = Wal::create(
            &wal_dir,
            opts.segment_bytes,
            opts.fsync,
            opts.flush_idle_ms,
            last_lsn + 1,
            next_seq,
            catalog,
            on_disk_bytes,
            &metrics,
        )?;

        let persist = Persist {
            inner: Arc::new(PersistInner {
                dir: dir.to_path_buf(),
                opts,
                broker: broker.cloned(),
                carried_broker,
                wal,
                flusher: Mutex::new(Some(flusher)),
                checkpoint_mutex: Mutex::new(()),
                checkpoint_seq: AtomicU64::new(
                    base_seqs
                        .last()
                        .copied()
                        .unwrap_or(0)
                        .max(delta_seqs.last().copied().unwrap_or(0)),
                ),
                last_checkpoint_lsn: AtomicU64::new(start_lsn),
                last_checkpoint_bytes: AtomicU64::new(0),
                retained: Mutex::new(retained),
                chain: Mutex::new(ChainState {
                    base_seq: loaded_seq.unwrap_or(0),
                    tail_seq: chain_tail,
                    len: chain_len,
                }),
                metrics,
            }),
        };
        if attach {
            persist.attach(store, broker);
        }
        Ok((persist, report))
    }

    /// Attach the WAL as the store's (and broker's) persister so their
    /// mutations are logged from here on. Open does this automatically;
    /// a replica open defers it to promote.
    pub fn attach(&self, store: &Store, broker: Option<&Broker>) {
        store.set_persister(self.persister());
        if let Some(b) = broker {
            b.set_persister(self.persister());
        }
    }

    /// The hook the store logs through.
    pub fn persister(&self) -> Arc<dyn Persister> {
        Arc::new(self.inner.wal.clone())
    }

    /// Direct WAL handle (benches, tests).
    pub fn wal(&self) -> &Wal {
        &self.inner.wal
    }

    /// Block until every event logged so far is durable.
    pub fn flush(&self) {
        self.inner.wal.flush();
    }

    /// Write a durable checkpoint of `store`: a compact **delta**
    /// (`checkpoint-<seq>.delta.json`, the rows and broker topics touched
    /// since the previous cut) when the compaction policy allows, else a
    /// full **base** — the policy forces a base when no base exists yet,
    /// the chain has reached `persist.delta_chain_max`, or the dirty-row
    /// ratio crossed `persist.delta_dirty_ratio`. Bases apply retention
    /// and prune the WAL to the oldest retained base's cut; deltas never
    /// move the prune horizon. Serialized: concurrent calls queue up.
    pub fn checkpoint(&self, store: &Store) -> Result<CheckpointReport> {
        self.checkpoint_inner(store, None)
    }

    /// Force a full base checkpoint (compaction on demand —
    /// `POST /api/admin/checkpoint?full=1`).
    pub fn checkpoint_full(&self, store: &Store) -> Result<CheckpointReport> {
        self.checkpoint_inner(store, Some(true))
    }

    /// Force a delta checkpoint — always writes a file, unlike the auto
    /// path's quiescent skip (the admin route and tests/benches pinning
    /// the chain shape use this). Still writes a base when none exists
    /// yet: a delta without a base would have nothing to fold onto.
    pub fn checkpoint_delta(&self, store: &Store) -> Result<CheckpointReport> {
        self.checkpoint_inner(store, Some(false))
    }

    /// Seed checkpoint for a snapshot-bootstrapped standby: the installed
    /// store corresponds to the primary's WAL position `cut_lsn`, so the
    /// local (empty) WAL must first adopt that LSN and then a base is
    /// written with it as the cut — recovery on this standby thereafter
    /// starts from the seed instead of an empty store. The dirty sets the
    /// snapshot install marked are drained and *discarded*: every row is
    /// in the base being written.
    pub fn bootstrap_base(&self, store: &Store, cut_lsn: u64) -> Result<CheckpointReport> {
        let inner = &*self.inner;
        let _gate = inner.checkpoint_mutex.lock().unwrap();
        let t0 = Instant::now();
        inner.wal.advance_next_lsn(cut_lsn);
        let _ = store.take_dirty();
        if let Some(b) = &inner.broker {
            let _ = b.take_dirty_topics();
        }
        let report = self.write_base(store, cut_lsn, t0)?;
        inner.last_checkpoint_lsn.store(cut_lsn, Ordering::Relaxed);
        inner.last_checkpoint_bytes.store(report.bytes, Ordering::Relaxed);
        Ok(report)
    }

    fn checkpoint_inner(&self, store: &Store, force_full: Option<bool>) -> Result<CheckpointReport> {
        let inner = &*self.inner;
        let _gate = inner.checkpoint_mutex.lock().unwrap();
        let mut sp = crate::obs::span("persist.checkpoint");
        let t0 = Instant::now();
        // everything below start_lsn must be on disk before the checkpoint
        // claims to cover it
        inner.wal.flush();
        let start_lsn = inner.wal.next_lsn();
        // drain dirtiness AFTER the cut read: every mutation whose event
        // predates the cut marked itself before this drain (marks happen
        // before the log enqueue, inside the same lock critical section),
        // so nothing can fall between the delta and the WAL suffix
        let dirty = store.take_dirty();
        let broker_dirty = match &inner.broker {
            Some(b) => b.take_dirty_topics(),
            None => Vec::new(),
        };
        let (base_seq_now, chain_len_now, tail_seq_now) = {
            let chain = inner.chain.lock().unwrap();
            (chain.base_seq, chain.len, chain.tail_seq)
        };
        // quiescent interval: nothing dirty and no WAL growth since the
        // last cut — an auto checkpoint writes nothing, because an empty
        // delta would only lengthen the chain until the length policy
        // forced a pointless full base of an unchanged store. Forced
        // base/delta calls are explicit requests for a file and still
        // write.
        if force_full.is_none()
            && base_seq_now != 0
            && dirty.is_empty()
            && broker_dirty.is_empty()
            && start_lsn == inner.last_checkpoint_lsn.load(Ordering::Relaxed)
        {
            inner.metrics.counter("persist.checkpoint.skipped").inc();
            // a quiescent skip writes nothing — don't let poll-interval
            // no-ops crowd real checkpoints out of the trace ring
            sp.cancel();
            return Ok(CheckpointReport {
                seq: tail_seq_now,
                start_lsn,
                bytes: 0,
                duration_ms: t0.elapsed().as_secs_f64() * 1e3,
                segments_deleted: 0,
                full: false,
                base_seq: base_seq_now,
                chain_len: chain_len_now,
                rows: 0,
                skipped: true,
            });
        }
        let write_base = match force_full {
            Some(true) => true,
            Some(false) => base_seq_now == 0,
            None => {
                base_seq_now == 0
                    || chain_len_now >= inner.opts.delta_chain_max
                    || dirty.total() as f64
                        >= inner.opts.delta_dirty_ratio * store.rows_total().max(1) as f64
            }
        };
        let result = if write_base {
            self.write_base(store, start_lsn, t0)
        } else {
            self.write_delta(store, start_lsn, t0, &dirty, &broker_dirty)
        };
        match &result {
            Ok(report) => {
                sp.attr("kind", if report.full { "base" } else { "delta" });
                sp.attr("bytes", report.bytes);
                sp.attr("rows", report.rows);
                inner.last_checkpoint_lsn.store(start_lsn, Ordering::Relaxed);
                inner.last_checkpoint_bytes.store(report.bytes, Ordering::Relaxed);
                inner.metrics.counter("persist.checkpoint.count").inc();
                if !report.full {
                    inner.metrics.counter("persist.checkpoint.delta.count").inc();
                }
                inner.metrics.counter("persist.checkpoint.bytes").add(report.bytes);
                inner.metrics.counter("persist.checkpoint.rows").add(report.rows);
                inner
                    .metrics
                    .histogram("persist.checkpoint.duration_us")
                    .observe((report.duration_ms * 1e3) as u64);
            }
            Err(_) => {
                // hand the drained dirtiness back or the next delta would
                // silently miss these rows
                store.restore_dirty(dirty);
                if let Some(b) = &inner.broker {
                    b.restore_dirty_topics(broker_dirty);
                }
            }
        }
        result
    }

    /// Atomic durable publish: tmp → write → fsync → rename → dir sync.
    fn publish_json(&self, body: &Json, path: &Path) -> Result<u64> {
        let inner = &*self.inner;
        let _sp = crate::obs::span("persist.checkpoint.write");
        let mut text = String::new();
        body.write_to(&mut text);
        // `checkpoint.corrupt` publishes "successfully" with a truncated
        // body — the input that drives recovery's `.corrupt` sidelining
        if failpoints::check("checkpoint.corrupt").is_err() {
            text.truncate(text.len() / 2);
            log::warn!("failpoint checkpoint.corrupt: publishing truncated {}", path.display());
        }
        let tmp = path.with_extension("json.tmp");
        {
            let mut f =
                File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
            failpoints::check("checkpoint.write")
                .and_then(|_| f.write_all(text.as_bytes()))
                .with_context(|| format!("writing {}", tmp.display()))?;
            if inner.opts.fsync != FsyncMode::Never {
                failpoints::check("checkpoint.fsync")
                    .and_then(|_| f.sync_data())
                    .with_context(|| format!("syncing {}", tmp.display()))?;
            }
        }
        failpoints::check("checkpoint.rename")
            .map_err(anyhow::Error::new)
            .and_then(|_| std::fs::rename(&tmp, path).map_err(anyhow::Error::new))
            .with_context(|| format!("publishing checkpoint {}", path.display()))?;
        if inner.opts.fsync != FsyncMode::Never {
            sync_dir(&inner.dir);
        }
        Ok(text.len() as u64)
    }

    fn write_base(&self, store: &Store, start_lsn: u64, t0: Instant) -> Result<CheckpointReport> {
        let inner = &*self.inner;
        let rows = store.rows_total() as u64;
        let snap = store.snapshot();
        // with a broker attached the base carries the broker section
        // (topics, subscriptions, backlogs, in-flight), read after the cut
        // under the same topic locks the broker logs under — the fuzzy-cut
        // argument covers it (DESIGN.md, "Broker").
        let snap = match (&inner.broker, &inner.carried_broker) {
            (Some(b), _) => snap.set("broker", b.snapshot_json()),
            // store-only writer on a broker-bearing dir: pass the
            // recovered (chain-folded) section through unchanged
            (None, Some(bj)) => snap.set("broker", bj.clone()),
            (None, None) => snap,
        };
        let seq = inner.checkpoint_seq.fetch_add(1, Ordering::Relaxed) + 1;
        let body = Json::obj()
            .set("version", 1u64)
            .set("seq", seq)
            .set("start_lsn", start_lsn)
            .set("snapshot", snap);
        let path = checkpoint_path(&inner.dir, seq);
        let bytes = self.publish_json(&body, &path)?;
        // retention first: drop all but the newest `checkpoint_keep` BASES
        // plus every delta (this base supersedes any chain), then prune
        // the WAL only to the oldest base cut still retained — if this
        // checkpoint ever fails to parse, the fallback still has its full
        // replay suffix on disk
        let prune_lsn = {
            let mut retained = inner.retained.lock().unwrap();
            retained.push((seq, start_lsn));
            while retained.len() > inner.opts.checkpoint_keep {
                retained.remove(0);
            }
            let oldest_seq = retained.first().map(|&(s, _)| s).unwrap_or(seq);
            if let Ok(seqs) = list_by(&inner.dir, checkpoint_seq_of) {
                for &old in seqs.iter().filter(|&&s| s < oldest_seq) {
                    let _ = std::fs::remove_file(checkpoint_path(&inner.dir, old));
                }
            }
            if let Ok(dseqs) = list_by(&inner.dir, delta_seq_of) {
                for &old in dseqs.iter().filter(|&&s| s < seq) {
                    let _ = std::fs::remove_file(delta_path(&inner.dir, old));
                }
            }
            retained.iter().map(|&(_, lsn)| lsn).min().unwrap_or(start_lsn)
        };
        let segments_deleted = inner.wal.prune_below(prune_lsn);
        {
            let mut chain = inner.chain.lock().unwrap();
            chain.base_seq = seq;
            chain.tail_seq = seq;
            chain.len = 0;
        }
        Ok(CheckpointReport {
            seq,
            start_lsn,
            bytes,
            duration_ms: t0.elapsed().as_secs_f64() * 1e3,
            segments_deleted,
            full: true,
            base_seq: seq,
            chain_len: 0,
            rows,
            skipped: false,
        })
    }

    fn write_delta(
        &self,
        store: &Store,
        start_lsn: u64,
        t0: Instant,
        dirty: &DirtySets,
        broker_dirty: &[String],
    ) -> Result<CheckpointReport> {
        let inner = &*self.inner;
        let rows = dirty.total() as u64;
        let delta = store.delta_snapshot(dirty);
        let (base_seq, prev_seq, new_len) = {
            let chain = inner.chain.lock().unwrap();
            (chain.base_seq, chain.tail_seq, chain.len + 1)
        };
        let seq = inner.checkpoint_seq.fetch_add(1, Ordering::Relaxed) + 1;
        let mut body = Json::obj()
            .set("version", 1u64)
            .set("kind", "delta")
            .set("seq", seq)
            .set("base_seq", base_seq)
            .set("prev_seq", prev_seq)
            .set("start_lsn", start_lsn)
            .set("delta", delta);
        if let Some(b) = &inner.broker {
            if !broker_dirty.is_empty() {
                // touched topics read after the cut under their topic
                // locks — the same fuzzy-cut argument as the store tables
                body = body.set("broker", b.delta_json(broker_dirty));
            }
        }
        let path = delta_path(&inner.dir, seq);
        let bytes = self.publish_json(&body, &path)?;
        // no retention and no WAL pruning here: the prune horizon is the
        // oldest retained BASE's cut (regression-pinned — pruning to a
        // delta cut would strand exactly the base fallback a mid-chain
        // corruption needs), and that horizon only moves when a base lands
        {
            let mut chain = inner.chain.lock().unwrap();
            chain.tail_seq = seq;
            chain.len = new_len;
        }
        Ok(CheckpointReport {
            seq,
            start_lsn,
            bytes,
            duration_ms: t0.elapsed().as_secs_f64() * 1e3,
            segments_deleted: 0,
            full: false,
            base_seq,
            chain_len: new_len,
            rows,
            skipped: false,
        })
    }

    /// Checkpoint topology for the `/api/health` persist section: current
    /// base, delta-chain length, last checkpoint size, and the live
    /// dirty-row counts the next delta would write.
    pub fn checkpoint_topology(&self, store: &Store) -> Json {
        let inner = &*self.inner;
        let (base_seq, chain_len) = {
            let chain = inner.chain.lock().unwrap();
            (chain.base_seq, chain.len)
        };
        let mut j = Json::obj()
            .set("base_seq", base_seq)
            .set("chain_len", chain_len)
            .set("last_seq", inner.checkpoint_seq.load(Ordering::Relaxed))
            .set("last_bytes", inner.last_checkpoint_bytes.load(Ordering::Relaxed))
            .set("dirty", store.dirty_counts())
            .set("dirty_total", store.dirty_total());
        if let Some(b) = &inner.broker {
            j = j.set("dirty_topics", b.dirty_topic_count());
        }
        j
    }

    /// Live durability stats for `/api/health`.
    pub fn stats(&self) -> Json {
        let wal = &self.inner.wal;
        let next = wal.next_lsn();
        let durable = wal.durable_lsn();
        // no data-dir path here: stats land in the unauthenticated
        // /api/health response, and filesystem layout should not leak
        let mut j = Json::obj()
            .set("next_lsn", next)
            .set("durable_lsn", durable)
            .set("lag_events", next - 1 - durable.min(next - 1))
            .set("wal_segments", wal.segment_count())
            .set("wal_bytes", wal.bytes_on_disk())
            .set(
                "last_checkpoint_seq",
                self.inner.checkpoint_seq.load(Ordering::Relaxed),
            )
            .set(
                "last_checkpoint_lsn",
                self.inner.last_checkpoint_lsn.load(Ordering::Relaxed),
            )
            .set(
                "last_checkpoint_bytes",
                self.inner.last_checkpoint_bytes.load(Ordering::Relaxed),
            );
        if let Some(e) = wal.io_error() {
            j = j.set("io_error", e);
        }
        j
    }

    /// Stop the flusher after draining the queue. Also runs on drop of the
    /// last clone.
    pub fn shutdown(&self) {
        self.inner.wal.flush();
        self.inner.wal.stop();
        if let Some(t) = self.inner.flusher.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{RequestKind, RequestStatus};
    use crate::util::clock::WallClock;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "idds-persist-{tag}-{}-{}",
            std::process::id(),
            crate::util::next_id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn opts() -> PersistOptions {
        PersistOptions {
            segment_bytes: 32 * 1024,
            fsync: FsyncMode::Never,
            checkpoint_keep: 2,
            flush_idle_ms: 5,
            ..PersistOptions::default()
        }
    }

    fn store() -> Store {
        Store::new(Arc::new(WallClock::new()))
    }

    #[test]
    fn empty_dir_opens_with_nothing_to_recover() {
        let dir = tmp_dir("empty");
        let s = store();
        let (p, report) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
        assert_eq!(report.events_replayed, 0);
        assert_eq!(report.checkpoint_seq, None);
        assert_eq!(s.counts().get("requests").unwrap().as_u64(), Some(0));
        p.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn log_recover_replays_events() {
        let dir = tmp_dir("replay");
        let s = store();
        let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
        let ids: Vec<_> = (0..20)
            .map(|i| s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null))
            .collect();
        assert_eq!(s.update_requests_status(&ids[..10], RequestStatus::Transforming), 10);
        p.shutdown();

        let s2 = store();
        let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
        // 20 inserts plus the batch transition (one event per stripe the
        // batch touched, so between 1 and 10 events for 10 ids)
        assert!(
            (21..=30).contains(&report.events_replayed),
            "unexpected replay count {}",
            report.events_replayed
        );
        assert_eq!(
            s2.requests_with_status(RequestStatus::Transforming),
            s.requests_with_status(RequestStatus::Transforming)
        );
        assert_eq!(
            s2.requests_with_status(RequestStatus::New),
            s.requests_with_status(RequestStatus::New)
        );
        // ids keep flowing past everything recovered
        let fresh = s2.add_request("fresh", "u", RequestKind::Workflow, Json::Null);
        assert!(fresh > *ids.iter().max().unwrap());
        p2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_prunes_and_recovery_uses_it() {
        let dir = tmp_dir("ckpt");
        let s = store();
        let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
        let ids: Vec<_> = (0..50)
            .map(|i| s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null))
            .collect();
        let rep = p.checkpoint(&s).unwrap();
        assert!(rep.start_lsn > 50);
        // post-checkpoint writes land in the WAL suffix
        assert_eq!(s.update_requests_status(&ids, RequestStatus::Transforming), 50);
        p.shutdown();

        let s2 = store();
        let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
        assert_eq!(report.checkpoint_seq, Some(rep.seq));
        // only the post-checkpoint batch replays: one event per stripe it
        // touched, never the 50 pre-checkpoint inserts
        assert!(
            (1..=16).contains(&report.events_replayed),
            "unexpected replay count {}",
            report.events_replayed
        );
        assert_eq!(
            s2.requests_with_status(RequestStatus::Transforming).len(),
            50
        );
        p2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unusable_newest_checkpoint_falls_back_to_older() {
        let dir = tmp_dir("fallback");
        let s = store();
        let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
        for i in 0..10 {
            s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
        }
        let first = p.checkpoint_full(&s).unwrap();
        s.add_request("late", "u", RequestKind::Workflow, Json::Null);
        let second = p.checkpoint_full(&s).unwrap();
        p.shutdown();
        // newest checkpoint parses as JSON but cannot restore (bad version)
        std::fs::write(
            checkpoint_path(&dir, second.seq),
            Json::obj()
                .set("version", 1u64)
                .set("seq", second.seq)
                .set("start_lsn", second.start_lsn)
                .set("snapshot", Json::obj().set("version", 99u64))
                .to_string(),
        )
        .unwrap();

        let s2 = store();
        let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
        assert_eq!(
            report.checkpoint_seq,
            Some(first.seq),
            "recovery must fall back to the older checkpoint"
        );
        // WAL was pruned only to the oldest retained cut, so the suffix
        // after the fallback checkpoint (incl. the 'late' insert) replays
        assert_eq!(s2.counts().get("requests").unwrap().as_u64(), Some(11));
        // the unusable file was set aside, not left to fail every boot
        assert!(!checkpoint_path(&dir, second.seq).exists());
        assert!(checkpoint_path(&dir, second.seq)
            .with_extension("json.corrupt")
            .exists());
        p2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segments_after_a_torn_middle_segment_still_replay() {
        let dir = tmp_dir("tornmid");
        let s = store();
        let small = PersistOptions { segment_bytes: 2048, ..opts() };
        let (p, _) = Persist::open(&dir, small.clone(), &s, Registry::default()).unwrap();
        let ids: Vec<_> = (0..120)
            .map(|i| {
                let id = s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
                if i % 10 == 0 {
                    p.flush(); // force small flush batches → several segments
                }
                id
            })
            .collect();
        p.shutdown();
        let wal_dir = dir.join("wal");
        let mut segs = list_by(&wal_dir, super::wal::segment_seq).unwrap();
        segs.retain(|&seq| {
            std::fs::metadata(super::wal::segment_path(&wal_dir, seq))
                .map(|m| m.len() > 16)
                .unwrap_or(false)
        });
        assert!(segs.len() >= 3, "need several segments, got {}", segs.len());
        // tear the tail of a MIDDLE segment
        let victim = super::wal::segment_path(&wal_dir, segs[segs.len() / 2]);
        let len = std::fs::metadata(&victim).unwrap().len();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&victim)
            .unwrap()
            .set_len(len - 5)
            .unwrap();

        let s2 = store();
        let (p2, report) = Persist::open(&dir, small, &s2, Registry::default()).unwrap();
        assert!(report.torn_bytes > 0);
        // events after the torn segment were durably committed and must
        // survive — in particular the very last insert
        assert!(s2.get_request(*ids.last().unwrap()).is_ok());
        // only the torn frame's events are lost, not whole segments
        assert!(report.events_replayed > 110, "lost more than the torn frame");
        p2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn broker_state_round_trips_through_checkpoint_and_wal() {
        let dir = tmp_dir("broker");
        let s = store();
        let clock = crate::util::clock::SimClock::new();
        let b = Broker::new(clock.clone()).with_redelivery_timeout(10.0);
        let (p, _) =
            Persist::open_with_broker(&dir, opts(), &s, Some(&b), Registry::default()).unwrap();
        let sub = b.subscribe("idds.out");
        b.publish_many("idds.out", (0..5).map(|i| Json::from(i as u64)).collect());
        let ds = b.poll(sub, 2); // 2 in flight
        p.checkpoint(&s).unwrap();
        // the WAL suffix past the checkpoint cut
        b.publish("idds.out", Json::from(99u64));
        assert!(b.ack(sub, ds[0].id));
        p.shutdown();

        let s2 = store();
        let clock2 = crate::util::clock::SimClock::new();
        let b2 = Broker::new(clock2).with_redelivery_timeout(10.0);
        let (p2, report) =
            Persist::open_with_broker(&dir, opts(), &s2, Some(&b2), Registry::default()).unwrap();
        assert!(report.checkpoint_seq.is_some());
        assert_eq!(b.snapshot_json(), b2.snapshot_json(), "broker state must survive");
        assert_eq!(b2.backlog(sub), 5, "4 pending + 1 unacked in-flight");
        p2.shutdown();

        // a store-only open of the same dir must still work: the v3
        // snapshot's broker section is held opaquely and broker WAL
        // events are skipped
        let s3 = store();
        let (p3, r3) = Persist::open(&dir, opts(), &s3, Registry::default()).unwrap();
        assert!(r3.checkpoint_seq.is_some());
        // ... and a checkpoint it writes must carry the broker section
        // through, not destroy it
        p3.checkpoint(&s3).unwrap();
        p3.shutdown();

        let s4 = store();
        let clock4 = crate::util::clock::SimClock::new();
        let b4 = Broker::new(clock4).with_redelivery_timeout(10.0);
        let (p4, _) =
            Persist::open_with_broker(&dir, opts(), &s4, Some(&b4), Registry::default()).unwrap();
        // the carried section is the state at the ORIGINAL checkpoint cut
        // (3 pending + 2 in-flight); the suffix publish/ack predate the
        // store-only checkpoint's cut, so they do not replay on top —
        // the ack re-shows as a redelivery, per at-least-once
        assert_eq!(b4.backlog(sub), 5, "broker state must survive a store-only checkpoint");
        assert_eq!(
            b4.health_json().get("subscriptions").unwrap().as_u64(),
            Some(1),
            "the subscription itself must survive"
        );
        p4.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_retention_keeps_newest() {
        let dir = tmp_dir("keep");
        let s = store();
        let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
        for i in 0..4 {
            s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
            p.checkpoint_full(&s).unwrap();
        }
        let ckpts = list_by(&dir, checkpoint_seq_of).unwrap();
        assert_eq!(ckpts.len(), 2, "retention must keep checkpoint_keep files");
        assert_eq!(ckpts, vec![3, 4]);
        p.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn delta_checkpoints_chain_and_recover() {
        let dir = tmp_dir("delta");
        let s = store();
        let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
        for i in 0..20 {
            s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
        }
        let base = p.checkpoint(&s).unwrap();
        assert!(base.full, "the first checkpoint must be a base");
        let ids = s.requests_with_status(RequestStatus::New);
        s.update_requests_status(&ids[..3], RequestStatus::Transforming);
        let d1 = p.checkpoint_delta(&s).unwrap();
        assert!(!d1.full);
        assert_eq!(d1.base_seq, base.seq);
        assert_eq!(d1.chain_len, 1);
        assert_eq!(d1.rows, 3, "a delta writes only the dirty rows");
        assert!(d1.bytes < base.bytes, "delta bytes scale with churn");
        assert!(delta_path(&dir, d1.seq).exists());
        s.update_requests_status(&ids[..1], RequestStatus::Finished);
        let d2 = p.checkpoint_delta(&s).unwrap();
        assert_eq!(d2.chain_len, 2);
        assert_eq!(d2.rows, 1);
        // suffix past the last delta
        s.add_request("suffix", "u", RequestKind::Workflow, Json::Null);
        p.shutdown();

        let s2 = store();
        let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
        assert_eq!(report.checkpoint_seq, Some(base.seq), "the base anchors recovery");
        assert_eq!(report.deltas_folded, 2);
        assert_eq!(report.start_lsn, d2.start_lsn, "replay starts at the chain tail");
        assert_eq!(s2.counts().get("requests").unwrap().as_u64(), Some(21));
        assert_eq!(s2.requests_with_status(RequestStatus::Transforming).len(), 2);
        assert_eq!(s2.requests_with_status(RequestStatus::Finished), ids[..1].to_vec());
        p2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn auto_policy_compacts_on_chain_length_and_dirty_ratio() {
        let dir = tmp_dir("policy");
        let s = store();
        let tuned = PersistOptions { delta_chain_max: 2, delta_dirty_ratio: 0.5, ..opts() };
        let (p, _) = Persist::open(&dir, tuned, &s, Registry::default()).unwrap();
        let ids: Vec<_> = (0..40)
            .map(|i| s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null))
            .collect();
        assert!(p.checkpoint(&s).unwrap().full, "no base yet → base");
        // small churn → deltas, until the chain cap forces compaction
        s.update_requests_status(&ids[..2], RequestStatus::Transforming);
        assert!(!p.checkpoint(&s).unwrap().full);
        s.update_requests_status(&ids[..2], RequestStatus::Finished);
        assert!(!p.checkpoint(&s).unwrap().full);
        s.update_requests_status(&ids[2..4], RequestStatus::Transforming);
        let compacted = p.checkpoint(&s).unwrap();
        assert!(compacted.full, "chain at delta_chain_max must compact to a base");
        assert_eq!(compacted.chain_len, 0);
        assert!(
            list_by(&dir, delta_seq_of).unwrap().is_empty(),
            "a new base supersedes and removes the old chain"
        );
        // heavy churn → ratio forces a base even with a short chain
        s.update_requests_status(&ids, RequestStatus::Transforming);
        assert!(p.checkpoint(&s).unwrap().full, "dirty ratio >= threshold must compact");
        p.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quiescent_auto_checkpoints_write_nothing() {
        let dir = tmp_dir("quiescent");
        let s = store();
        let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
        for i in 0..5 {
            s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
        }
        let base = p.checkpoint(&s).unwrap();
        assert!(base.full && !base.skipped);
        // nothing changed since the base: every further auto tick is free
        for _ in 0..3 {
            let r = p.checkpoint(&s).unwrap();
            assert!(r.skipped, "an idle interval must not write a file");
            assert_eq!(r.seq, base.seq);
            assert_eq!(r.chain_len, 0, "skips must not lengthen the chain");
        }
        assert!(list_by(&dir, delta_seq_of).unwrap().is_empty());
        // forced calls are explicit requests for a file and still write
        assert!(!p.checkpoint_delta(&s).unwrap().skipped);
        // ... and new work re-arms the auto path
        s.add_request("r2", "u", RequestKind::Workflow, Json::Null);
        let r = p.checkpoint(&s).unwrap();
        assert!(!r.skipped);
        assert_eq!(r.rows, 1);
        p.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_delta_checkpoint_is_valid() {
        let dir = tmp_dir("emptydelta");
        let s = store();
        let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
        s.add_request("r", "u", RequestKind::Workflow, Json::Null);
        p.checkpoint(&s).unwrap();
        // nothing dirty: the delta is empty but keeps the chain linked
        let d = p.checkpoint_delta(&s).unwrap();
        assert!(!d.full);
        assert_eq!(d.rows, 0);
        p.shutdown();
        let s2 = store();
        let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
        assert_eq!(report.deltas_folded, 1);
        assert_eq!(s2.counts().get("requests").unwrap().as_u64(), Some(1));
        p2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
