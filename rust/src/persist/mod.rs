//! Durable state for the head service: write-ahead log + checkpoints +
//! crash recovery (production iDDS keeps this state in Oracle/PostgreSQL;
//! here an append-only WAL over [`crate::store::Store`] plays that role —
//! see DESIGN.md, "Durability model").
//!
//! Layout under the data dir:
//!
//! ```text
//! <data_dir>/
//!   checkpoint-00000001.json     Store::snapshot() + the WAL cut LSN
//!   wal/wal-00000001.log         length+CRC-framed event segments
//! ```
//!
//! * **Write path** — the store *and the broker* log one [`PersistEvent`]
//!   per applied mutation through the [`Persister`] hook; the WAL
//!   group-commits them (one write+fsync per flusher batch, mirroring the
//!   store's batched transition philosophy).
//! * **Checkpoint** — flush the WAL, note the next LSN (`start_lsn`),
//!   write `Store::snapshot()` durably — extended to snapshot format v3
//!   with a `broker` section when a broker is attached (see
//!   [`Persist::open_with_broker`]) — then rotate + delete segments whose
//!   events all predate `start_lsn`.
//! * **Recovery** — load the newest readable checkpoint, replay the WAL
//!   suffix (`lsn >= start_lsn`) through [`crate::store::Store::apply_event`]
//!   (broker events route to [`crate::broker::Broker::apply_event`]),
//!   truncate any torn tail at the first bad frame, and advance the
//!   process-wide id counter past everything seen.
//!
//! The soundness argument for the fuzzy checkpoint cut (log-after-apply
//! under the discovery lock ⇒ `lsn < start_lsn` implies the effect is in
//! the snapshot; replay is insert-if-absent + last-write-wins so the
//! overlapping suffix converges) lives in DESIGN.md.

pub mod events;
pub mod wal;

use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::broker::Broker;
use crate::config::Config;
use crate::metrics::Registry;
use crate::store::{Id, Store};
use crate::util::json::{parse, Json};

pub use events::{PersistEvent, Persister};
pub use wal::Wal;

use wal::{scan_segment, segment_path, segment_seq, sync_dir, ScanEnd, SegmentInfo};

/// When the flusher calls `fsync`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncMode {
    /// One `fsync` per group-commit batch (the durable default).
    Group,
    /// Never fsync — page cache only (fast, survives process crashes but
    /// not power loss; useful for tests and benches).
    Never,
}

impl FsyncMode {
    pub fn parse(s: &str) -> Option<FsyncMode> {
        match s {
            "group" => Some(FsyncMode::Group),
            "never" => Some(FsyncMode::Never),
            _ => None,
        }
    }
}

/// Tunables, resolved from the `persist.*` config keys.
#[derive(Debug, Clone)]
pub struct PersistOptions {
    pub segment_bytes: u64,
    pub fsync: FsyncMode,
    pub checkpoint_keep: usize,
    pub flush_idle_ms: u64,
}

impl Default for PersistOptions {
    fn default() -> Self {
        PersistOptions {
            segment_bytes: 8 * 1024 * 1024,
            fsync: FsyncMode::Group,
            checkpoint_keep: 2,
            flush_idle_ms: 50,
        }
    }
}

impl PersistOptions {
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let fsync_str = cfg.str("persist.fsync")?;
        Ok(PersistOptions {
            segment_bytes: cfg.u64("persist.segment_bytes")?.max(1024),
            fsync: FsyncMode::parse(&fsync_str)
                .with_context(|| format!("persist.fsync '{fsync_str}' not one of group|never"))?,
            checkpoint_keep: cfg.usize("persist.checkpoint_keep")?.max(1),
            flush_idle_ms: cfg.u64("persist.flush_idle_ms")?,
        })
    }
}

/// What recovery found and did.
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    pub checkpoint_seq: Option<u64>,
    /// The loaded checkpoint's cut LSN (0 when starting empty).
    pub start_lsn: u64,
    pub segments_scanned: usize,
    pub events_replayed: u64,
    pub events_skipped: u64,
    /// Bytes physically truncated off a torn segment tail.
    pub torn_bytes: u64,
    pub max_id: Id,
}

#[derive(Debug, Clone)]
pub struct CheckpointReport {
    pub seq: u64,
    pub start_lsn: u64,
    pub bytes: u64,
    pub duration_ms: f64,
    pub segments_deleted: usize,
}

impl CheckpointReport {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("seq", self.seq)
            .set("start_lsn", self.start_lsn)
            .set("bytes", self.bytes)
            .set("duration_ms", self.duration_ms)
            .set("segments_deleted", self.segments_deleted)
    }
}

struct PersistInner {
    dir: PathBuf,
    opts: PersistOptions,
    /// Attached broker (see [`Persist::open_with_broker`]); checkpoints
    /// include its state as the snapshot-v3 `broker` section.
    broker: Option<Broker>,
    /// On a *store-only* open of a data dir whose checkpoint carried a
    /// broker section: the section, held opaquely so this writer's own
    /// checkpoints carry it through instead of silently destroying
    /// consumer state it never loaded. (Broker WAL-suffix events are
    /// still lost to such a checkpoint's prune — acks among them re-show
    /// as redeliveries, inside the at-least-once contract.)
    carried_broker: Option<Json>,
    wal: Wal,
    flusher: Mutex<Option<std::thread::JoinHandle<()>>>,
    checkpoint_mutex: Mutex<()>,
    checkpoint_seq: AtomicU64,
    last_checkpoint_lsn: AtomicU64,
    /// `(seq, start_lsn)` of the checkpoints still on disk, ascending —
    /// WAL segments are pruned to the *oldest* retained cut so every
    /// fallback checkpoint keeps a complete replay suffix.
    retained: Mutex<Vec<(u64, u64)>>,
    metrics: Registry,
}

impl Drop for PersistInner {
    fn drop(&mut self) {
        self.wal.stop();
        if let Some(t) = self.flusher.lock().unwrap().take() {
            let _ = t.join();
        }
        let _ = std::fs::remove_file(self.dir.join("LOCK"));
    }
}

/// The durability subsystem handle (cheap to clone).
#[derive(Clone)]
pub struct Persist {
    inner: Arc<PersistInner>,
}

fn checkpoint_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("checkpoint-{seq:08}.json"))
}

fn checkpoint_seq_of(name: &str) -> Option<u64> {
    name.strip_prefix("checkpoint-")?.strip_suffix(".json")?.parse().ok()
}

fn list_by<T: Ord>(dir: &Path, f: impl Fn(&str) -> Option<T>) -> Result<Vec<T>> {
    let mut out = Vec::new();
    if dir.is_dir() {
        for entry in std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
            let entry = entry?;
            if let Some(v) = entry.file_name().to_str().and_then(&f) {
                out.push(v);
            }
        }
    }
    out.sort();
    Ok(out)
}

impl Persist {
    /// Open (or initialize) a data dir: recover the newest checkpoint +
    /// WAL suffix into `store`, truncate any torn tail, advance the id
    /// counter, arm the group-commit writer on a fresh segment, and attach
    /// this WAL to the store as its persister. The store must be freshly
    /// created and not yet shared with daemons or handlers. Broker events
    /// found in the log are dropped (no broker to put them in) — `idds
    /// serve` uses [`Persist::open_with_broker`] instead.
    pub fn open(
        dir: &Path,
        opts: PersistOptions,
        store: &Store,
        metrics: Registry,
    ) -> Result<(Persist, RecoveryReport)> {
        Self::open_with_broker(dir, opts, store, None, metrics)
    }

    /// Like [`Persist::open`], but also recovers broker state — topics,
    /// subscriptions, per-subscriber backlogs and in-flight sets — from
    /// the checkpoint's snapshot-v3 `broker` section plus the WAL suffix,
    /// and attaches the WAL to the broker so subscribe/publish/deliver/ack
    /// are durable from here on. The broker must be freshly created (same
    /// contract as the store).
    pub fn open_with_broker(
        dir: &Path,
        opts: PersistOptions,
        store: &Store,
        broker: Option<&Broker>,
        metrics: Registry,
    ) -> Result<(Persist, RecoveryReport)> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating data dir {}", dir.display()))?;
        let wal_dir = dir.join("wal");
        std::fs::create_dir_all(&wal_dir)
            .with_context(|| format!("creating wal dir {}", wal_dir.display()))?;

        // single-writer guard: two live processes on one data dir would
        // assign interleaved LSNs and prune each other's segments. The
        // claim is atomic (create_new / O_EXCL); a stale lock from a
        // crashed process (pid no longer alive) is removed and the claim
        // retried — recovery after a crash is the point. Two racers both
        // removing a stale lock still serialize on create_new: exactly
        // one wins, the other re-reads a live pid and bails.
        let lock_path = dir.join("LOCK");
        let mut claimed = false;
        for _ in 0..2 {
            match OpenOptions::new().write(true).create_new(true).open(&lock_path) {
                Ok(mut f) => {
                    f.write_all(std::process::id().to_string().as_bytes())
                        .with_context(|| format!("writing {}", lock_path.display()))?;
                    claimed = true;
                    break;
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let holder = std::fs::read_to_string(&lock_path)
                        .ok()
                        .and_then(|t| t.trim().parse::<u32>().ok());
                    match holder {
                        Some(pid)
                            if pid != std::process::id()
                                && std::path::Path::new(&format!("/proc/{pid}")).exists() =>
                        {
                            anyhow::bail!(
                                "data dir {} is locked by live process {pid}; \
                                 remove {} only if that process is not an idds instance",
                                dir.display(),
                                lock_path.display()
                            );
                        }
                        Some(pid) if pid == std::process::id() => {
                            claimed = true; // same process re-opening (tests)
                            break;
                        }
                        _ => {
                            // dead holder or unreadable lock: clear and retry
                            let _ = std::fs::remove_file(&lock_path);
                        }
                    }
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("claiming {}", lock_path.display()))
                }
            }
        }
        anyhow::ensure!(claimed, "could not claim {} (lock contention)", lock_path.display());

        // sweep temp files a crash mid-checkpoint may have left — seqs
        // never repeat, so nothing else would ever clean them up
        if let Ok(entries) = std::fs::read_dir(dir) {
            for entry in entries.flatten() {
                if let Some(name) = entry.file_name().to_str() {
                    if name.starts_with("checkpoint-") && name.ends_with(".json.tmp") {
                        let _ = std::fs::remove_file(entry.path());
                    }
                }
            }
        }

        let mut report = RecoveryReport::default();

        // 1. newest *valid* checkpoint restores the store; every valid
        //    checkpoint's cut LSN is remembered so WAL pruning can respect
        //    the oldest retained fallback, not just the newest. A
        //    checkpoint that fails any stage — read, parse, missing
        //    start_lsn, or restore — is set aside as `.corrupt` and the
        //    next older one is tried; `Store::restore` is two-phase
        //    (decode-then-insert), so a half-bad snapshot fails before
        //    touching the store and the fallback loads into a clean slate.
        let checkpoint_seqs = list_by(dir, checkpoint_seq_of)?;
        let mut retained: Vec<(u64, u64)> = Vec::new(); // (seq, start_lsn)
        let mut loaded: Option<(u64, u64)> = None;
        let mut carried_broker: Option<Json> = None;
        for &seq in checkpoint_seqs.iter().rev() {
            let path = checkpoint_path(dir, seq);
            let validated = std::fs::read_to_string(&path)
                .map_err(anyhow::Error::from)
                .and_then(|text| parse(&text).map_err(anyhow::Error::from))
                .and_then(|j| {
                    let start_lsn = j
                        .get("start_lsn")
                        .and_then(|v| v.as_u64())
                        .context("missing start_lsn")?;
                    let snap = j.get("snapshot").context("missing snapshot")?;
                    if loaded.is_none() {
                        // two-phase across both subsystems: the broker
                        // section is decoded before the store restore
                        // mutates anything, so a checkpoint that fails
                        // either stage is set aside with both left clean
                        let decoded_broker = match (broker, snap.get("broker")) {
                            (Some(_), Some(bj)) => Some(
                                Broker::decode_snapshot(bj)
                                    .context("broker section does not decode")?,
                            ),
                            // store-only open: hold the section opaquely
                            // so our own checkpoints carry it through
                            // (see `carried_broker`) — decoded anyway so
                            // its sub/msg ids still advance the id
                            // counter; an undecodable section is dropped
                            // rather than propagated
                            (None, Some(bj)) => match Broker::decode_snapshot(bj) {
                                Ok(d) => {
                                    carried_broker = Some(bj.clone());
                                    Some(d)
                                }
                                Err(e) => {
                                    log::warn!("dropping undecodable broker section: {e}");
                                    None
                                }
                            },
                            _ => None,
                        };
                        let mut max_id =
                            store.restore(snap).context("snapshot does not restore")?;
                        if let Some(d) = decoded_broker {
                            max_id = max_id.max(match broker {
                                Some(b) => b.install_decoded(d),
                                None => d.max_id(),
                            });
                        }
                        return Ok((Some(max_id), start_lsn));
                    }
                    // fallback checkpoints get the same full decode the
                    // restore path would need — a checkpoint that cannot
                    // load must not be retained (the WAL is pruned to the
                    // oldest *retained* cut, so retaining a dud would
                    // leave no usable recovery point on a double fault)
                    Store::validate_snapshot(snap)
                        .context("fallback snapshot does not decode")?;
                    // broker-less opens ignore the broker section on the
                    // primary path, so a corrupt one must not disqualify
                    // an otherwise-loadable fallback either
                    if broker.is_some() {
                        if let Some(bj) = snap.get("broker") {
                            Broker::decode_snapshot(bj)
                                .context("fallback broker section does not decode")?;
                        }
                    }
                    Ok((None, start_lsn))
                });
            match validated {
                Ok((restored_max_id, start_lsn)) => {
                    if let Some(max_id) = restored_max_id {
                        report.max_id = report.max_id.max(max_id);
                        loaded = Some((seq, start_lsn));
                    }
                    retained.push((seq, start_lsn));
                }
                Err(e) => {
                    let aside = path.with_extension("json.corrupt");
                    log::warn!(
                        "setting aside unusable checkpoint {} ({e}); trying an older one",
                        path.display()
                    );
                    let _ = std::fs::rename(&path, &aside);
                }
            }
        }
        retained.sort_unstable();
        let start_lsn = loaded.map(|(_, lsn)| lsn).unwrap_or(0);
        report.checkpoint_seq = loaded.map(|(seq, _)| seq);
        report.start_lsn = start_lsn;

        // 2. replay the WAL, truncating each torn tail at its first bad
        //    frame. Scanning CONTINUES past a torn segment: LSNs are
        //    globally monotone across segments and replay is idempotent,
        //    so later segments hold durably committed events (e.g. written
        //    after a rotate-on-write-error) that must not be thrown away —
        //    only the torn suffix of the damaged segment itself is lost.
        let segment_seqs = list_by(&wal_dir, segment_seq)?;
        let mut catalog: Vec<SegmentInfo> = Vec::new();
        let mut last_lsn = start_lsn.saturating_sub(1);
        let mut on_disk_bytes = 0u64;
        for &seq in segment_seqs.iter() {
            let path = segment_path(&wal_dir, seq);
            let scan = scan_segment(&path)?;
            report.segments_scanned += 1;
            let mut info = SegmentInfo { seq, first_lsn: None, last_lsn: None };
            for (lsn, ev) in &scan.events {
                info.first_lsn.get_or_insert(*lsn);
                info.last_lsn = Some(*lsn);
                report.max_id = report.max_id.max(ev.max_id());
                if *lsn < start_lsn {
                    report.events_skipped += 1;
                } else if ev.is_broker() {
                    match broker {
                        Some(b) => {
                            b.apply_event(ev);
                            report.events_replayed += 1;
                        }
                        // store-only open: nowhere to put broker state
                        None => report.events_skipped += 1,
                    }
                } else {
                    store.apply_event(ev);
                    report.events_replayed += 1;
                }
                last_lsn = last_lsn.max(*lsn);
            }
            match &scan.end {
                ScanEnd::Clean => {
                    on_disk_bytes += scan.file_len;
                    catalog.push(info);
                }
                ScanEnd::Torn { valid_len, reason } => {
                    report.torn_bytes += scan.file_len - valid_len;
                    if *valid_len == 0 {
                        // no valid magic: a segment abandoned mid-creation
                        // (or with a destroyed header) holds nothing
                        // recoverable, and truncation can never repair it —
                        // delete it so it stops re-tearing every boot
                        log::warn!(
                            "removing wal segment {} with no valid header ({reason})",
                            path.display()
                        );
                        std::fs::remove_file(&path).with_context(|| {
                            format!("removing headerless segment {}", path.display())
                        })?;
                    } else {
                        log::warn!(
                            "wal segment {} torn at byte {valid_len} ({reason}); truncating {} bytes",
                            path.display(),
                            scan.file_len - valid_len
                        );
                        OpenOptions::new()
                            .write(true)
                            .open(&path)
                            .and_then(|f| f.set_len(*valid_len))
                            .with_context(|| {
                                format!("truncating torn tail of {}", path.display())
                            })?;
                        on_disk_bytes += valid_len;
                        catalog.push(info);
                    }
                }
            }
        }
        crate::util::advance_next_id(report.max_id);

        // 3. arm the writer on a fresh segment
        let next_seq = segment_seqs.last().copied().unwrap_or(0) + 1;
        let (wal, flusher) = Wal::create(
            &wal_dir,
            opts.segment_bytes,
            opts.fsync,
            opts.flush_idle_ms,
            last_lsn + 1,
            next_seq,
            catalog,
            on_disk_bytes,
            &metrics,
        )?;

        let persist = Persist {
            inner: Arc::new(PersistInner {
                dir: dir.to_path_buf(),
                opts,
                broker: broker.cloned(),
                carried_broker,
                wal,
                flusher: Mutex::new(Some(flusher)),
                checkpoint_mutex: Mutex::new(()),
                checkpoint_seq: AtomicU64::new(checkpoint_seqs.last().copied().unwrap_or(0)),
                last_checkpoint_lsn: AtomicU64::new(start_lsn),
                retained: Mutex::new(retained),
                metrics,
            }),
        };
        store.set_persister(persist.persister());
        if let Some(b) = broker {
            b.set_persister(persist.persister());
        }
        Ok((persist, report))
    }

    /// The hook the store logs through.
    pub fn persister(&self) -> Arc<dyn Persister> {
        Arc::new(self.inner.wal.clone())
    }

    /// Direct WAL handle (benches, tests).
    pub fn wal(&self) -> &Wal {
        &self.inner.wal
    }

    /// Block until every event logged so far is durable.
    pub fn flush(&self) {
        self.inner.wal.flush();
    }

    /// Write a durable checkpoint of `store` and prune fully-covered WAL
    /// segments. Serialized: concurrent calls queue up.
    pub fn checkpoint(&self, store: &Store) -> Result<CheckpointReport> {
        let inner = &*self.inner;
        let _gate = inner.checkpoint_mutex.lock().unwrap();
        let t0 = Instant::now();
        // everything below start_lsn must be on disk before the checkpoint
        // claims to cover it
        inner.wal.flush();
        let start_lsn = inner.wal.next_lsn();
        let snap = store.snapshot();
        // with a broker attached, the checkpoint carries snapshot format
        // v3: v2's six tables plus the broker section (topics,
        // subscriptions, backlogs, in-flight). The broker read happens
        // after the cut under the same topic locks the broker logs under,
        // so the fuzzy-cut argument covers it (DESIGN.md, "Broker").
        let snap = match (&inner.broker, &inner.carried_broker) {
            (Some(b), _) => snap.set("version", 3u64).set("broker", b.snapshot_json()),
            // store-only writer on a broker-bearing dir: pass the
            // recovered section through unchanged
            (None, Some(bj)) => snap.set("version", 3u64).set("broker", bj.clone()),
            (None, None) => snap,
        };
        let seq = inner.checkpoint_seq.fetch_add(1, Ordering::Relaxed) + 1;
        let body = Json::obj()
            .set("version", 1u64)
            .set("seq", seq)
            .set("start_lsn", start_lsn)
            .set("snapshot", snap);
        let mut text = String::new();
        body.write_to(&mut text);
        let path = checkpoint_path(&inner.dir, seq);
        let tmp = path.with_extension("json.tmp");
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(text.as_bytes())?;
            if inner.opts.fsync != FsyncMode::Never {
                f.sync_data()?;
            }
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing checkpoint {}", path.display()))?;
        if inner.opts.fsync != FsyncMode::Never {
            sync_dir(&inner.dir);
        }
        // retention first: drop all but the newest `checkpoint_keep`
        // checkpoints, then prune the WAL only to the oldest cut we still
        // retain — if this checkpoint ever fails to parse, the fallback
        // still has its full replay suffix on disk
        let prune_lsn = {
            let mut retained = inner.retained.lock().unwrap();
            retained.push((seq, start_lsn));
            while retained.len() > inner.opts.checkpoint_keep {
                retained.remove(0);
            }
            let oldest_seq = retained.first().map(|&(s, _)| s).unwrap_or(seq);
            if let Ok(seqs) = list_by(&inner.dir, checkpoint_seq_of) {
                for &old in seqs.iter().filter(|&&s| s < oldest_seq) {
                    let _ = std::fs::remove_file(checkpoint_path(&inner.dir, old));
                }
            }
            retained.iter().map(|&(_, lsn)| lsn).min().unwrap_or(start_lsn)
        };
        let segments_deleted = inner.wal.prune_below(prune_lsn);
        inner.last_checkpoint_lsn.store(start_lsn, Ordering::Relaxed);
        let report = CheckpointReport {
            seq,
            start_lsn,
            bytes: text.len() as u64,
            duration_ms: t0.elapsed().as_secs_f64() * 1e3,
            segments_deleted,
        };
        inner.metrics.counter("persist.checkpoint.count").inc();
        inner.metrics.counter("persist.checkpoint.bytes").add(report.bytes);
        inner
            .metrics
            .histogram("persist.checkpoint.duration_us")
            .observe((report.duration_ms * 1e3) as u64);
        Ok(report)
    }

    /// Live durability stats for `/api/health`.
    pub fn stats(&self) -> Json {
        let wal = &self.inner.wal;
        let next = wal.next_lsn();
        let durable = wal.durable_lsn();
        // no data-dir path here: stats land in the unauthenticated
        // /api/health response, and filesystem layout should not leak
        let mut j = Json::obj()
            .set("next_lsn", next)
            .set("durable_lsn", durable)
            .set("lag_events", next - 1 - durable.min(next - 1))
            .set("wal_segments", wal.segment_count())
            .set("wal_bytes", wal.bytes_on_disk())
            .set(
                "last_checkpoint_seq",
                self.inner.checkpoint_seq.load(Ordering::Relaxed),
            )
            .set(
                "last_checkpoint_lsn",
                self.inner.last_checkpoint_lsn.load(Ordering::Relaxed),
            );
        if let Some(e) = wal.io_error() {
            j = j.set("io_error", e);
        }
        j
    }

    /// Stop the flusher after draining the queue. Also runs on drop of the
    /// last clone.
    pub fn shutdown(&self) {
        self.inner.wal.flush();
        self.inner.wal.stop();
        if let Some(t) = self.inner.flusher.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{RequestKind, RequestStatus};
    use crate::util::clock::WallClock;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "idds-persist-{tag}-{}-{}",
            std::process::id(),
            crate::util::next_id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn opts() -> PersistOptions {
        PersistOptions {
            segment_bytes: 32 * 1024,
            fsync: FsyncMode::Never,
            checkpoint_keep: 2,
            flush_idle_ms: 5,
        }
    }

    fn store() -> Store {
        Store::new(Arc::new(WallClock::new()))
    }

    #[test]
    fn empty_dir_opens_with_nothing_to_recover() {
        let dir = tmp_dir("empty");
        let s = store();
        let (p, report) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
        assert_eq!(report.events_replayed, 0);
        assert_eq!(report.checkpoint_seq, None);
        assert_eq!(s.counts().get("requests").unwrap().as_u64(), Some(0));
        p.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn log_recover_replays_events() {
        let dir = tmp_dir("replay");
        let s = store();
        let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
        let ids: Vec<_> = (0..20)
            .map(|i| s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null))
            .collect();
        assert_eq!(s.update_requests_status(&ids[..10], RequestStatus::Transforming), 10);
        p.shutdown();

        let s2 = store();
        let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
        // 20 inserts plus the batch transition (one event per stripe the
        // batch touched, so between 1 and 10 events for 10 ids)
        assert!(
            (21..=30).contains(&report.events_replayed),
            "unexpected replay count {}",
            report.events_replayed
        );
        assert_eq!(
            s2.requests_with_status(RequestStatus::Transforming),
            s.requests_with_status(RequestStatus::Transforming)
        );
        assert_eq!(
            s2.requests_with_status(RequestStatus::New),
            s.requests_with_status(RequestStatus::New)
        );
        // ids keep flowing past everything recovered
        let fresh = s2.add_request("fresh", "u", RequestKind::Workflow, Json::Null);
        assert!(fresh > *ids.iter().max().unwrap());
        p2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_prunes_and_recovery_uses_it() {
        let dir = tmp_dir("ckpt");
        let s = store();
        let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
        let ids: Vec<_> = (0..50)
            .map(|i| s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null))
            .collect();
        let rep = p.checkpoint(&s).unwrap();
        assert!(rep.start_lsn > 50);
        // post-checkpoint writes land in the WAL suffix
        assert_eq!(s.update_requests_status(&ids, RequestStatus::Transforming), 50);
        p.shutdown();

        let s2 = store();
        let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
        assert_eq!(report.checkpoint_seq, Some(rep.seq));
        // only the post-checkpoint batch replays: one event per stripe it
        // touched, never the 50 pre-checkpoint inserts
        assert!(
            (1..=16).contains(&report.events_replayed),
            "unexpected replay count {}",
            report.events_replayed
        );
        assert_eq!(
            s2.requests_with_status(RequestStatus::Transforming).len(),
            50
        );
        p2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unusable_newest_checkpoint_falls_back_to_older() {
        let dir = tmp_dir("fallback");
        let s = store();
        let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
        for i in 0..10 {
            s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
        }
        let first = p.checkpoint(&s).unwrap();
        s.add_request("late", "u", RequestKind::Workflow, Json::Null);
        let second = p.checkpoint(&s).unwrap();
        p.shutdown();
        // newest checkpoint parses as JSON but cannot restore (bad version)
        std::fs::write(
            checkpoint_path(&dir, second.seq),
            Json::obj()
                .set("version", 1u64)
                .set("seq", second.seq)
                .set("start_lsn", second.start_lsn)
                .set("snapshot", Json::obj().set("version", 99u64))
                .to_string(),
        )
        .unwrap();

        let s2 = store();
        let (p2, report) = Persist::open(&dir, opts(), &s2, Registry::default()).unwrap();
        assert_eq!(
            report.checkpoint_seq,
            Some(first.seq),
            "recovery must fall back to the older checkpoint"
        );
        // WAL was pruned only to the oldest retained cut, so the suffix
        // after the fallback checkpoint (incl. the 'late' insert) replays
        assert_eq!(s2.counts().get("requests").unwrap().as_u64(), Some(11));
        // the unusable file was set aside, not left to fail every boot
        assert!(!checkpoint_path(&dir, second.seq).exists());
        assert!(checkpoint_path(&dir, second.seq)
            .with_extension("json.corrupt")
            .exists());
        p2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segments_after_a_torn_middle_segment_still_replay() {
        let dir = tmp_dir("tornmid");
        let s = store();
        let small = PersistOptions { segment_bytes: 2048, ..opts() };
        let (p, _) = Persist::open(&dir, small.clone(), &s, Registry::default()).unwrap();
        let ids: Vec<_> = (0..120)
            .map(|i| {
                let id = s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
                if i % 10 == 0 {
                    p.flush(); // force small flush batches → several segments
                }
                id
            })
            .collect();
        p.shutdown();
        let wal_dir = dir.join("wal");
        let mut segs = list_by(&wal_dir, super::wal::segment_seq).unwrap();
        segs.retain(|&seq| {
            std::fs::metadata(super::wal::segment_path(&wal_dir, seq))
                .map(|m| m.len() > 16)
                .unwrap_or(false)
        });
        assert!(segs.len() >= 3, "need several segments, got {}", segs.len());
        // tear the tail of a MIDDLE segment
        let victim = super::wal::segment_path(&wal_dir, segs[segs.len() / 2]);
        let len = std::fs::metadata(&victim).unwrap().len();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&victim)
            .unwrap()
            .set_len(len - 5)
            .unwrap();

        let s2 = store();
        let (p2, report) = Persist::open(&dir, small, &s2, Registry::default()).unwrap();
        assert!(report.torn_bytes > 0);
        // events after the torn segment were durably committed and must
        // survive — in particular the very last insert
        assert!(s2.get_request(*ids.last().unwrap()).is_ok());
        // only the torn frame's events are lost, not whole segments
        assert!(report.events_replayed > 110, "lost more than the torn frame");
        p2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn broker_state_round_trips_through_checkpoint_and_wal() {
        let dir = tmp_dir("broker");
        let s = store();
        let clock = crate::util::clock::SimClock::new();
        let b = Broker::new(clock.clone()).with_redelivery_timeout(10.0);
        let (p, _) =
            Persist::open_with_broker(&dir, opts(), &s, Some(&b), Registry::default()).unwrap();
        let sub = b.subscribe("idds.out");
        b.publish_many("idds.out", (0..5).map(|i| Json::from(i as u64)).collect());
        let ds = b.poll(sub, 2); // 2 in flight
        p.checkpoint(&s).unwrap();
        // the WAL suffix past the checkpoint cut
        b.publish("idds.out", Json::from(99u64));
        assert!(b.ack(sub, ds[0].id));
        p.shutdown();

        let s2 = store();
        let clock2 = crate::util::clock::SimClock::new();
        let b2 = Broker::new(clock2).with_redelivery_timeout(10.0);
        let (p2, report) =
            Persist::open_with_broker(&dir, opts(), &s2, Some(&b2), Registry::default()).unwrap();
        assert!(report.checkpoint_seq.is_some());
        assert_eq!(b.snapshot_json(), b2.snapshot_json(), "broker state must survive");
        assert_eq!(b2.backlog(sub), 5, "4 pending + 1 unacked in-flight");
        p2.shutdown();

        // a store-only open of the same dir must still work: the v3
        // snapshot's broker section is held opaquely and broker WAL
        // events are skipped
        let s3 = store();
        let (p3, r3) = Persist::open(&dir, opts(), &s3, Registry::default()).unwrap();
        assert!(r3.checkpoint_seq.is_some());
        // ... and a checkpoint it writes must carry the broker section
        // through, not destroy it
        p3.checkpoint(&s3).unwrap();
        p3.shutdown();

        let s4 = store();
        let clock4 = crate::util::clock::SimClock::new();
        let b4 = Broker::new(clock4).with_redelivery_timeout(10.0);
        let (p4, _) =
            Persist::open_with_broker(&dir, opts(), &s4, Some(&b4), Registry::default()).unwrap();
        // the carried section is the state at the ORIGINAL checkpoint cut
        // (3 pending + 2 in-flight); the suffix publish/ack predate the
        // store-only checkpoint's cut, so they do not replay on top —
        // the ack re-shows as a redelivery, per at-least-once
        assert_eq!(b4.backlog(sub), 5, "broker state must survive a store-only checkpoint");
        assert_eq!(
            b4.health_json().get("subscriptions").unwrap().as_u64(),
            Some(1),
            "the subscription itself must survive"
        );
        p4.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_retention_keeps_newest() {
        let dir = tmp_dir("keep");
        let s = store();
        let (p, _) = Persist::open(&dir, opts(), &s, Registry::default()).unwrap();
        for i in 0..4 {
            s.add_request(&format!("r{i}"), "u", RequestKind::Workflow, Json::Null);
            p.checkpoint(&s).unwrap();
        }
        let ckpts = list_by(&dir, checkpoint_seq_of).unwrap();
        assert_eq!(ckpts.len(), 2, "retention must keep checkpoint_keep files");
        assert_eq!(ckpts, vec![3, 4]);
        p.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
