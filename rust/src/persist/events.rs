//! The durability event vocabulary: every mutating store operation is
//! described by one [`PersistEvent`], logged *after* the mutation applied
//! and *while still holding the lock that made the touched ids
//! discoverable* (see the "Durability model" section of DESIGN.md for why
//! that ordering rule is what makes fuzzy checkpoints sound).
//!
//! Events record **applied effects, not requests**: a batch transition
//! logs exactly the ids that actually moved, with the timestamp the store
//! stamped on the rows, so replay never re-validates and never diverges.
//! Insert events replay as insert-if-absent and transition events as
//! last-write-wins, which makes replaying a WAL suffix that partially
//! overlaps a checkpoint converge to the live state.

use anyhow::{Context, Result};

use crate::store::{
    CollectionKind, ContentStatus, Id, MessageStatus, ProcessingStatus, RequestKind,
    RequestStatus, TransformStatus,
};
use crate::util::json::Json;

/// Sink for store mutation events. The store calls [`Persister::log`]
/// under row/index locks, so implementations must only enqueue (no I/O,
/// no store locks — the WAL's group-commit queue mutex is a leaf lock).
pub trait Persister: Send + Sync {
    fn log(&self, ev: PersistEvent);
}

/// One durable store mutation. `at` fields carry the store-stamped
/// timestamp so replayed rows get byte-identical `created_at`/`updated_at`
/// values.
#[derive(Debug, Clone, PartialEq)]
pub enum PersistEvent {
    AddRequest {
        id: Id,
        name: String,
        requester: String,
        kind: RequestKind,
        workflow: Json,
        at: f64,
    },
    RequestStatus {
        ids: Vec<Id>,
        to: RequestStatus,
        at: f64,
    },
    /// Full serialized workflow-engine state for a request (instance
    /// counters + completed set + structural hash): last-write-wins, so
    /// replaying any suffix converges on the newest state.
    RequestEngine {
        id: Id,
        engine: Json,
        at: f64,
    },
    /// Compact engine-state delta (absolute counter values for the
    /// templates that changed, newly completed instances, monotone next
    /// id — see `crate::workflow::StateUpdate::Delta`). Replay folds it
    /// into the row's full state via `crate::workflow::fold_engine_state`,
    /// which is idempotent — so per-completion WAL bytes are O(changed
    /// templates) while full state appears only in checkpoints.
    RequestEngineDelta {
        id: Id,
        delta: Json,
        at: f64,
    },
    AddTransform {
        id: Id,
        request_id: Id,
        name: String,
        work: Json,
        at: f64,
    },
    TransformStatus {
        ids: Vec<Id>,
        to: TransformStatus,
        at: f64,
    },
    TransformWork {
        id: Id,
        work: Json,
        at: f64,
    },
    /// Absolute retry count (not an increment): idempotent on replay.
    TransformRetries {
        id: Id,
        retries: u32,
    },
    AddProcessing {
        id: Id,
        transform_id: Id,
        at: f64,
    },
    ProcessingStatus {
        ids: Vec<Id>,
        to: ProcessingStatus,
        at: f64,
    },
    ProcessingWfmTask {
        id: Id,
        task: Id,
    },
    AddCollection {
        id: Id,
        transform_id: Id,
        name: String,
        kind: CollectionKind,
        at: f64,
    },
    CloseCollection {
        id: Id,
    },
    /// Bulk content registration: `(id, name, size_bytes)` triples, all
    /// starting in `ContentStatus::New`.
    AddContents {
        collection_id: Id,
        items: Vec<(Id, String, u64)>,
        at: f64,
    },
    ContentStatus {
        ids: Vec<Id>,
        to: ContentStatus,
        at: f64,
    },
    ContentDdmFile {
        id: Id,
        ddm_file: Id,
    },
    AddMessage {
        id: Id,
        topic: String,
        source_transform: Option<Id>,
        payload: Json,
        at: f64,
    },
    MessageStatus {
        ids: Vec<Id>,
        to: MessageStatus,
    },
    /// Broker events (routed to [`crate::broker::Broker::apply_event`] on
    /// recovery, not to the store): a new subscriber queue on a topic.
    BrokerSubscribe {
        sub: Id,
        topic: String,
    },
    /// A subscriber queue dropped from its topic (consumer went away).
    BrokerUnsubscribe {
        sub: Id,
    },
    /// A publish fan-out: the `(msg id, payload)` pairs enqueued, plus
    /// `subs` — the fan-out set *at publish time*. Replay must enqueue
    /// into exactly those subscribers: a snapshot taken after the cut may
    /// already contain a later-joining subscriber, and fan-out-at-publish
    /// time means it must not receive this batch.
    BrokerPublish {
        topic: String,
        subs: Vec<Id>,
        msgs: Vec<(Id, Json)>,
    },
    /// Message ids a poll moved to (or renewed in) a subscriber's
    /// in-flight set. Replay re-arms deadlines from the recovering
    /// broker's clock, so the redelivery timer restarts at recovery.
    BrokerDeliver {
        sub: Id,
        ids: Vec<Id>,
    },
    /// Message ids actually removed from a subscriber's in-flight set.
    BrokerAck {
        sub: Id,
        ids: Vec<Id>,
    },
}

fn ids_json(ids: &[Id]) -> Json {
    Json::Arr(ids.iter().map(|&i| Json::from(i)).collect())
}

fn parse_ids(j: &Json) -> Result<Vec<Id>> {
    j.get("ids")
        .and_then(|a| a.as_arr())
        .context("missing ids")?
        .iter()
        .map(|v| v.as_u64().context("non-integer id"))
        .collect()
}

fn req_u64(j: &Json, key: &str) -> Result<Id> {
    j.get(key).and_then(|v| v.as_u64()).with_context(|| format!("missing {key}"))
}

fn req_str<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.get(key).and_then(|v| v.as_str()).with_context(|| format!("missing {key}"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key).and_then(|v| v.as_f64()).with_context(|| format!("missing {key}"))
}

impl PersistEvent {
    /// Tag string used as the `op` field of the encoded form.
    pub fn op(&self) -> &'static str {
        match self {
            PersistEvent::AddRequest { .. } => "add_request",
            PersistEvent::RequestStatus { .. } => "request_status",
            PersistEvent::RequestEngine { .. } => "request_engine",
            PersistEvent::RequestEngineDelta { .. } => "request_engine_delta",
            PersistEvent::AddTransform { .. } => "add_transform",
            PersistEvent::TransformStatus { .. } => "transform_status",
            PersistEvent::TransformWork { .. } => "transform_work",
            PersistEvent::TransformRetries { .. } => "transform_retries",
            PersistEvent::AddProcessing { .. } => "add_processing",
            PersistEvent::ProcessingStatus { .. } => "processing_status",
            PersistEvent::ProcessingWfmTask { .. } => "processing_wfm_task",
            PersistEvent::AddCollection { .. } => "add_collection",
            PersistEvent::CloseCollection { .. } => "close_collection",
            PersistEvent::AddContents { .. } => "add_contents",
            PersistEvent::ContentStatus { .. } => "content_status",
            PersistEvent::ContentDdmFile { .. } => "content_ddm_file",
            PersistEvent::AddMessage { .. } => "add_message",
            PersistEvent::MessageStatus { .. } => "message_status",
            PersistEvent::BrokerSubscribe { .. } => "broker_subscribe",
            PersistEvent::BrokerUnsubscribe { .. } => "broker_unsubscribe",
            PersistEvent::BrokerPublish { .. } => "broker_publish",
            PersistEvent::BrokerDeliver { .. } => "broker_deliver",
            PersistEvent::BrokerAck { .. } => "broker_ack",
        }
    }

    /// Which logical table this event mutates — the event bus's filter
    /// and daemon-interest axis (see `persist::bus::table_mask`).
    pub fn table(&self) -> &'static str {
        match self {
            PersistEvent::AddRequest { .. }
            | PersistEvent::RequestStatus { .. }
            | PersistEvent::RequestEngine { .. }
            | PersistEvent::RequestEngineDelta { .. } => "requests",
            PersistEvent::AddTransform { .. }
            | PersistEvent::TransformStatus { .. }
            | PersistEvent::TransformWork { .. }
            | PersistEvent::TransformRetries { .. } => "transforms",
            PersistEvent::AddProcessing { .. }
            | PersistEvent::ProcessingStatus { .. }
            | PersistEvent::ProcessingWfmTask { .. } => "processings",
            PersistEvent::AddCollection { .. } | PersistEvent::CloseCollection { .. } => {
                "collections"
            }
            PersistEvent::AddContents { .. }
            | PersistEvent::ContentStatus { .. }
            | PersistEvent::ContentDdmFile { .. } => "contents",
            PersistEvent::AddMessage { .. } | PersistEvent::MessageStatus { .. } => "messages",
            PersistEvent::BrokerSubscribe { .. }
            | PersistEvent::BrokerUnsubscribe { .. }
            | PersistEvent::BrokerPublish { .. }
            | PersistEvent::BrokerDeliver { .. }
            | PersistEvent::BrokerAck { .. } => "broker",
        }
    }

    /// Whether recovery routes this event to the broker instead of the
    /// store (see `Persist::open_with_broker`).
    pub fn is_broker(&self) -> bool {
        matches!(
            self,
            PersistEvent::BrokerSubscribe { .. }
                | PersistEvent::BrokerUnsubscribe { .. }
                | PersistEvent::BrokerPublish { .. }
                | PersistEvent::BrokerDeliver { .. }
                | PersistEvent::BrokerAck { .. }
        )
    }

    /// Largest id this event introduces or references — recovery advances
    /// the process-wide id counter past the maximum over the whole log.
    pub fn max_id(&self) -> Id {
        match self {
            PersistEvent::AddRequest { id, .. }
            | PersistEvent::RequestEngine { id, .. }
            | PersistEvent::RequestEngineDelta { id, .. }
            | PersistEvent::TransformWork { id, .. }
            | PersistEvent::TransformRetries { id, .. }
            | PersistEvent::CloseCollection { id }
            | PersistEvent::AddMessage { id, .. } => *id,
            PersistEvent::AddTransform { id, request_id, .. } => (*id).max(*request_id),
            PersistEvent::AddProcessing { id, transform_id, .. } => (*id).max(*transform_id),
            PersistEvent::AddCollection { id, transform_id, .. } => (*id).max(*transform_id),
            PersistEvent::ProcessingWfmTask { id, task } => (*id).max(*task),
            PersistEvent::ContentDdmFile { id, ddm_file } => (*id).max(*ddm_file),
            PersistEvent::AddContents { collection_id, items, .. } => items
                .iter()
                .map(|(id, _, _)| *id)
                .max()
                .unwrap_or(0)
                .max(*collection_id),
            PersistEvent::RequestStatus { ids, .. }
            | PersistEvent::TransformStatus { ids, .. }
            | PersistEvent::ProcessingStatus { ids, .. }
            | PersistEvent::ContentStatus { ids, .. }
            | PersistEvent::MessageStatus { ids, .. } => ids.iter().copied().max().unwrap_or(0),
            PersistEvent::BrokerSubscribe { sub, .. }
            | PersistEvent::BrokerUnsubscribe { sub } => *sub,
            PersistEvent::BrokerPublish { subs, msgs, .. } => msgs
                .iter()
                .map(|(id, _)| *id)
                .chain(subs.iter().copied())
                .max()
                .unwrap_or(0),
            PersistEvent::BrokerDeliver { sub, ids } | PersistEvent::BrokerAck { sub, ids } => {
                ids.iter().copied().max().unwrap_or(0).max(*sub)
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let base = Json::obj().set("op", self.op());
        match self {
            PersistEvent::AddRequest { id, name, requester, kind, workflow, at } => base
                .set("id", *id)
                .set("name", name.as_str())
                .set("requester", requester.as_str())
                .set("kind", kind.as_str())
                .set("workflow", workflow.clone())
                .set("at", *at),
            PersistEvent::RequestStatus { ids, to, at } => {
                base.set("ids", ids_json(ids)).set("to", to.as_str()).set("at", *at)
            }
            PersistEvent::RequestEngine { id, engine, at } => {
                base.set("id", *id).set("engine", engine.clone()).set("at", *at)
            }
            PersistEvent::RequestEngineDelta { id, delta, at } => {
                base.set("id", *id).set("delta", delta.clone()).set("at", *at)
            }
            PersistEvent::AddTransform { id, request_id, name, work, at } => base
                .set("id", *id)
                .set("request_id", *request_id)
                .set("name", name.as_str())
                .set("work", work.clone())
                .set("at", *at),
            PersistEvent::TransformStatus { ids, to, at } => {
                base.set("ids", ids_json(ids)).set("to", to.as_str()).set("at", *at)
            }
            PersistEvent::TransformWork { id, work, at } => {
                base.set("id", *id).set("work", work.clone()).set("at", *at)
            }
            PersistEvent::TransformRetries { id, retries } => {
                base.set("id", *id).set("retries", *retries)
            }
            PersistEvent::AddProcessing { id, transform_id, at } => {
                base.set("id", *id).set("transform_id", *transform_id).set("at", *at)
            }
            PersistEvent::ProcessingStatus { ids, to, at } => {
                base.set("ids", ids_json(ids)).set("to", to.as_str()).set("at", *at)
            }
            PersistEvent::ProcessingWfmTask { id, task } => {
                base.set("id", *id).set("task", *task)
            }
            PersistEvent::AddCollection { id, transform_id, name, kind, at } => base
                .set("id", *id)
                .set("transform_id", *transform_id)
                .set("name", name.as_str())
                .set("kind", kind.as_str())
                .set("at", *at),
            PersistEvent::CloseCollection { id } => base.set("id", *id),
            PersistEvent::AddContents { collection_id, items, at } => base
                .set("collection_id", *collection_id)
                .set(
                    "items",
                    Json::Arr(
                        items
                            .iter()
                            .map(|(id, name, size)| {
                                Json::Arr(vec![
                                    Json::from(*id),
                                    Json::from(name.as_str()),
                                    Json::from(*size),
                                ])
                            })
                            .collect(),
                    ),
                )
                .set("at", *at),
            PersistEvent::ContentStatus { ids, to, at } => {
                base.set("ids", ids_json(ids)).set("to", to.as_str()).set("at", *at)
            }
            PersistEvent::ContentDdmFile { id, ddm_file } => {
                base.set("id", *id).set("ddm_file", *ddm_file)
            }
            PersistEvent::AddMessage { id, topic, source_transform, payload, at } => {
                let mut j = base
                    .set("id", *id)
                    .set("topic", topic.as_str())
                    .set("payload", payload.clone())
                    .set("at", *at);
                if let Some(src) = source_transform {
                    j = j.set("source_transform", *src);
                }
                j
            }
            PersistEvent::MessageStatus { ids, to } => {
                base.set("ids", ids_json(ids)).set("to", to.as_str())
            }
            PersistEvent::BrokerSubscribe { sub, topic } => {
                base.set("sub", *sub).set("topic", topic.as_str())
            }
            PersistEvent::BrokerUnsubscribe { sub } => base.set("sub", *sub),
            PersistEvent::BrokerPublish { topic, subs, msgs } => base
                .set("topic", topic.as_str())
                .set("subs", Json::Arr(subs.iter().map(|&s| Json::from(s)).collect()))
                .set(
                    "msgs",
                    Json::Arr(
                        msgs.iter()
                            .map(|(id, payload)| Json::Arr(vec![Json::from(*id), payload.clone()]))
                            .collect(),
                    ),
                ),
            PersistEvent::BrokerDeliver { sub, ids } => {
                base.set("sub", *sub).set("ids", ids_json(ids))
            }
            PersistEvent::BrokerAck { sub, ids } => {
                base.set("sub", *sub).set("ids", ids_json(ids))
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<PersistEvent> {
        let op = req_str(j, "op")?;
        Ok(match op {
            "add_request" => PersistEvent::AddRequest {
                id: req_u64(j, "id")?,
                name: req_str(j, "name")?.to_string(),
                requester: req_str(j, "requester")?.to_string(),
                kind: RequestKind::parse(req_str(j, "kind")?).context("bad request kind")?,
                workflow: j.get("workflow").cloned().unwrap_or(Json::Null),
                at: req_f64(j, "at")?,
            },
            "request_status" => PersistEvent::RequestStatus {
                ids: parse_ids(j)?,
                to: RequestStatus::parse(req_str(j, "to")?).context("bad request status")?,
                at: req_f64(j, "at")?,
            },
            "request_engine" => PersistEvent::RequestEngine {
                id: req_u64(j, "id")?,
                engine: j.get("engine").cloned().unwrap_or(Json::Null),
                at: req_f64(j, "at")?,
            },
            "request_engine_delta" => PersistEvent::RequestEngineDelta {
                id: req_u64(j, "id")?,
                delta: j.get("delta").cloned().unwrap_or(Json::Null),
                at: req_f64(j, "at")?,
            },
            "add_transform" => PersistEvent::AddTransform {
                id: req_u64(j, "id")?,
                request_id: req_u64(j, "request_id")?,
                name: req_str(j, "name")?.to_string(),
                work: j.get("work").cloned().unwrap_or(Json::Null),
                at: req_f64(j, "at")?,
            },
            "transform_status" => PersistEvent::TransformStatus {
                ids: parse_ids(j)?,
                to: TransformStatus::parse(req_str(j, "to")?).context("bad transform status")?,
                at: req_f64(j, "at")?,
            },
            "transform_work" => PersistEvent::TransformWork {
                id: req_u64(j, "id")?,
                work: j.get("work").cloned().unwrap_or(Json::Null),
                at: req_f64(j, "at")?,
            },
            "transform_retries" => PersistEvent::TransformRetries {
                id: req_u64(j, "id")?,
                retries: req_u64(j, "retries")? as u32,
            },
            "add_processing" => PersistEvent::AddProcessing {
                id: req_u64(j, "id")?,
                transform_id: req_u64(j, "transform_id")?,
                at: req_f64(j, "at")?,
            },
            "processing_status" => PersistEvent::ProcessingStatus {
                ids: parse_ids(j)?,
                to: ProcessingStatus::parse(req_str(j, "to")?).context("bad processing status")?,
                at: req_f64(j, "at")?,
            },
            "processing_wfm_task" => PersistEvent::ProcessingWfmTask {
                id: req_u64(j, "id")?,
                task: req_u64(j, "task")?,
            },
            "add_collection" => PersistEvent::AddCollection {
                id: req_u64(j, "id")?,
                transform_id: req_u64(j, "transform_id")?,
                name: req_str(j, "name")?.to_string(),
                kind: CollectionKind::parse(req_str(j, "kind")?).context("bad collection kind")?,
                at: req_f64(j, "at")?,
            },
            "close_collection" => PersistEvent::CloseCollection { id: req_u64(j, "id")? },
            "add_contents" => PersistEvent::AddContents {
                collection_id: req_u64(j, "collection_id")?,
                items: j
                    .get("items")
                    .and_then(|a| a.as_arr())
                    .context("missing items")?
                    .iter()
                    .map(|it| {
                        let t = it.as_arr().context("item not a triple")?;
                        anyhow::ensure!(t.len() == 3, "item not a triple");
                        Ok((
                            t[0].as_u64().context("item id")?,
                            t[1].as_str().context("item name")?.to_string(),
                            t[2].as_u64().context("item size")?,
                        ))
                    })
                    .collect::<Result<Vec<_>>>()?,
                at: req_f64(j, "at")?,
            },
            "content_status" => PersistEvent::ContentStatus {
                ids: parse_ids(j)?,
                to: ContentStatus::parse(req_str(j, "to")?).context("bad content status")?,
                at: req_f64(j, "at")?,
            },
            "content_ddm_file" => PersistEvent::ContentDdmFile {
                id: req_u64(j, "id")?,
                ddm_file: req_u64(j, "ddm_file")?,
            },
            "add_message" => PersistEvent::AddMessage {
                id: req_u64(j, "id")?,
                topic: req_str(j, "topic")?.to_string(),
                source_transform: j.get("source_transform").and_then(|v| v.as_u64()),
                payload: j.get("payload").cloned().unwrap_or(Json::Null),
                at: req_f64(j, "at")?,
            },
            "message_status" => PersistEvent::MessageStatus {
                ids: parse_ids(j)?,
                to: MessageStatus::parse(req_str(j, "to")?).context("bad message status")?,
            },
            "broker_subscribe" => PersistEvent::BrokerSubscribe {
                sub: req_u64(j, "sub")?,
                topic: req_str(j, "topic")?.to_string(),
            },
            "broker_unsubscribe" => PersistEvent::BrokerUnsubscribe { sub: req_u64(j, "sub")? },
            "broker_publish" => PersistEvent::BrokerPublish {
                topic: req_str(j, "topic")?.to_string(),
                subs: j
                    .get("subs")
                    .and_then(|a| a.as_arr())
                    .context("missing subs")?
                    .iter()
                    .map(|v| v.as_u64().context("non-integer sub"))
                    .collect::<Result<Vec<_>>>()?,
                msgs: j
                    .get("msgs")
                    .and_then(|a| a.as_arr())
                    .context("missing msgs")?
                    .iter()
                    .map(|it| {
                        let pair = it.as_arr().context("msg not a pair")?;
                        anyhow::ensure!(pair.len() == 2, "msg not a pair");
                        Ok((pair[0].as_u64().context("msg id")?, pair[1].clone()))
                    })
                    .collect::<Result<Vec<_>>>()?,
            },
            "broker_deliver" => PersistEvent::BrokerDeliver {
                sub: req_u64(j, "sub")?,
                ids: parse_ids(j)?,
            },
            "broker_ack" => PersistEvent::BrokerAck {
                sub: req_u64(j, "sub")?,
                ids: parse_ids(j)?,
            },
            other => anyhow::bail!("unknown persist op '{other}'"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(ev: PersistEvent) {
        let j = ev.to_json();
        let text = j.to_string();
        let back = PersistEvent::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(ev, back, "roundtrip via {text}");
    }

    #[test]
    fn every_variant_roundtrips() {
        roundtrip(PersistEvent::AddRequest {
            id: 7,
            name: "camp".into(),
            requester: "alice".into(),
            kind: RequestKind::DataCarousel,
            workflow: Json::obj().set("w", 1u64),
            at: 1.5,
        });
        roundtrip(PersistEvent::RequestStatus {
            ids: vec![1, 2, 3],
            to: RequestStatus::Transforming,
            at: 2.0,
        });
        roundtrip(PersistEvent::RequestEngine {
            id: 7,
            engine: Json::obj()
                .set("hash", "00deadbeef001234")
                .set("instances", Json::obj().set("a", 2u64)),
            at: 2.5,
        });
        roundtrip(PersistEvent::RequestEngineDelta {
            id: 7,
            delta: Json::obj()
                .set("instances", Json::obj().set("a", 3u64))
                .set("completed", Json::Arr(vec![Json::from(2u64)]))
                .set("next_instance", 4u64),
            at: 2.75,
        });
        roundtrip(PersistEvent::AddTransform {
            id: 8,
            request_id: 7,
            name: "w#0".into(),
            work: Json::Null,
            at: 0.0,
        });
        roundtrip(PersistEvent::TransformStatus {
            ids: vec![8],
            to: TransformStatus::Running,
            at: 3.0,
        });
        roundtrip(PersistEvent::TransformWork { id: 8, work: Json::obj().set("k", "v"), at: 4.0 });
        roundtrip(PersistEvent::TransformRetries { id: 8, retries: 3 });
        roundtrip(PersistEvent::AddProcessing { id: 9, transform_id: 8, at: 5.0 });
        roundtrip(PersistEvent::ProcessingStatus {
            ids: vec![9],
            to: ProcessingStatus::Finished,
            at: 6.0,
        });
        roundtrip(PersistEvent::ProcessingWfmTask { id: 9, task: 77 });
        roundtrip(PersistEvent::AddCollection {
            id: 10,
            transform_id: 8,
            name: "in".into(),
            kind: CollectionKind::Input,
            at: 7.0,
        });
        roundtrip(PersistEvent::CloseCollection { id: 10 });
        roundtrip(PersistEvent::AddContents {
            collection_id: 10,
            items: vec![(11, "f0".into(), 100), (12, "f1".into(), 200)],
            at: 8.0,
        });
        roundtrip(PersistEvent::ContentStatus {
            ids: vec![11, 12],
            to: ContentStatus::Staging,
            at: 9.0,
        });
        roundtrip(PersistEvent::ContentDdmFile { id: 11, ddm_file: 500 });
        roundtrip(PersistEvent::AddMessage {
            id: 13,
            topic: "idds.work.finished".into(),
            source_transform: Some(8),
            payload: Json::obj().set("failed", false),
            at: 10.0,
        });
        roundtrip(PersistEvent::AddMessage {
            id: 14,
            topic: "t".into(),
            source_transform: None,
            payload: Json::Null,
            at: 11.0,
        });
        roundtrip(PersistEvent::MessageStatus { ids: vec![13, 14], to: MessageStatus::Delivered });
        roundtrip(PersistEvent::BrokerSubscribe { sub: 21, topic: "idds.out".into() });
        roundtrip(PersistEvent::BrokerUnsubscribe { sub: 21 });
        roundtrip(PersistEvent::BrokerPublish {
            topic: "idds.out".into(),
            subs: vec![21],
            msgs: vec![(22, Json::obj().set("f", "x")), (23, Json::Null)],
        });
        roundtrip(PersistEvent::BrokerDeliver { sub: 21, ids: vec![22, 23] });
        roundtrip(PersistEvent::BrokerAck { sub: 21, ids: vec![22] });
    }

    #[test]
    fn broker_events_are_flagged_and_cover_ids() {
        let pubs = PersistEvent::BrokerPublish {
            topic: "t".into(),
            subs: vec![40],
            msgs: vec![(5, Json::Null), (9, Json::Null)],
        };
        assert!(pubs.is_broker());
        assert_eq!(pubs.max_id(), 40, "fan-out sub ids count too");
        let deliver = PersistEvent::BrokerDeliver { sub: 40, ids: vec![5, 9] };
        assert!(deliver.is_broker());
        assert_eq!(deliver.max_id(), 40);
        assert!(PersistEvent::BrokerUnsubscribe { sub: 7 }.is_broker());
        assert!(!PersistEvent::CloseCollection { id: 3 }.is_broker());
    }

    #[test]
    fn max_id_covers_introduced_ids() {
        let ev = PersistEvent::AddContents {
            collection_id: 4,
            items: vec![(90, "a".into(), 1), (95, "b".into(), 1)],
            at: 0.0,
        };
        assert_eq!(ev.max_id(), 95);
        assert_eq!(PersistEvent::CloseCollection { id: 3 }.max_id(), 3);
        assert_eq!(
            PersistEvent::MessageStatus { ids: vec![], to: MessageStatus::Acked }.max_id(),
            0
        );
    }

    #[test]
    fn unknown_op_rejected() {
        assert!(PersistEvent::from_json(&Json::obj().set("op", "nope")).is_err());
    }
}
