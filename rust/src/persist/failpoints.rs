//! Named fault-injection points for the durability stack.
//!
//! Real disk faults are the rarest inputs the persist layer sees, and the
//! sticky `io_error` path, the rotate-on-write-error recovery, and the
//! `.corrupt` checkpoint sidelining all exist for exactly those inputs.
//! A failpoint makes them drivable on demand: tests (or an operator via
//! `persist.failpoints`) arm a named site and the next time execution
//! passes it, it reports an injected `io::Error` instead of doing the
//! real syscall's error path by accident of hardware.
//!
//! Sites wired in this crate:
//!
//! | name                | effect when armed                               |
//! |---------------------|-------------------------------------------------|
//! | `wal.write`         | `Wal::flush_batch` write fails (batch lost,     |
//! |                     | segment rotates, `io_error` goes sticky)        |
//! | `wal.fsync`         | group-commit fsync fails (bytes are in the      |
//! |                     | file, durability unacknowledged — the degraded- |
//! |                     | write path: `sync_submit` must answer 503)      |
//! | `checkpoint.write`  | checkpoint tmp-file write fails                 |
//! | `checkpoint.fsync`  | checkpoint tmp-file fsync fails                 |
//! | `checkpoint.rename` | the atomic publish rename fails (tmp swept at   |
//! |                     | next open; dirty sets restored)                 |
//! | `checkpoint.corrupt`| the checkpoint publishes *successfully* but     |
//! |                     | with a truncated body — recovery must sideline  |
//! |                     | it as `.corrupt` and fall back                  |
//! | `worker.complete`   | a worker process (`idds work` / `worker::run`)  |
//! |                     | drops a finished Work instead of reporting it — |
//! |                     | crash-in-the-gap between doing and reporting;   |
//! |                     | the lease must expire and the Work redeliver    |
//!
//! The disarmed fast path is a single relaxed atomic load, so the hooks
//! are always compiled in (no test-only cfg split to drift) and cost
//! nothing in production. Arming is process-global: tests that arm sites
//! must serialize among themselves (see `tests/failpoints.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use anyhow::{bail, Result};

/// Fast path: one relaxed load when nothing is armed anywhere.
static ARMED: AtomicBool = AtomicBool::new(false);

/// Remaining trigger count per armed site; `None` = fail every pass.
fn registry() -> &'static Mutex<HashMap<String, Option<u64>>> {
    static REG: OnceLock<Mutex<HashMap<String, Option<u64>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Arm `name` to fail `times` passes (`None` = until disarmed).
pub fn arm(name: &str, times: Option<u64>) {
    let mut reg = registry().lock().unwrap();
    reg.insert(name.to_string(), times);
    ARMED.store(true, Ordering::Release);
}

pub fn disarm(name: &str) {
    let mut reg = registry().lock().unwrap();
    reg.remove(name);
    if reg.is_empty() {
        ARMED.store(false, Ordering::Release);
    }
}

pub fn disarm_all() {
    let mut reg = registry().lock().unwrap();
    reg.clear();
    ARMED.store(false, Ordering::Release);
}

/// Parse and arm a `persist.failpoints` spec: comma-separated
/// `site=always` or `site=<n>` entries, e.g.
/// `wal.fsync=always,checkpoint.rename=2`.
pub fn arm_from_spec(spec: &str) -> Result<()> {
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let Some((name, mode)) = entry.split_once('=') else {
            bail!("failpoint entry '{entry}' is not site=always|<count>");
        };
        let times = match mode.trim() {
            "always" => None,
            n => Some(n.parse::<u64>().map_err(|_| {
                anyhow::anyhow!("failpoint count '{n}' in '{entry}' is not a number")
            })?),
        };
        arm(name.trim(), times);
    }
    Ok(())
}

/// Called at each site: `Ok(())` when disarmed, an injected error while
/// the site's trigger budget lasts. A counted site disarms itself after
/// its last trigger.
pub fn check(name: &str) -> std::io::Result<()> {
    if !ARMED.load(Ordering::Acquire) {
        return Ok(());
    }
    let mut reg = registry().lock().unwrap();
    let fire = match reg.get_mut(name) {
        None => false,
        Some(None) => true,
        Some(Some(left)) => {
            if *left > 0 {
                *left -= 1;
                if *left == 0 {
                    reg.remove(name);
                    if reg.is_empty() {
                        ARMED.store(false, Ordering::Release);
                    }
                }
                true
            } else {
                false
            }
        }
    };
    if fire {
        Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            format!("injected failpoint: {name}"),
        ))
    } else {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; this module's tests serialize on
    // one mutex so parallel test threads cannot see each other's arms.
    fn serial() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn disarmed_is_ok() {
        let _g = serial();
        disarm_all();
        assert!(check("never.armed").is_ok());
    }

    #[test]
    fn counted_site_fires_then_self_disarms() {
        let _g = serial();
        disarm_all();
        arm("unit.counted", Some(2));
        assert!(check("unit.counted").is_err());
        assert!(check("unit.counted").is_err());
        assert!(check("unit.counted").is_ok(), "budget exhausted → disarmed");
        assert!(!ARMED.load(Ordering::Acquire), "last site clears the fast path");
    }

    #[test]
    fn always_site_fires_until_disarmed() {
        let _g = serial();
        disarm_all();
        arm("unit.always", None);
        for _ in 0..5 {
            assert!(check("unit.always").is_err());
        }
        // other sites stay clean
        assert!(check("unit.other").is_ok());
        disarm("unit.always");
        assert!(check("unit.always").is_ok());
    }

    #[test]
    fn spec_parsing() {
        let _g = serial();
        disarm_all();
        arm_from_spec("a.b=always, c.d=1").unwrap();
        assert!(check("a.b").is_err());
        assert!(check("c.d").is_err());
        assert!(check("c.d").is_ok());
        assert!(arm_from_spec("nope").is_err());
        assert!(arm_from_spec("x=notanumber").is_err());
        disarm_all();
    }
}
