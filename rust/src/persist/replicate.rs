//! WAL-shipping replication: warm standby + fenced failover.
//!
//! Production iDDS survives head-node loss by keeping all state in an HA
//! relational database; this reproduction's head owns its state, so a
//! second copy needs log shipping. The design (DESIGN.md, "Replication"):
//!
//! * **Ship** — the primary serves `GET /api/replication/wal?from_lsn=N`:
//!   frames re-encoded from its on-disk segments (closed segments first,
//!   then the live one), capped at the *durable* LSN read before any file
//!   is touched, chunked by `max_bytes`. The body is pure WAL framing
//!   (`len|crc|lsn|event-json`), so the standby runs the same CRC check a
//!   local recovery would.
//! * **Fold** — the standby pulls continuously, applies each event through
//!   the idempotent replay path ([`crate::store::Store::apply_event`] /
//!   [`crate::broker::Broker::apply_event`]), *then* appends the frame to
//!   its own WAL via [`Wal::append_shipped`], preserving the primary's
//!   LSNs. Apply-before-append keeps the fuzzy-checkpoint-cut invariant
//!   (mark-dirty happens before the standby's cut can pass the LSN), so
//!   standby checkpoints are safe; a crash between the two just re-pulls.
//! * **Fence** — a cluster epoch lives in an `EPOCH` file next to the
//!   seed's LOCK. Every ship request carries the caller's epoch; seeing a
//!   higher one fences the node (sticky `FENCED` marker + [`Wal::fence`],
//!   which drops all further appends). `POST /api/admin/promote` bumps the
//!   standby's epoch, attaches its WAL for writes, and best-effort fences
//!   the old primary over REST — so two heads never both write: the old
//!   primary is fenced on its next ship/serve touch even if the fence
//!   POST never arrived, because its epoch is now stale everywhere.
//! * **Bootstrap** — a fresh standby asking for history the primary has
//!   pruned gets `410 Gone` and falls back to
//!   `GET /api/replication/snapshot` (a full store+broker snapshot cut at
//!   a flushed LSN), installs it, writes a local base checkpoint at that
//!   cut, and resumes pulling frames from there.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::broker::Broker;
use crate::config::Config;
use crate::metrics::Registry;
use crate::rest::http::{http_request_full, HttpResponse};
use crate::store::Store;
use crate::util::json::{parse, Json};

use super::wal::{decode_frames, encode_frame, scan_segment, segment_path};
use super::{Persist, Wal};

/// Request/response headers carrying the fencing epoch and watermarks.
pub const H_EPOCH: &str = "X-IDDS-Epoch";
pub const H_PEER_EPOCH: &str = "X-IDDS-Peer-Epoch";
pub const H_DURABLE_LSN: &str = "X-IDDS-Durable-LSN";
pub const H_OLDEST_LSN: &str = "X-IDDS-Oldest-LSN";

// ---------------------------------------------------------------------------
// Epoch + fence marker files (next to the data dir's LOCK)
// ---------------------------------------------------------------------------

fn epoch_path(dir: &Path) -> PathBuf {
    dir.join("EPOCH")
}

fn fenced_path(dir: &Path) -> PathBuf {
    dir.join("FENCED")
}

/// Read the persisted cluster epoch; 0 when the file is absent (a dir
/// that has never participated in a cluster).
pub fn read_epoch(dir: &Path) -> u64 {
    std::fs::read_to_string(epoch_path(dir))
        .ok()
        .and_then(|t| t.trim().parse().ok())
        .unwrap_or(0)
}

/// Persist the cluster epoch (tmp + rename + dir sync, like checkpoints).
pub fn write_epoch(dir: &Path, epoch: u64) -> Result<()> {
    let tmp = dir.join("EPOCH.tmp");
    std::fs::write(&tmp, epoch.to_string())
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, epoch_path(dir)).context("publishing EPOCH")?;
    super::wal::sync_dir(dir);
    Ok(())
}

/// The epoch that fenced this dir, if a FENCED marker exists. A fenced
/// data dir must not serve again without operator intervention — its log
/// may have diverged from the promoted timeline.
pub fn read_fenced(dir: &Path) -> Option<u64> {
    std::fs::read_to_string(fenced_path(dir))
        .ok()
        .map(|t| t.trim().parse().unwrap_or(0))
}

fn write_fenced(dir: &Path, epoch: u64) {
    if let Err(e) = std::fs::write(fenced_path(dir), epoch.to_string()) {
        log::error!("could not persist FENCED marker in {}: {e}", dir.display());
    }
    super::wal::sync_dir(dir);
}

// ---------------------------------------------------------------------------
// Cluster state
// ---------------------------------------------------------------------------

/// Shared replication/fencing state, attached to the REST server. Present
/// on every node: a plain primary carries role + epoch, a standby also
/// tracks its pull position and lag.
pub struct ClusterState {
    data_dir: Option<PathBuf>,
    /// The primary this node replicates from (empty for a primary).
    primary_addr: String,
    replica: AtomicBool,
    epoch: AtomicU64,
    fenced: AtomicBool,
    /// Latched by promote: the serve loop watches this to start daemons.
    promoted: AtomicBool,
    /// Last primary LSN applied to the local store/broker.
    applied_lsn: AtomicU64,
    /// Primary's durable LSN as of the last successful pull.
    primary_durable_lsn: AtomicU64,
    pulls: AtomicU64,
    pull_errors: AtomicU64,
    last_error: Mutex<Option<String>>,
    /// Serializes EPOCH-file writes: `epoch` itself is monotone via
    /// `fetch_max`, but two racing persists could otherwise interleave so
    /// the file ends up holding the smaller value (re-offered after a
    /// restart). Writers take this lock and re-read the in-memory epoch
    /// under it, so the file always ends at the newest adopted value.
    epoch_file: Mutex<()>,
}

impl ClusterState {
    pub fn primary(data_dir: Option<PathBuf>, epoch: u64) -> Arc<ClusterState> {
        Arc::new(ClusterState {
            data_dir,
            primary_addr: String::new(),
            replica: AtomicBool::new(false),
            epoch: AtomicU64::new(epoch.max(1)),
            fenced: AtomicBool::new(false),
            promoted: AtomicBool::new(false),
            applied_lsn: AtomicU64::new(0),
            primary_durable_lsn: AtomicU64::new(0),
            pulls: AtomicU64::new(0),
            pull_errors: AtomicU64::new(0),
            last_error: Mutex::new(None),
            epoch_file: Mutex::new(()),
        })
    }

    pub fn replica(data_dir: PathBuf, primary_addr: &str, epoch: u64) -> Arc<ClusterState> {
        Arc::new(ClusterState {
            data_dir: Some(data_dir),
            primary_addr: primary_addr.to_string(),
            replica: AtomicBool::new(true),
            epoch: AtomicU64::new(epoch),
            fenced: AtomicBool::new(false),
            promoted: AtomicBool::new(false),
            applied_lsn: AtomicU64::new(0),
            primary_durable_lsn: AtomicU64::new(0),
            pulls: AtomicU64::new(0),
            pull_errors: AtomicU64::new(0),
            last_error: Mutex::new(None),
            epoch_file: Mutex::new(()),
        })
    }

    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    pub fn is_replica(&self) -> bool {
        self.replica.load(Ordering::Acquire)
    }

    pub fn is_fenced(&self) -> bool {
        self.fenced.load(Ordering::Acquire)
    }

    /// True once promote completed — `idds serve --replica-of` polls this
    /// to start the daemon host on the new primary.
    pub fn is_promoted(&self) -> bool {
        self.promoted.load(Ordering::Acquire)
    }

    pub fn applied_lsn(&self) -> u64 {
        self.applied_lsn.load(Ordering::Acquire)
    }

    /// Replication lag in LSNs (primary durable − locally applied).
    pub fn lag_lsn(&self) -> u64 {
        self.primary_durable_lsn
            .load(Ordering::Acquire)
            .saturating_sub(self.applied_lsn.load(Ordering::Acquire))
    }

    /// Adopt a (higher) epoch learned from the primary, persisting it so a
    /// restarted standby never re-offers a stale epoch.
    fn adopt_epoch(&self, epoch: u64) {
        let prev = self.epoch.fetch_max(epoch, Ordering::AcqRel);
        if epoch > prev {
            if let Some(dir) = &self.data_dir {
                // Persist under the file lock, re-reading the in-memory
                // epoch: a concurrent adopter that won the fetch_max race
                // with a larger value must not have its file write
                // overwritten by ours landing later with the smaller one.
                let _g = self.epoch_file.lock().unwrap();
                let current = self.epoch.load(Ordering::Acquire);
                if let Err(e) = write_epoch(dir, current) {
                    log::error!("could not persist adopted epoch {current}: {e}");
                }
            }
        }
    }

    fn note_error(&self, e: &str) {
        self.pull_errors.fetch_add(1, Ordering::Relaxed);
        *self.last_error.lock().unwrap() = Some(e.to_string());
    }

    /// The `replication` section of `/api/health`.
    pub fn health_json(&self) -> Json {
        let mut j = Json::obj()
            .set("role", if self.is_replica() { "replica" } else { "primary" })
            .set("epoch", self.epoch())
            .set("fenced", self.is_fenced());
        if self.is_replica() || self.is_promoted() {
            j = j
                .set("primary", self.primary_addr.as_str())
                .set("applied_lsn", self.applied_lsn())
                .set(
                    "primary_durable_lsn",
                    self.primary_durable_lsn.load(Ordering::Acquire),
                )
                .set("lag_lsn", self.lag_lsn())
                .set("pulls", self.pulls.load(Ordering::Relaxed))
                .set("pull_errors", self.pull_errors.load(Ordering::Relaxed))
                .set("promoted", self.is_promoted());
            if let Some(e) = self.last_error.lock().unwrap().clone() {
                j = j.set("last_error", e);
            }
        }
        j
    }
}

/// Fence this node: sticky marker on disk, WAL refuses further appends,
/// cluster state reports it. Called when a ship/fence request proves a
/// higher epoch exists.
pub fn fence_node(cluster: &ClusterState, wal: Option<&Wal>, their_epoch: u64) {
    cluster.fenced.store(true, Ordering::Release);
    if let Some(w) = wal {
        w.fence();
    }
    if let Some(dir) = &cluster.data_dir {
        write_fenced(dir, their_epoch);
    }
    log::error!(
        "node fenced: epoch {} superseded by {their_epoch}; all writes refused",
        cluster.epoch()
    );
}

// ---------------------------------------------------------------------------
// Ship side (primary)
// ---------------------------------------------------------------------------

/// What the ship endpoint returns for one pull.
pub enum ShipReply {
    /// Re-encoded frames `from_lsn ..= last_lsn` (empty when caught up).
    Batch { frames: Vec<u8>, count: usize, last_lsn: u64, durable_lsn: u64 },
    /// History before `oldest_lsn` was pruned by checkpoints — the caller
    /// must bootstrap from a snapshot instead.
    Gone { oldest_lsn: u64, durable_lsn: u64 },
}

/// Collect up to `max_bytes` of durable frames starting at `from_lsn`.
///
/// The durable mark is read *before* any file: it only advances after the
/// flusher's `write_all` returns, so every frame at or below it is fully
/// present in the segment bytes we then read — a concurrent flush can at
/// worst add a torn tail of *newer* frames, which the segment scanner
/// already stops at. At least one frame is returned even if it alone
/// exceeds `max_bytes`, so a single oversized event cannot wedge a pull.
pub fn ship_frames(wal: &Wal, from_lsn: u64, max_bytes: usize) -> Result<ShipReply> {
    let durable_lsn = wal.durable_lsn();
    let (dir, segs) = wal.catalog();
    let oldest_lsn = segs
        .iter()
        .filter_map(|s| s.first_lsn)
        .min()
        .unwrap_or(durable_lsn + 1);
    if from_lsn < oldest_lsn {
        return Ok(ShipReply::Gone { oldest_lsn, durable_lsn });
    }
    let mut frames = Vec::new();
    let mut count = 0usize;
    let mut last_lsn = 0u64;
    let mut text = String::new();
    'segments: for seg in &segs {
        if let Some(last) = seg.last_lsn {
            if last < from_lsn {
                continue; // fully below the requested window
            }
        }
        // This segment's catalog entry may hold frames >= from_lsn, so a
        // scan failure here must NOT be skipped: a checkpoint prune racing
        // this read can delete the file, and silently resuming at a later
        // segment would ship a batch with a hole the standby would apply
        // over — permanent divergence. Fail the pull instead; the standby
        // retries against a fresh catalog, which reports a real prune as
        // an honest 410 Gone (from_lsn < the new oldest_lsn).
        let scan = scan_segment(&segment_path(&dir, seg.seq)).with_context(|| {
            format!(
                "scanning wal segment {} for ship (pruned or unreadable mid-batch)",
                seg.seq
            )
        })?;
        for (lsn, ev) in &scan.events {
            if *lsn < from_lsn {
                continue;
            }
            if *lsn > durable_lsn {
                break 'segments; // LSNs are globally monotone across segments
            }
            text.clear();
            ev.to_json().write_to(&mut text);
            encode_frame(*lsn, &text, &mut frames);
            count += 1;
            last_lsn = *lsn;
            if frames.len() >= max_bytes {
                break 'segments;
            }
        }
    }
    Ok(ShipReply::Batch { frames, count, last_lsn, durable_lsn })
}

// ---------------------------------------------------------------------------
// Pull side (standby)
// ---------------------------------------------------------------------------

/// Standby tunables, resolved from the `replication.*` config keys.
#[derive(Debug, Clone)]
pub struct ReplicationOptions {
    /// Idle poll interval when the last pull returned no frames.
    pub poll_interval_ms: u64,
    /// Per-pull byte cap passed to the ship endpoint.
    pub batch_bytes: u64,
    /// Backoff after a failed pull (primary down, transfer error).
    pub retry_ms: u64,
}

impl Default for ReplicationOptions {
    fn default() -> Self {
        ReplicationOptions { poll_interval_ms: 50, batch_bytes: 1 << 20, retry_ms: 200 }
    }
}

impl ReplicationOptions {
    pub fn from_config(cfg: &Config) -> Result<Self> {
        Ok(ReplicationOptions {
            poll_interval_ms: cfg.u64("replication.poll_interval_ms")?.max(1),
            batch_bytes: cfg.u64("replication.batch_bytes")?.max(4096),
            retry_ms: cfg.u64("replication.retry_ms")?.max(1),
        })
    }
}

struct ReplicaShared {
    store: Store,
    broker: Broker,
    persist: Persist,
    cluster: Arc<ClusterState>,
    token: String,
    opts: ReplicationOptions,
    metrics: Registry,
    stop: AtomicBool,
    /// When the primary runs in the same process (tests, embedded
    /// topologies), its event bus signal turns the caught-up idle sleep
    /// into a wakeup: new durable frames pull immediately instead of
    /// waiting out `poll_interval_ms`. Over the network this is `None`
    /// and the loop falls back to the plain interval poll.
    wake: Option<Arc<super::bus::WakeSignal>>,
}

/// A running standby: the pull thread plus the promote entry point.
pub struct Replica {
    shared: Arc<ReplicaShared>,
    puller: Mutex<Option<std::thread::JoinHandle<()>>>,
    promote_gate: Mutex<()>,
}

impl Replica {
    /// Spawn the pull loop. `persist` must come from
    /// [`Persist::open_replica`] (WAL not yet attached to the store — the
    /// standby's only writers are this thread and, after promote, the
    /// daemons).
    pub fn start(
        store: Store,
        broker: Broker,
        persist: Persist,
        cluster: Arc<ClusterState>,
        token: &str,
        opts: ReplicationOptions,
        metrics: Registry,
    ) -> Result<Arc<Replica>> {
        Self::start_with_wake(store, broker, persist, cluster, token, opts, metrics, None)
    }

    /// Like [`Replica::start`], with an optional wake signal from the
    /// *primary's* event bus (in-process topologies only): the caught-up
    /// idle sleep becomes signal-driven, so freshly durable frames pull
    /// immediately instead of waiting out the poll interval.
    #[allow(clippy::too_many_arguments)]
    pub fn start_with_wake(
        store: Store,
        broker: Broker,
        persist: Persist,
        cluster: Arc<ClusterState>,
        token: &str,
        opts: ReplicationOptions,
        metrics: Registry,
        wake: Option<Arc<super::bus::WakeSignal>>,
    ) -> Result<Arc<Replica>> {
        // resume where the local WAL ends: recovery replayed it into the
        // store, so the first pull asks for the next primary LSN
        let resume = persist.wal().next_lsn().saturating_sub(1);
        cluster.applied_lsn.store(resume, Ordering::Release);
        let shared = Arc::new(ReplicaShared {
            store,
            broker,
            persist,
            cluster,
            token: token.to_string(),
            opts,
            metrics,
            stop: AtomicBool::new(false),
            wake,
        });
        let replica = Arc::new(Replica {
            shared: Arc::clone(&shared),
            puller: Mutex::new(None),
            promote_gate: Mutex::new(()),
        });
        let thread = std::thread::Builder::new()
            .name("idds-replica-pull".into())
            .spawn(move || pull_loop(&shared))
            .context("spawning replica pull thread")?;
        *replica.puller.lock().unwrap() = Some(thread);
        Ok(replica)
    }

    pub fn cluster(&self) -> Arc<ClusterState> {
        Arc::clone(&self.shared.cluster)
    }

    /// Stop pulling (graceful standby shutdown; promote calls this too).
    pub fn stop(&self) {
        self.shared.stop.store(true, Ordering::Release);
        if let Some(w) = &self.shared.wake {
            w.notify(); // interrupt a signal-driven idle wait
        }
        if let Some(t) = self.puller.lock().unwrap().take() {
            let _ = t.join();
        }
    }

    /// Take over as primary: stop the pull loop, drain shipped frames to
    /// local disk, bump + persist the cluster epoch, attach the WAL to the
    /// store/broker so their writes are durable from here on, and
    /// best-effort fence the old primary over REST (it is fenced on its
    /// next ship touch regardless, its epoch now being stale). Idempotent:
    /// a second call reports the already-promoted state.
    pub fn promote(&self) -> Result<Json> {
        let _gate = self.promote_gate.lock().unwrap();
        let sh = &*self.shared;
        let mut sp = crate::obs::span("replication.promote");
        if sh.cluster.is_promoted() {
            sp.cancel(); // idempotent re-call, no takeover happened
            return Ok(Json::obj()
                .set("promoted", true)
                .set("already", true)
                .set("epoch", sh.cluster.epoch())
                .set("applied_lsn", sh.cluster.applied_lsn()));
        }
        // A standby that has never completed a pull still sits at epoch 0
        // and knows nothing about the cluster; epoch 0 + 1 = 1 would tie a
        // first-boot primary's epoch, so the fence comparison (strictly
        // newer) would never fire and both heads would accept writes.
        // Refuse the blind promote — the operator can retry once a pull
        // (or snapshot bootstrap) has adopted the primary's epoch. Checked
        // before stop() so a refused promote leaves the pull loop running.
        if sh.cluster.epoch() == 0 {
            bail!(
                "standby has never synced with the primary (cluster epoch still 0); \
                 refusing promote that could not fence the old primary"
            );
        }
        self.stop();
        sh.persist.wal().flush();
        let new_epoch = sh.cluster.epoch().max(1) + 1;
        let dir = sh
            .cluster
            .data_dir
            .as_ref()
            .context("replica has no data dir")?;
        {
            // same file lock as adopt_epoch — the pull loop is stopped by
            // now, but any straggling persist must not clobber this write
            let _g = sh.cluster.epoch_file.lock().unwrap();
            write_epoch(dir, new_epoch)?;
        }
        sh.cluster.epoch.store(new_epoch, Ordering::Release);
        sh.persist.attach(&sh.store, Some(&sh.broker));
        sh.cluster.replica.store(false, Ordering::Release);
        sh.cluster.promoted.store(true, Ordering::Release);
        sp.attr("epoch", new_epoch);
        sp.attr("applied_lsn", sh.cluster.applied_lsn());
        sh.metrics.counter("replication.promotions").inc();
        log::info!(
            "promoted to primary at epoch {new_epoch} (applied through lsn {})",
            sh.cluster.applied_lsn()
        );
        // fence the old primary now rather than waiting for its next ship
        // touch; best-effort — on failover it is usually already dead
        let fence_body = Json::obj().set("epoch", new_epoch).to_string();
        let auth = format!("Bearer {}", sh.token);
        match http_request_full(
            sh.cluster.primary_addr.as_str(),
            "POST",
            "/api/replication/fence",
            &[("Authorization", auth.as_str()), ("Content-Type", "application/json")],
            fence_body.as_bytes(),
        ) {
            Ok(r) if r.status == 200 => log::info!("old primary acknowledged fence"),
            Ok(r) => log::warn!("old primary fence returned {}", r.status),
            Err(e) => log::warn!("old primary unreachable for fence (expected on failover): {e}"),
        }
        Ok(Json::obj()
            .set("promoted", true)
            .set("epoch", new_epoch)
            .set("applied_lsn", sh.cluster.applied_lsn()))
    }
}

fn pull_loop(sh: &ReplicaShared) {
    let lag_gauge = sh.metrics.gauge("replication.lag_lsn");
    while !sh.stop.load(Ordering::Acquire) {
        // A fenced standby's timeline is dead: a newer epoch superseded it
        // (e.g. a sibling standby was promoted). Stop pulling — its WAL
        // refuses appends anyway, and continuing to apply into memory
        // would only let reads drift from what the dir can recover.
        if sh.cluster.is_fenced() {
            log::error!(
                "replica pull loop exiting: node fenced at epoch {}",
                sh.cluster.epoch()
            );
            break;
        }
        // snapshot the wake epoch BEFORE pulling: frames published while
        // the pull is in flight advance the epoch, so the wait below
        // returns immediately instead of missing them until the next poll
        let seen = sh.wake.as_ref().map(|w| w.epoch());
        match pull_once(sh) {
            Ok(applied) => {
                lag_gauge.set(sh.cluster.lag_lsn() as i64);
                if applied == 0 && !sh.stop.load(Ordering::Acquire) {
                    let idle = std::time::Duration::from_millis(sh.opts.poll_interval_ms);
                    match (&sh.wake, seen) {
                        (Some(w), Some(s)) => {
                            w.wait_past(s, idle);
                        }
                        _ => std::thread::sleep(idle),
                    }
                }
            }
            Err(e) => {
                sh.cluster.note_error(&e.to_string());
                log::debug!("replica pull failed (will retry): {e:#}");
                if !sh.stop.load(Ordering::Acquire) {
                    std::thread::sleep(std::time::Duration::from_millis(sh.opts.retry_ms));
                }
            }
        }
    }
}

/// One pull round trip; returns how many frames were applied.
fn pull_once(sh: &ReplicaShared) -> Result<usize> {
    let from = sh.cluster.applied_lsn() + 1;
    // Root span on the pull thread. Its context rides the X-IDDS-Trace
    // header, so the primary's request span (and the nested ship span)
    // join this trace — one cross-process view of a replication round.
    let mut sp = crate::obs::span("replication.pull");
    sp.attr("from_lsn", from);
    let trace_hv = {
        let c = sp.ctx();
        (!c.is_none()).then(|| c.header_value())
    };
    let auth = format!("Bearer {}", sh.token);
    let peer_epoch = sh.cluster.epoch().to_string();
    let path = format!(
        "/api/replication/wal?from_lsn={from}&max_bytes={}",
        sh.opts.batch_bytes
    );
    let mut headers =
        vec![("Authorization", auth.as_str()), (H_PEER_EPOCH, peer_epoch.as_str())];
    if let Some(hv) = trace_hv.as_deref() {
        headers.push((crate::obs::TRACE_HEADER, hv));
    }
    let resp = http_request_full(
        sh.cluster.primary_addr.as_str(),
        "GET",
        &path,
        &headers,
        b"",
    )?;
    sh.cluster.pulls.fetch_add(1, Ordering::Relaxed);
    let applied = match resp.status {
        200 => apply_batch(sh, &resp)?,
        410 => {
            // primary pruned past our position: only a *fresh* standby may
            // re-seed itself — one with applied history would silently
            // lose the gap
            if sh.cluster.applied_lsn() > 0 {
                bail!(
                    "primary pruned wal history past lsn {} (oldest {}); \
                     clear this replica's data dir to re-seed from a snapshot",
                    sh.cluster.applied_lsn(),
                    resp.header_u64(H_OLDEST_LSN).unwrap_or(0)
                );
            }
            bootstrap_snapshot(sh)?;
            1
        }
        409 => {
            // epoch conflict: ours is stale → adopt the primary's and
            // retry next round; theirs stale means a partitioned old
            // primary answered — back off and keep trying
            let theirs = resp.header_u64(H_EPOCH).unwrap_or(0);
            if theirs > sh.cluster.epoch() {
                sh.cluster.adopt_epoch(theirs);
                0
            } else {
                bail!("ship rejected: primary reports stale epoch {theirs}")
            }
        }
        401 => bail!("primary rejected our auth token"),
        s => bail!("ship request returned {s}"),
    };
    if applied == 0 {
        // caught-up idle poll: keep the 50ms heartbeat out of the ring
        sp.cancel();
    } else {
        sp.attr("frames", applied);
    }
    Ok(applied)
}

fn apply_batch(sh: &ReplicaShared, resp: &HttpResponse) -> Result<usize> {
    if let Some(e) = resp.header_u64(H_EPOCH) {
        sh.cluster.adopt_epoch(e);
    }
    if let Some(d) = resp.header_u64(H_DURABLE_LSN) {
        sh.cluster.primary_durable_lsn.store(d, Ordering::Release);
    }
    // strict CRC verification — a damaged transfer rejects the whole batch
    let frames = decode_frames(&resp.body).context("verifying shipped frames")?;
    let mut applied = 0usize;
    let mut max_id = 0;
    // Primary LSNs are dense, so a correct batch continues exactly at
    // applied+1 (frames at or below applied are replay overlap from a
    // retried pull). Anything else means frames were lost in shipping —
    // applying over a gap would diverge this replica from the primary
    // forever, so refuse the rest of the batch and re-pull.
    let mut expect = sh.cluster.applied_lsn() + 1;
    for (lsn, ev) in frames {
        if lsn < expect {
            continue; // replay across a retried pull; apply is idempotent anyway
        }
        if lsn > expect {
            bail!(
                "shipped batch skips lsn {expect} (next frame is {lsn}); \
                 refusing non-contiguous apply"
            );
        }
        expect = lsn + 1;
        max_id = max_id.max(ev.max_id());
        // apply FIRST, then append: the dirty mark lands before the local
        // WAL's next_lsn can pass this frame, so a standby checkpoint cut
        // between the two still covers the row (same fuzzy-cut argument
        // as the primary's log-after-apply). A crash between them loses
        // only the append — the next pull re-fetches from applied+1.
        if ev.is_broker() {
            sh.broker.apply_event(&ev);
        } else {
            sh.store.apply_event(&ev);
        }
        sh.persist.wal().append_shipped(lsn, ev);
        sh.cluster.applied_lsn.store(lsn, Ordering::Release);
        applied += 1;
    }
    if applied > 0 {
        // keep the global id allocator ahead of every replicated id so a
        // promoted standby never re-mints one
        crate::util::advance_next_id(max_id);
        sh.metrics.counter("replication.pull.frames").add(applied as u64);
        sh.metrics.counter("replication.pull.bytes").add(resp.body.len() as u64);
    }
    Ok(applied)
}

/// Seed an empty standby from the primary's snapshot endpoint (history
/// before the oldest retained WAL frame is only available this way).
fn bootstrap_snapshot(sh: &ReplicaShared) -> Result<()> {
    let mut sp = crate::obs::span("replication.bootstrap");
    let auth = format!("Bearer {}", sh.token);
    let resp = http_request_full(
        sh.cluster.primary_addr.as_str(),
        "GET",
        "/api/replication/snapshot",
        &[("Authorization", auth.as_str())],
        b"",
    )?;
    anyhow::ensure!(resp.status == 200, "snapshot request returned {}", resp.status);
    let j = parse(std::str::from_utf8(&resp.body).context("snapshot utf-8")?)
        .context("snapshot json")?;
    let cut_lsn = j
        .get("cut_lsn")
        .and_then(|v| v.as_u64())
        .context("snapshot missing cut_lsn")?;
    let snap = j.get("snapshot").context("snapshot missing body")?;
    sh.store.restore(snap).context("installing primary snapshot")?;
    if let Some(bj) = snap.get("broker") {
        sh.broker.restore(bj).context("installing primary broker section")?;
    }
    // a local base checkpoint at the cut makes the seed durable and lets
    // recovery on the standby start from it instead of an empty store
    sh.persist
        .bootstrap_base(&sh.store, cut_lsn)
        .context("writing bootstrap checkpoint")?;
    sh.cluster.applied_lsn.store(cut_lsn.saturating_sub(1), Ordering::Release);
    if let Some(e) = j.get("epoch").and_then(|v| v.as_u64()) {
        sh.cluster.adopt_epoch(e);
    }
    sp.attr("cut_lsn", cut_lsn);
    sh.metrics.counter("replication.bootstraps").inc();
    log::info!("standby bootstrapped from primary snapshot at cut lsn {cut_lsn}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::{FsyncMode, PersistEvent, Persister};
    use crate::store::RequestKind;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "idds-repl-unit-{tag}-{}-{}",
            std::process::id(),
            crate::util::next_id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn ev(i: u64) -> PersistEvent {
        PersistEvent::AddRequest {
            id: i,
            name: format!("r{i}"),
            requester: "u".into(),
            kind: RequestKind::Workflow,
            workflow: Json::Null,
            at: i as f64,
        }
    }

    /// The prune/ship race: a cataloged segment that may hold frames the
    /// standby asked for vanishes (checkpoint prune) before the scan
    /// reaches it. Shipping must fail — a skip would hand the standby a
    /// batch with a silent hole it would apply over.
    #[test]
    fn ship_scan_failure_is_an_error_not_a_gap() {
        let dir = tmp_dir("shipgap");
        let metrics = Registry::default();
        let (wal, flusher) =
            Wal::create(&dir, 2048, FsyncMode::Never, 5, 1, 1, Vec::new(), 0, &metrics)
                .unwrap();
        for i in 0..200u64 {
            wal.log(ev(i));
            if i % 10 == 0 {
                wal.flush(); // many small batches → several segment rotations
            }
        }
        wal.flush();
        let (wdir, segs) = wal.catalog();
        assert!(segs.len() >= 3, "need multiple segments to stage the race");
        let victim = segs[1].clone();
        std::fs::remove_file(segment_path(&wdir, victim.seq)).unwrap();

        let r = ship_frames(&wal, 1, 1 << 20);
        assert!(r.is_err(), "a vanished in-range segment must fail the ship, not skip");

        // history wholly below from_lsn is legitimately skippable: a pull
        // starting past the victim never opens it and still gets frames
        let from = victim.last_lsn.unwrap() + 1;
        match ship_frames(&wal, from, 1 << 20).unwrap() {
            ShipReply::Batch { count, last_lsn, .. } => {
                assert!(count > 0, "later segments still ship");
                assert_eq!(last_lsn, wal.durable_lsn());
            }
            ShipReply::Gone { .. } => panic!("history at lsn {from} still exists"),
        }
        wal.stop();
        flusher.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
