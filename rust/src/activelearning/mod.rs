//! Active Learning workflows (paper section 3.3.2, Fig. 7).
//!
//! Two Work template kinds alternate through Condition branches:
//! a **processing** Work produces summary statistics, a **decision** Work
//! (the AOT `al_decision` artifact) evaluates them and either triggers the
//! next processing iteration (with newly bound parameters) or lets the
//! workflow terminate — a *cyclic* directed graph, the paper's flagship
//! DG-beyond-DAG case.
//!
//! [`build_workflow`] constructs that cyclic workflow; [`ScanExecutor`]
//! is the synthetic processing payload: a parameter scan whose measured
//! "signal significance" grows with the scanned region, so the loop
//! provably converges after a few iterations.

use std::collections::HashMap;
use std::sync::Mutex;
#[cfg(test)]
use std::sync::Arc;

use anyhow::Result;

use crate::daemons::executors::Executor;
use crate::util::json::Json;
use crate::workflow::{Condition, Predicate, WorkKind, WorkTemplate, Workflow};

/// Build the cyclic Active-Learning workflow.
///
/// * `proc` (Noop kind → [`ScanExecutor`] in practice) takes `lo`/`hi`
///   scan bounds and produces `result.stats` (8 summary statistics) plus
///   `result.next_lo`/`result.next_hi` (the refined region).
/// * `decide` (Decision kind → AOT artifact) consumes the stats and emits
///   `result.go` ∈ {0, 1}.
/// * conditions: `proc → decide` always (stats bound from the result);
///   `decide → proc` when `go` — the cycle. Bounded by `max_iters`.
pub fn build_workflow(max_iters: u32, threshold: f64) -> Workflow {
    Workflow::new("active-learning")
        .add_template(
            WorkTemplate::new("proc")
                .kind(WorkKind::Noop) // executed by ScanExecutor
                .default("lo", Json::Num(0.0))
                .default("hi", Json::Num(1.0))
                .max_instances(max_iters),
        )
        .add_template(
            WorkTemplate::new("decide")
                .kind(WorkKind::Decision)
                .default(
                    "weights",
                    Json::Arr(vec![Json::Num(1.0); 8]),
                )
                .default("bias", Json::Num(-4.0))
                .default("threshold", Json::Num(threshold))
                .max_instances(max_iters),
        )
        .add_condition(
            Condition::always("proc", "decide")
                .bind("stats", "${result.stats}")
                .bind("next_lo", "${result.next_lo}")
                .bind("next_hi", "${result.next_hi}"),
        )
        .add_condition(
            Condition::when("decide", "proc", Predicate::truthy("go"))
                .bind("lo", "${param.next_lo}")
                .bind("hi", "${param.next_hi}"),
        )
        .entry("proc")
}

/// Synthetic processing payload: "scan" the region [lo, hi] of a parameter
/// space; the produced statistics strengthen as the region narrows onto
/// the signal at 0.7, so `al_decision`'s logistic score eventually drops
/// below threshold and the loop stops.
pub struct ScanExecutor {
    done: Mutex<HashMap<u64, Json>>,
}

impl Default for ScanExecutor {
    fn default() -> Self {
        ScanExecutor {
            done: Mutex::new(HashMap::new()),
        }
    }
}

const SIGNAL: f64 = 0.7;

impl Executor for ScanExecutor {
    fn submit(&self, work: &Json) -> Result<u64> {
        let lo = work
            .get_path(&["params", "lo"])
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        let hi = work
            .get_path(&["params", "hi"])
            .and_then(|v| v.as_f64())
            .unwrap_or(1.0);
        let width = (hi - lo).max(1e-6);
        // stats: wider region -> large residual uncertainty stats ->
        // logistic(go) stays high; narrow region -> stats shrink -> stop.
        let stats: Vec<Json> = (0..8)
            .map(|i| Json::Num(width * (1.0 + 0.1 * i as f64)))
            .collect();
        // refine: halve the region around the signal
        let mid = SIGNAL.clamp(lo, hi);
        let next_lo = (mid - width / 4.0).max(lo);
        let next_hi = (mid + width / 4.0).min(hi);
        let result = Json::obj()
            .set("stats", Json::Arr(stats))
            .set("next_lo", next_lo)
            .set("next_hi", next_hi)
            .set("width", width);
        let handle = crate::util::next_id();
        self.done.lock().unwrap().insert(handle, result);
        Ok(handle)
    }

    fn poll(&self, handle: u64) -> Result<Option<Json>> {
        Ok(self.done.lock().unwrap().remove(&handle))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::Broker;
    use crate::daemons::executors::ExecutorSet;
    use crate::daemons::{pump, Pipeline};
    use crate::metrics::Registry;
    use crate::runtime::{default_artifacts_dir, EngineHandle};
    use crate::store::{RequestKind, RequestStatus, Store};
    use crate::util::clock::WallClock;

    #[test]
    fn workflow_is_cyclic_and_valid() {
        let wf = build_workflow(10, 0.5);
        assert!(wf.validate().is_ok());
        assert!(wf.has_cycle());
        // round-trips through the client serialization
        let back = Workflow::from_json(&wf.to_json()).unwrap();
        assert!(back.has_cycle());
    }

    #[test]
    fn scan_executor_narrows_region() {
        let e = ScanExecutor::default();
        let w = Json::obj().set(
            "params",
            Json::obj().set("lo", 0.0).set("hi", 1.0),
        );
        let h = e.submit(&w).unwrap();
        let r = e.poll(h).unwrap().unwrap();
        let lo = r.get("next_lo").unwrap().as_f64().unwrap();
        let hi = r.get("next_hi").unwrap().as_f64().unwrap();
        assert!(hi - lo < 1.0);
        assert!(lo <= SIGNAL && SIGNAL <= hi);
    }

    /// The full cyclic loop through the daemons + the real decision
    /// artifact: iterate until the logistic score drops below threshold.
    #[test]
    fn active_learning_loop_converges() {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts missing; run `make artifacts`");
            return;
        }
        let engine = EngineHandle::start(&dir).unwrap();
        let clock = Arc::new(WallClock::new());
        let execs = ExecutorSet::default()
            .with(WorkKind::Noop, Arc::new(ScanExecutor::default()))
            .with(
                WorkKind::Decision,
                Arc::new(crate::daemons::executors::RuntimeExecutor::new(engine, 2)),
            );
        let p = Pipeline::new(
            Store::new(clock.clone()),
            Broker::new(clock),
            Registry::default(),
            execs,
        );
        let wf = build_workflow(12, 0.5);
        let req = p
            .store
            .add_request("al", "physicist", RequestKind::ActiveLearning, wf.to_json());
        let (clerk, marsh, tfr, carrier, conductor) = p.daemons();
        // RuntimeExecutor completes asynchronously; pump with retries
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(120);
        loop {
            pump(&[&clerk, &marsh, &tfr, &carrier, &conductor], 10_000);
            let st = p.store.get_request(req).unwrap().status;
            if st.is_terminal() {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "AL loop did not converge in time"
            );
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        assert_eq!(
            p.store.get_request(req).unwrap().status,
            RequestStatus::Finished
        );
        let tfs = p.store.transforms_of_request(req);
        // at least proc -> decide -> proc -> decide (converging loop),
        // strictly fewer than the 2*12 cap (it stopped by decision)
        assert!(tfs.len() >= 4, "{} transforms", tfs.len());
        assert!(tfs.len() < 24, "{} transforms — never converged", tfs.len());
        // last decision said "no"
        let last_decide = tfs
            .iter()
            .filter_map(|t| p.store.get_transform(*t).ok())
            .filter(|t| t.name.starts_with("decide"))
            .next_back()
            .unwrap();
        let go = last_decide
            .work
            .get_path(&["result", "go"])
            .and_then(|g| g.as_bool())
            .unwrap();
        assert!(!go, "final decision must stop the loop");
    }
}
