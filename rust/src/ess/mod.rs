//! Event Streaming Service model (paper section 1).
//!
//! The paper motivates iDDS with workflows like the ATLAS Event Streaming
//! Service, which "delivers fine-grained input data to remote computing
//! resources over the network" — i.e. ship only the *event ranges* a job
//! actually reads instead of whole files. This module models that
//! delivery-granularity decision, the iDDS function "data delivery with
//! optimal granularity ... while preserving effective data caching":
//!
//! * input files hold `events × bytes_per_event`;
//! * an access trace (Zipf file popularity, per-job selectivity) says
//!   which event ranges each job reads;
//! * an LRU edge cache of configurable capacity sits in front of the WAN;
//! * [`simulate`] measures WAN bytes, cache hit rate and delivered bytes
//!   for [`Delivery::WholeFile`] vs [`Delivery::EventRanges`].
//!
//! The interesting output is the **crossover**: ranged delivery wins at
//! low selectivity (sparse reads), whole-file wins when jobs read most of
//! each file *and* reuse is high enough that cached whole files amortize
//! (the paper's "preserving effective data caching" caveat). The
//! `bench_ess` target sweeps selectivity to locate the crossover.

use std::collections::HashMap;

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Delivery {
    /// stage the whole file to the edge cache, serve locally
    WholeFile,
    /// ship only the requested event ranges (granularity = `chunk_events`)
    EventRanges,
}

/// Shape of the event-streaming experiment: the file population, the edge
/// cache in front of the WAN, and the delivery chunking.
#[derive(Debug, Clone)]
pub struct EssConfig {
    pub files: usize,
    pub events_per_file: u64,
    pub bytes_per_event: u64,
    /// edge cache capacity in bytes
    pub cache_bytes: u64,
    /// ranged mode ships ceil(range/chunk) chunks of this many events
    pub chunk_events: u64,
    /// Zipf exponent for file popularity
    pub zipf_s: f64,
}

impl Default for EssConfig {
    fn default() -> Self {
        EssConfig {
            files: 200,
            events_per_file: 10_000,
            bytes_per_event: 100_000, // 1 GB files
            cache_bytes: 50_000_000_000, // 50 GB edge cache
            chunk_events: 100,
            zipf_s: 1.1,
        }
    }
}

/// One job's read: `count` events starting at `start` in `file`.
#[derive(Debug, Clone, Copy)]
pub struct Access {
    pub file: usize,
    pub start: u64,
    pub count: u64,
}

/// Generate an access trace: `jobs` reads over Zipf-popular files, each
/// reading a contiguous range covering `selectivity` of the file.
pub fn generate_trace(cfg: &EssConfig, jobs: usize, selectivity: f64, seed: u64) -> Vec<Access> {
    let mut rng = Rng::new(seed);
    let sel = selectivity.clamp(0.0, 1.0);
    (0..jobs)
        .map(|_| {
            let file = (rng.zipf(cfg.files as u64, cfg.zipf_s) - 1) as usize;
            let count = ((cfg.events_per_file as f64 * sel).round() as u64)
                .clamp(1, cfg.events_per_file);
            let max_start = cfg.events_per_file - count;
            let start = if max_start == 0 { 0 } else { rng.below(max_start + 1) };
            Access { file, start, count }
        })
        .collect()
}

/// Traffic accounting for one simulated trace under one delivery mode.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EssResult {
    /// bytes pulled over the WAN (the paper's "minimize network traffic")
    pub wan_bytes: u64,
    /// bytes served out of the edge cache
    pub cached_bytes: u64,
    /// bytes actually delivered to jobs (= what they read)
    pub delivered_bytes: u64,
    /// cache hit ratio by bytes
    pub hit_ratio: f64,
}

/// Byte-capacity LRU over abstract unit keys.
///
/// Recency order lives in a tick-keyed `BTreeMap` (ticks are unique), so
/// touch/insert/evict are all O(log n) — the original scan-the-map-per-
/// eviction version made 10k-job traces quadratic (EXPERIMENTS.md §Perf,
/// L3 iteration 4).
struct Lru {
    capacity: u64,
    used: u64,
    /// key -> (size, last-use tick)
    entries: HashMap<(usize, u64), (u64, u64)>,
    /// last-use tick -> key (ticks unique: strict recency order)
    order: std::collections::BTreeMap<u64, (usize, u64)>,
    tick: u64,
}

impl Lru {
    fn new(capacity: u64) -> Self {
        Lru {
            capacity,
            used: 0,
            entries: HashMap::new(),
            order: std::collections::BTreeMap::new(),
            tick: 0,
        }
    }

    fn touch(&mut self, key: (usize, u64)) -> bool {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            self.order.remove(&e.1);
            e.1 = self.tick;
            self.order.insert(self.tick, key);
            true
        } else {
            false
        }
    }

    fn insert(&mut self, key: (usize, u64), size: u64) {
        self.tick += 1;
        if size > self.capacity {
            return; // uncacheable
        }
        while self.used + size > self.capacity {
            // evict LRU = smallest tick
            let Some((&t, &victim)) = self.order.iter().next() else { break };
            self.order.remove(&t);
            let (vsize, _) = self.entries.remove(&victim).unwrap();
            self.used -= vsize;
        }
        self.entries.insert(key, (size, self.tick));
        self.order.insert(self.tick, key);
        self.used += size;
    }
}

/// Run the trace under a delivery mode.
pub fn simulate(cfg: &EssConfig, mode: Delivery, trace: &[Access]) -> EssResult {
    let mut cache = Lru::new(cfg.cache_bytes);
    let file_bytes = cfg.events_per_file * cfg.bytes_per_event;
    let chunk_bytes = cfg.chunk_events * cfg.bytes_per_event;
    let mut r = EssResult::default();

    for a in trace {
        let read_bytes = a.count * cfg.bytes_per_event;
        r.delivered_bytes += read_bytes;
        match mode {
            Delivery::WholeFile => {
                // cache unit = the file (chunk index 0)
                let key = (a.file, u64::MAX);
                if cache.touch(key) {
                    r.cached_bytes += read_bytes;
                } else {
                    r.wan_bytes += file_bytes; // stage the whole file
                    cache.insert(key, file_bytes);
                }
            }
            Delivery::EventRanges => {
                // cache unit = fixed event chunks covering the range
                let first = a.start / cfg.chunk_events;
                let last = (a.start + a.count - 1) / cfg.chunk_events;
                for chunk in first..=last {
                    let key = (a.file, chunk);
                    if cache.touch(key) {
                        r.cached_bytes += chunk_bytes;
                    } else {
                        r.wan_bytes += chunk_bytes;
                        cache.insert(key, chunk_bytes);
                    }
                }
            }
        }
    }
    let total = r.wan_bytes + r.cached_bytes;
    r.hit_ratio = if total == 0 {
        0.0
    } else {
        r.cached_bytes as f64 / total as f64
    };
    r
}

/// Sweep selectivity and return (selectivity, whole-file WAN, ranged WAN)
/// rows — the crossover table.
pub fn selectivity_sweep(
    cfg: &EssConfig,
    jobs: usize,
    selectivities: &[f64],
    seed: u64,
) -> Vec<(f64, u64, u64)> {
    selectivities
        .iter()
        .map(|&sel| {
            let trace = generate_trace(cfg, jobs, sel, seed);
            let wf = simulate(cfg, Delivery::WholeFile, &trace);
            let er = simulate(cfg, Delivery::EventRanges, &trace);
            (sel, wf.wan_bytes, er.wan_bytes)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> EssConfig {
        EssConfig {
            files: 50,
            events_per_file: 1000,
            bytes_per_event: 1000,
            cache_bytes: 10_000_000, // 10 files worth
            chunk_events: 10,
            zipf_s: 1.1,
        }
    }

    #[test]
    fn trace_ranges_are_in_bounds() {
        let c = cfg();
        for a in generate_trace(&c, 500, 0.3, 1) {
            assert!(a.file < c.files);
            assert!(a.count >= 1);
            assert!(a.start + a.count <= c.events_per_file);
        }
    }

    #[test]
    fn sparse_reads_favor_event_ranges() {
        let c = cfg();
        let trace = generate_trace(&c, 1000, 0.02, 2); // 2% of each file
        let wf = simulate(&c, Delivery::WholeFile, &trace);
        let er = simulate(&c, Delivery::EventRanges, &trace);
        assert!(
            er.wan_bytes * 3 < wf.wan_bytes,
            "ranged {} vs whole {}",
            er.wan_bytes,
            wf.wan_bytes
        );
    }

    #[test]
    fn dense_reads_with_reuse_favor_whole_file_caching() {
        let mut c = cfg();
        c.files = 5; // heavy reuse: everything fits the cache
        c.cache_bytes = 5 * 1000 * 1000;
        let trace = generate_trace(&c, 2000, 0.95, 3);
        let wf = simulate(&c, Delivery::WholeFile, &trace);
        let er = simulate(&c, Delivery::EventRanges, &trace);
        // whole-file stages each file once and then serves from cache;
        // ranged pays chunk misses per distinct range start
        assert!(wf.wan_bytes <= er.wan_bytes, "whole {} vs ranged {}", wf.wan_bytes, er.wan_bytes);
        assert!(wf.hit_ratio > 0.9);
    }

    #[test]
    fn delivered_bytes_independent_of_mode() {
        let c = cfg();
        let trace = generate_trace(&c, 300, 0.2, 4);
        let wf = simulate(&c, Delivery::WholeFile, &trace);
        let er = simulate(&c, Delivery::EventRanges, &trace);
        assert_eq!(wf.delivered_bytes, er.delivered_bytes);
    }

    #[test]
    fn lru_evicts_and_respects_capacity() {
        let mut l = Lru::new(100);
        l.insert((0, 0), 60);
        l.insert((1, 0), 60); // evicts (0,0)
        assert!(l.used <= 100);
        assert!(!l.touch((0, 0)));
        assert!(l.touch((1, 0)));
        // oversized item is not cached
        l.insert((2, 0), 1000);
        assert!(!l.touch((2, 0)));
    }

    #[test]
    fn sweep_shows_crossover_direction() {
        let c = cfg();
        let rows = selectivity_sweep(&c, 800, &[0.01, 0.5, 1.0], 5);
        // at 1% ranged must win; at 100% ranged cannot beat whole-file by
        // more than chunk rounding
        let (_, wf_lo, er_lo) = rows[0];
        assert!(er_lo < wf_lo);
        let (_, wf_hi, er_hi) = rows[2];
        assert!(er_hi as f64 >= wf_hi as f64 * 0.9);
    }
}
