//! Tape-system simulator (the substrate under the ATLAS Data Carousel,
//! paper section 3.1).
//!
//! Discrete-event model of a tape library: files live on cartridges; a
//! limited set of drives serves recall requests; switching a drive to a
//! different cartridge pays a mount latency; each file read pays a seek
//! plus size/bandwidth transfer time.
//!
//! The model is driven with explicit timestamps (`tick(now)`), not a
//! clock, so the discrete-event simulation owns time. The scheduler is
//! mount-minimizing: a drive keeps reading its mounted cartridge while
//! that cartridge has pending recalls, and otherwise picks the unserviced
//! cartridge with the deepest queue — the behaviour that makes *recall
//! order* (dataset-clustered vs scattered) matter, which is exactly the
//! effect the carousel experiments measure.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

pub type FileId = u64;
pub type CartridgeId = u32;

#[derive(Debug, Clone)]
struct TapeFile {
    cartridge: CartridgeId,
    size_bytes: u64,
}

#[derive(Debug, Clone)]
struct Drive {
    free_at: f64,
    mounted: Option<CartridgeId>,
}

/// A completed recall: the file is now on the disk buffer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecallDone {
    pub file: FileId,
    pub at: f64,
}

/// Cumulative library counters; mounts are the scarce operation the
/// carousel's recall ordering tries to minimize.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TapeStats {
    pub mounts: u64,
    pub recalls_done: u64,
    pub bytes_read: u64,
    /// drive-seconds spent mounted+reading (utilization numerator)
    pub busy_seconds: f64,
}

/// Ordered f64 for the completion heap.
#[derive(PartialEq, PartialOrd)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// The tape library: registered files, per-cartridge recall queues, a
/// bounded drive set, and the mount-minimizing scheduler.
pub struct TapeSystem {
    files: HashMap<FileId, TapeFile>,
    /// per-cartridge FIFO of (file, requested_at)
    pending: HashMap<CartridgeId, VecDeque<(FileId, f64)>>,
    pending_total: usize,
    drives: Vec<Drive>,
    completions: BinaryHeap<Reverse<(OrdF64, FileId)>>,
    mount_latency_s: f64,
    seek_latency_s: f64,
    bytes_per_sec: f64,
    stats: TapeStats,
}

impl TapeSystem {
    /// Build a library with `drives` drives, the given mount/seek
    /// latencies, and per-drive read bandwidth.
    pub fn new(
        drives: usize,
        mount_latency_s: f64,
        seek_latency_s: f64,
        bandwidth_mbps: f64,
    ) -> Self {
        assert!(drives > 0);
        TapeSystem {
            files: HashMap::new(),
            pending: HashMap::new(),
            pending_total: 0,
            drives: vec![
                Drive {
                    free_at: 0.0,
                    mounted: None,
                };
                drives
            ],
            completions: BinaryHeap::new(),
            mount_latency_s,
            seek_latency_s,
            bytes_per_sec: bandwidth_mbps * 1e6,
            stats: TapeStats::default(),
        }
    }

    /// Register a tape-resident file.
    pub fn register_file(&mut self, file: FileId, cartridge: CartridgeId, size_bytes: u64) {
        self.files.insert(
            file,
            TapeFile {
                cartridge,
                size_bytes,
            },
        );
    }

    /// Queue a recall at time `at`. Panics if the file is unknown
    /// (caller bug). The drive can start the read no earlier than `at`.
    pub fn request_recall(&mut self, file: FileId, at: f64) {
        let cart = self.files.get(&file).expect("recall of unknown file").cartridge;
        self.pending.entry(cart).or_default().push_back((file, at));
        self.pending_total += 1;
    }

    /// Recalls queued but not yet completed.
    pub fn pending_recalls(&self) -> usize {
        self.pending_total
    }

    /// Cumulative counters so far.
    pub fn stats(&self) -> TapeStats {
        self.stats
    }

    /// Advance to `now`: schedule free drives onto pending work and return
    /// all recalls completed at or before `now`.
    pub fn tick(&mut self, now: f64) -> Vec<RecallDone> {
        self.schedule(now);
        let mut out = Vec::new();
        while let Some(Reverse((OrdF64(t), _))) = self.completions.peek() {
            if *t > now {
                break;
            }
            let Reverse((OrdF64(t), file)) = self.completions.pop().unwrap();
            out.push(RecallDone { file, at: t });
        }
        out
    }

    /// Earliest future completion (the DES driver jumps to this).
    pub fn next_event_time(&self) -> Option<f64> {
        self.completions.peek().map(|Reverse((OrdF64(t), _))| *t)
    }

    fn schedule(&mut self, now: f64) {
        loop {
            let mut progressed = false;
            for d in 0..self.drives.len() {
                if self.drives[d].free_at > now || self.pending_total == 0 {
                    continue;
                }
                let Some(cart) = self.pick_cartridge(d, now) else { continue };
                let (file, req_at) = self.pending.get_mut(&cart).unwrap().pop_front().unwrap();
                if self.pending[&cart].is_empty() {
                    self.pending.remove(&cart);
                }
                self.pending_total -= 1;

                let drive = &mut self.drives[d];
                // start when both the drive and the request exist
                let start = drive.free_at.max(req_at);
                let mut t = start;
                if drive.mounted != Some(cart) {
                    t += self.mount_latency_s;
                    drive.mounted = Some(cart);
                    self.stats.mounts += 1;
                }
                let size = self.files[&file].size_bytes;
                t += self.seek_latency_s + size as f64 / self.bytes_per_sec;
                drive.free_at = t;
                self.stats.busy_seconds += t - start;
                self.stats.bytes_read += size;
                self.stats.recalls_done += 1;
                self.completions.push(Reverse((OrdF64(t), file)));
                progressed = true;
            }
            if !progressed {
                break;
            }
        }
    }

    /// Cartridge choice for drive `d`: stickiness first (keep reading the
    /// mounted cartridge), else the deepest queue not held by another
    /// drive. A cartridge mounted on any other drive is unavailable — its
    /// own drive will serve it by stickiness, so no recall starves.
    fn pick_cartridge(&self, d: usize, _now: f64) -> Option<CartridgeId> {
        let mounted = self.drives[d].mounted;
        if let Some(c) = mounted {
            if self.pending.contains_key(&c) {
                return Some(c);
            }
        }
        let held: Vec<CartridgeId> = self
            .drives
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != d)
            .filter_map(|(_, dr)| dr.mounted)
            .collect();
        self.pending
            .iter()
            .filter(|(c, _)| !held.contains(c))
            .max_by_key(|(c, q)| (q.len(), Reverse(**c)))
            .map(|(c, _)| *c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> TapeSystem {
        // 2 drives, 60 s mount, 10 s seek, 100 MB/s
        TapeSystem::new(2, 60.0, 10.0, 100.0)
    }

    #[test]
    fn single_recall_timing() {
        let mut s = sys();
        s.register_file(1, 0, 1_000_000_000); // 1 GB -> 10 s transfer
        s.request_recall(1, 0.0);
        assert!(s.tick(0.0).is_empty()); // mount+seek+transfer = 80 s
        assert_eq!(s.next_event_time(), Some(80.0));
        let done = s.tick(80.0);
        assert_eq!(done, vec![RecallDone { file: 1, at: 80.0 }]);
        assert_eq!(s.stats().mounts, 1);
    }

    #[test]
    fn same_cartridge_avoids_remount() {
        let mut s = sys();
        s.register_file(1, 7, 100_000_000); // 1 s transfer
        s.register_file(2, 7, 100_000_000);
        s.request_recall(1, 0.0);
        s.request_recall(2, 0.0);
        let done = s.tick(1000.0);
        assert_eq!(done.len(), 2);
        assert_eq!(s.stats().mounts, 1, "second file reuses the mount");
        // file1: 60+10+1 = 71; file2: 71+10+1 = 82
        assert!((done[0].at - 71.0).abs() < 1e-6);
        assert!((done[1].at - 82.0).abs() < 1e-6);
    }

    #[test]
    fn scattered_recalls_pay_mounts() {
        let mut s = TapeSystem::new(1, 60.0, 10.0, 100.0);
        for i in 0..4u64 {
            s.register_file(i, i as CartridgeId, 100_000_000);
            s.request_recall(i, 0.0);
        }
        let done = s.tick(1e6);
        assert_eq!(done.len(), 4);
        assert_eq!(s.stats().mounts, 4, "every file on its own cartridge");
    }

    #[test]
    fn drives_work_in_parallel() {
        let mut s = sys();
        s.register_file(1, 0, 100_000_000);
        s.register_file(2, 1, 100_000_000);
        s.request_recall(1, 0.0);
        s.request_recall(2, 0.0);
        let done = s.tick(71.0);
        assert_eq!(done.len(), 2, "two drives, two cartridges, same finish");
    }

    #[test]
    fn two_drives_do_not_mount_same_cartridge() {
        let mut s = sys();
        for i in 0..10u64 {
            s.register_file(i, 0, 1_000_000_000);
            s.request_recall(i, 0.0);
        }
        s.tick(0.0);
        // only one drive can serve cartridge 0; the other must stay idle
        let busy: Vec<_> = s.drives.iter().filter(|d| d.free_at > 0.0).collect();
        assert_eq!(busy.len(), 1);
    }

    #[test]
    fn deepest_queue_first() {
        let mut s = TapeSystem::new(1, 60.0, 0.0, 1000.0);
        s.register_file(1, 0, 1_000);
        s.register_file(2, 1, 1_000);
        s.register_file(3, 1, 1_000);
        s.request_recall(1, 0.0);
        s.request_recall(2, 0.0);
        s.request_recall(3, 0.0);
        let done = s.tick(1e9);
        // cartridge 1 has depth 2 -> served first
        assert_eq!(done[0].file, 2);
        assert_eq!(done[1].file, 3);
        assert_eq!(done[2].file, 1);
        assert_eq!(s.stats().mounts, 2);
    }

    #[test]
    fn progressive_ticks_match_one_shot() {
        let build = || {
            let mut s = TapeSystem::new(2, 30.0, 5.0, 200.0);
            for i in 0..50u64 {
                s.register_file(i, (i % 5) as CartridgeId, 50_000_000 * (1 + i % 3));
                s.request_recall(i, 0.0);
            }
            s
        };
        let mut a = build();
        let one_shot: Vec<_> = a.tick(1e9).into_iter().collect();
        let mut b = build();
        let mut progressive = Vec::new();
        let mut t = 0.0;
        loop {
            progressive.extend(b.tick(t));
            match b.next_event_time() {
                Some(next) => t = next,
                None => break,
            }
        }
        progressive.extend(b.tick(1e9));
        assert_eq!(one_shot.len(), 50);
        assert_eq!(progressive.len(), 50);
        // The deepest-queue policy is evaluated at different instants in
        // the two modes, so exact times may differ by one transfer slot;
        // the completion *sets* must match and per-file times must agree
        // closely (no structural divergence).
        let mut am: Vec<_> = one_shot.iter().map(|r| (r.file, r.at)).collect();
        let mut bm: Vec<_> = progressive.iter().map(|r| (r.file, r.at)).collect();
        am.sort_by(|a, b| a.0.cmp(&b.0));
        bm.sort_by(|a, b| a.0.cmp(&b.0));
        for ((fa, ta), (fb, tb)) in am.iter().zip(bm.iter()) {
            assert_eq!(fa, fb);
            assert!((ta - tb).abs() < 2.0, "file {fa}: {ta} vs {tb}");
        }
    }

    #[test]
    fn stats_conservation() {
        let mut s = sys();
        for i in 0..20u64 {
            s.register_file(i, (i % 3) as CartridgeId, 10_000_000);
            s.request_recall(i, 0.0);
        }
        let done = s.tick(1e9);
        assert_eq!(done.len(), 20);
        let st = s.stats();
        assert_eq!(st.recalls_done, 20);
        assert_eq!(st.bytes_read, 20 * 10_000_000);
        assert!(st.mounts >= 3);
        assert_eq!(s.pending_recalls(), 0);
    }
}
