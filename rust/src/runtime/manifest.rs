//! Artifact manifest parsing (`artifacts/manifest.json`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{parse, Json};

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone, Default)]
pub struct EntrySpec {
    pub file: String,
    /// Ordered as the artifact's positional arguments.
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub consts: BTreeMap<String, u64>,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: BTreeMap<String, EntrySpec>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    // Objects in our JSON model are BTreeMaps (sorted by key); aot.py dicts
    // are insertion-ordered. To preserve positional order we rely on the
    // python side emitting an explicit "order" array alongside, falling
    // back to sorted order if absent.
    let obj = j.as_obj().context("tensor spec map")?;
    let mut out = Vec::new();
    for (name, spec) in obj {
        let shape = spec
            .get("shape")
            .and_then(|s| s.as_arr())
            .context("spec.shape")?
            .iter()
            .map(|d| d.as_u64().map(|v| v as usize).context("dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = spec
            .get("dtype")
            .and_then(|d| d.as_str())
            .unwrap_or("f32")
            .to_string();
        out.push(TensorSpec {
            name: name.clone(),
            shape,
            dtype,
        });
    }
    Ok(out)
}

fn ordered_tensor_specs(parent: &Json, key: &str) -> Result<Vec<TensorSpec>> {
    let specs = tensor_specs(parent.get(key).context("specs")?)?;
    // optional explicit ordering: "<key>_order": ["a", "b", ...]
    if let Some(order) = parent
        .get(&format!("{key}_order"))
        .and_then(|o| o.as_arr())
    {
        let mut by_name: BTreeMap<String, TensorSpec> =
            specs.into_iter().map(|s| (s.name.clone(), s)).collect();
        let mut out = Vec::new();
        for n in order {
            let n = n.as_str().context("order entry")?;
            out.push(
                by_name
                    .remove(n)
                    .with_context(|| format!("order references unknown tensor '{n}'"))?,
            );
        }
        if !by_name.is_empty() {
            bail!("order is missing tensors: {:?}", by_name.keys());
        }
        return Ok(out);
    }
    Ok(specs)
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse_str(&text)
    }

    pub fn parse_str(text: &str) -> Result<Manifest> {
        let j = parse(text).context("manifest json")?;
        let format = j.get("format").and_then(|f| f.as_str()).unwrap_or("");
        if format != "hlo-text" {
            bail!("unsupported manifest format '{format}' (want hlo-text)");
        }
        let mut entries = BTreeMap::new();
        let ents = j.get("entries").and_then(|e| e.as_obj()).context("entries")?;
        for (name, ej) in ents {
            let file = ej.get("file").and_then(|f| f.as_str()).context("entry.file")?;
            let inputs = ordered_tensor_specs(ej, "inputs")?;
            let outputs = ordered_tensor_specs(ej, "outputs")?;
            let mut consts = BTreeMap::new();
            if let Some(c) = ej.get("consts").and_then(|c| c.as_obj()) {
                for (k, v) in c {
                    consts.insert(k.clone(), v.as_u64().context("const value")?);
                }
            }
            entries.insert(
                name.clone(),
                EntrySpec {
                    file: file.to_string(),
                    inputs,
                    outputs,
                    consts,
                },
            );
        }
        Ok(Manifest { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": "hlo-text",
        "entries": {
            "f": {
                "file": "f.hlo.txt",
                "inputs": {"b": {"shape": [2, 3], "dtype": "f32"},
                           "a": {"shape": [], "dtype": "f32"}},
                "inputs_order": ["a", "b"],
                "outputs": {"y": {"shape": [6], "dtype": "f32"}},
                "consts": {"n": 6}
            }
        }
    }"#;

    #[test]
    fn parses_with_explicit_order() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        let e = &m.entries["f"];
        assert_eq!(e.inputs[0].name, "a");
        assert_eq!(e.inputs[1].name, "b");
        assert_eq!(e.inputs[1].numel(), 6);
        assert_eq!(e.inputs[0].numel(), 1, "scalar numel is 1");
        assert_eq!(e.outputs[0].shape, vec![6]);
        assert_eq!(e.consts["n"], 6);
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse_str(r#"{"format": "protobuf", "entries": {}}"#).is_err());
        assert!(Manifest::parse_str("not json").is_err());
    }

    #[test]
    fn order_must_be_complete() {
        let bad = SAMPLE.replace(r#""inputs_order": ["a", "b"],"#, r#""inputs_order": ["a"],"#);
        assert!(Manifest::parse_str(&bad).is_err());
    }
}
