//! PJRT runtime: load the AOT-compiled HLO-text artifacts and execute them
//! from the Rust hot path. Python never runs here — `make artifacts` is a
//! build-time step.
//!
//! The manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) describes each entry's input/output shapes;
//! [`Engine`] compiles every entry once at startup (PJRT CPU client) and
//! exposes typed wrappers:
//!
//! * [`Engine::gp_propose`]   — HPO proposal step: GP posterior + EI over a
//!   candidate batch.
//! * [`Engine::mlp_train`]    — the simulated remote-training payload.
//! * [`Engine::al_decision`]  — Active-Learning decision scorer.
//!
//! Executables are wrapped in a `Mutex` each; PJRT execution is internally
//! parallel, and the iDDS daemons call in from multiple worker threads.

pub mod actor;
pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

pub use actor::EngineHandle;
pub use manifest::{EntrySpec, Manifest, TensorSpec};

/// Convenience: locate the artifacts dir from the repo root or env.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("IDDS_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // try CWD and upward twice (tests run from target subdirs sometimes)
    for base in [".", "..", "../.."] {
        let p = Path::new(base).join("artifacts");
        if p.join("manifest.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

struct Compiled {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    spec: EntrySpec,
}

/// The artifact engine: one compiled executable per manifest entry.
pub struct Engine {
    client: xla::PjRtClient,
    entries: HashMap<String, Compiled>,
}

/// Result of one GP proposal round.
#[derive(Debug, Clone)]
pub struct Proposal {
    pub mu: Vec<f32>,
    pub var: Vec<f32>,
    pub ei: Vec<f32>,
}

/// Result of one training-payload execution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainOutcome {
    pub val_loss: f32,
    pub train_loss: f32,
}

impl Engine {
    /// Load and compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let mut entries = HashMap::new();
        for (name, spec) in manifest.entries {
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            entries.insert(
                name,
                Compiled {
                    exe: Mutex::new(exe),
                    spec,
                },
            );
        }
        Ok(Engine { client, entries })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn entry_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn spec(&self, entry: &str) -> Option<&EntrySpec> {
        self.entries.get(entry).map(|c| &c.spec)
    }

    /// Generic execution: f32 inputs in manifest order → f32 outputs in
    /// manifest order. Shape-checks against the manifest.
    pub fn execute_f32(&self, entry: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let compiled = self
            .entries
            .get(entry)
            .with_context(|| format!("unknown artifact entry '{entry}'"))?;
        let spec = &compiled.spec;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "entry '{entry}': expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, tspec)) in inputs.iter().zip(spec.inputs.iter()).enumerate() {
            let want: usize = tspec.numel();
            if data.len() != want {
                bail!(
                    "entry '{entry}' input {i} ('{}'): expected {} elements ({:?}), got {}",
                    tspec.name,
                    want,
                    tspec.shape,
                    data.len()
                );
            }
            let lit = xla::Literal::vec1(data);
            let dims: Vec<i64> = tspec.shape.iter().map(|&d| d as i64).collect();
            literals.push(lit.reshape(&dims)?);
        }
        let result = {
            let exe = compiled.exe.lock().unwrap();
            exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?
        };
        // aot.py lowers with return_tuple=True: root is a tuple
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "entry '{entry}': manifest declares {} outputs, artifact returned {}",
                spec.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (part, tspec) in parts.into_iter().zip(spec.outputs.iter()) {
            let v = part.to_vec::<f32>().with_context(|| {
                format!("entry '{entry}' output '{}' not f32", tspec.name)
            })?;
            if v.len() != tspec.numel() {
                bail!(
                    "entry '{entry}' output '{}': expected {} elements, got {}",
                    tspec.name,
                    tspec.numel(),
                    v.len()
                );
            }
            out.push(v);
        }
        Ok(out)
    }

    // -- typed wrappers ------------------------------------------------------

    /// GP surrogate + EI. `x_obs`: n_obs*dim (row-major), `x_cand`:
    /// n_cand*dim, `params`: [log ls, log sf, log noise, xi].
    pub fn gp_propose(
        &self,
        x_obs: &[f32],
        y_obs: &[f32],
        mask: &[f32],
        x_cand: &[f32],
        params: &[f32; 4],
    ) -> Result<Proposal> {
        let outs = self.execute_f32("gp_propose", &[x_obs, y_obs, mask, x_cand, params])?;
        let mut it = outs.into_iter();
        Ok(Proposal {
            mu: it.next().unwrap(),
            var: it.next().unwrap(),
            ei: it.next().unwrap(),
        })
    }

    /// Remote-training payload (one hyperparameter point evaluation).
    #[allow(clippy::too_many_arguments)]
    pub fn mlp_train(
        &self,
        hparams: &[f32; 4],
        xtr: &[f32],
        ytr: &[f32],
        xval: &[f32],
        yval: &[f32],
        w1: &[f32],
        b1: &[f32],
        w2: &[f32],
        b2: &[f32],
    ) -> Result<TrainOutcome> {
        let outs = self.execute_f32(
            "mlp_train",
            &[hparams, xtr, ytr, xval, yval, w1, b1, w2, b2],
        )?;
        Ok(TrainOutcome {
            val_loss: outs[0][0],
            train_loss: outs[1][0],
        })
    }

    /// Active-Learning decision scorer. Returns (score, go).
    pub fn al_decision(
        &self,
        stats: &[f32],
        weights: &[f32],
        bias: f32,
        threshold: f32,
    ) -> Result<(f32, bool)> {
        let outs = self.execute_f32("al_decision", &[stats, weights, &[bias], &[threshold]])?;
        Ok((outs[0][0], outs[1][0] > 0.5))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests require `make artifacts` to have run; they are the
    // Rust-side half of the AOT contract. Skip gracefully if missing so
    // `cargo test` works on a fresh checkout (CI runs `make test`).
    fn engine() -> Option<Engine> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts missing; run `make artifacts`");
            return None;
        }
        Some(Engine::load(&dir).expect("engine load"))
    }

    #[test]
    fn loads_all_entries() {
        let Some(e) = engine() else { return };
        assert_eq!(
            e.entry_names(),
            vec!["al_decision", "gp_propose", "mlp_train"]
        );
        assert!(!e.platform().is_empty());
    }

    #[test]
    fn al_decision_runs() {
        let Some(e) = engine() else { return };
        let stats = vec![1.0f32; 8];
        let weights = vec![1.0f32; 8];
        let (score, go) = e.al_decision(&stats, &weights, 0.0, 0.5).unwrap();
        assert!(score > 0.99);
        assert!(go);
        let (score2, go2) = e.al_decision(&stats, &vec![-1.0f32; 8], 0.0, 0.5).unwrap();
        assert!(score2 < 0.01);
        assert!(!go2);
    }

    #[test]
    fn gp_propose_empty_history_prior() {
        let Some(e) = engine() else { return };
        let spec = e.spec("gp_propose").unwrap().clone();
        let n_obs = spec.consts["n_obs"] as usize;
        let dim = spec.consts["dim"] as usize;
        let n_cand = spec.consts["n_cand"] as usize;
        let p = e
            .gp_propose(
                &vec![0.0; n_obs * dim],
                &vec![0.0; n_obs],
                &vec![0.0; n_obs],
                &vec![0.5; n_cand * dim],
                &[0.0, 0.0, (1e-2f32).ln(), 0.01],
            )
            .unwrap();
        assert_eq!(p.mu.len(), n_cand);
        // prior: mean 0, var sigma_f^2 = 1
        assert!(p.mu.iter().all(|m| m.abs() < 1e-4));
        assert!(p.var.iter().all(|v| (v - 1.0).abs() < 1e-2));
        assert!(p.ei.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn gp_propose_prefers_region_near_good_observation() {
        let Some(e) = engine() else { return };
        let spec = e.spec("gp_propose").unwrap().clone();
        let n_obs = spec.consts["n_obs"] as usize;
        let dim = spec.consts["dim"] as usize;
        let n_cand = spec.consts["n_cand"] as usize;
        // two observations: loss 0 at origin, loss 1 at (2,2,...)
        let mut x_obs = vec![0.0f32; n_obs * dim];
        for d in 0..dim {
            x_obs[dim + d] = 2.0;
        }
        let mut y_obs = vec![0.0f32; n_obs];
        y_obs[1] = 1.0;
        let mut mask = vec![0.0f32; n_obs];
        mask[0] = 1.0;
        mask[1] = 1.0;
        // candidates: half near origin, half near (2,...)
        let mut x_cand = vec![0.0f32; n_cand * dim];
        for c in n_cand / 2..n_cand {
            for d in 0..dim {
                x_cand[c * dim + d] = 2.0;
            }
        }
        let p = e
            .gp_propose(&x_obs, &y_obs, &mask, &x_cand, &[0.0, 0.0, (1e-4f32).ln(), 0.01])
            .unwrap();
        // posterior mean near origin ~0 (good), near far point ~1 (bad)
        assert!(p.mu[0] < 0.2, "mu near good obs: {}", p.mu[0]);
        assert!(p.mu[n_cand - 1] > 0.8, "mu near bad obs: {}", p.mu[n_cand - 1]);
    }

    #[test]
    fn mlp_train_objective_responds_to_lr() {
        let Some(e) = engine() else { return };
        let spec = e.spec("mlp_train").unwrap().clone();
        let train_n = spec.consts["train_n"] as usize;
        let val_n = spec.consts["val_n"] as usize;
        let in_dim = spec.consts["in_dim"] as usize;
        let hidden = spec.consts["hidden"] as usize;
        let mut rng = crate::util::rng::Rng::new(42);
        let mut mk = |n: usize, scale: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.normal() * scale) as f32).collect()
        };
        let xtr = mk(train_n * in_dim, 1.0);
        let xval = mk(val_n * in_dim, 1.0);
        let w1 = mk(in_dim * hidden, 0.3);
        let w2 = mk(hidden, 0.3);
        let ytr: Vec<f32> = (0..train_n)
            .map(|i| (xtr[i * in_dim] * 2.0).sin() + 0.5 * xtr[i * in_dim + 1])
            .collect();
        let yval: Vec<f32> = (0..val_n)
            .map(|i| (xval[i * in_dim] * 2.0).sin() + 0.5 * xval[i * in_dim + 1])
            .collect();
        let b1 = vec![0.0f32; hidden];
        let b2 = vec![0.0f32; 1];

        let run = |log_lr: f32| {
            e.mlp_train(
                &[log_lr, 0.9, (1e-6f32).ln(), (5.0f32).ln()],
                &xtr, &ytr, &xval, &yval, &w1, &b1, &w2, &b2,
            )
            .unwrap()
        };
        let tiny = run((1e-9f32).ln());
        let sane = run((0.05f32).ln());
        assert!(
            sane.val_loss < tiny.val_loss * 0.8,
            "training with sane lr must reduce loss: {} vs {}",
            sane.val_loss,
            tiny.val_loss
        );
        // deterministic
        let again = run((0.05f32).ln());
        assert_eq!(again, sane);
    }

    #[test]
    fn execute_f32_shape_validation() {
        let Some(e) = engine() else { return };
        let err = e
            .execute_f32("al_decision", &[&[1.0f32; 3]])
            .unwrap_err();
        assert!(format!("{err}").contains("expected"));
        assert!(e.execute_f32("nope", &[]).is_err());
    }
}
