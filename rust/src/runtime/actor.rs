//! Thread-safe handle over the PJRT [`Engine`].
//!
//! The `xla` crate's client/executable types hold `Rc`s and raw pointers —
//! they are neither `Send` nor `Sync` — but the iDDS daemons execute
//! payloads from a worker pool. [`EngineHandle`] runs the Engine on a
//! dedicated actor thread and forwards calls over a channel; the handle
//! itself is cheap to clone and fully `Send + Sync`. Execution requests
//! are serialized at the actor (PJRT's CPU backend parallelizes *inside*
//! each execution), which measurements in EXPERIMENTS.md §Perf show is not
//! the bottleneck for the HPO service.

use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{Context, Result};

use super::manifest::{EntrySpec, Manifest};
use super::{Engine, Proposal, TrainOutcome};

enum Call {
    Execute {
        entry: String,
        inputs: Vec<Vec<f32>>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    Shutdown,
}

/// Cloneable, thread-safe engine facade.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Arc<Mutex<mpsc::Sender<Call>>>,
    manifest: Arc<Manifest>,
    _joiner: Arc<Joiner>,
}

struct Joiner {
    tx: mpsc::Sender<Call>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Drop for Joiner {
    fn drop(&mut self) {
        let _ = self.tx.send(Call::Shutdown);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl EngineHandle {
    /// Load the artifacts on a dedicated actor thread.
    pub fn start(dir: &std::path::Path) -> Result<EngineHandle> {
        let manifest = Arc::new(Manifest::load(&dir.join("manifest.json"))?);
        let dir: PathBuf = dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Call>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-actor".into())
            .spawn(move || {
                let engine = match Engine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(call) = rx.recv() {
                    match call {
                        Call::Execute { entry, inputs, reply } => {
                            let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
                            let _ = reply.send(engine.execute_f32(&entry, &refs));
                        }
                        Call::Shutdown => break,
                    }
                }
            })
            .context("spawn pjrt actor")?;
        ready_rx
            .recv()
            .context("pjrt actor died during load")??;
        Ok(EngineHandle {
            tx: Arc::new(Mutex::new(tx.clone())),
            manifest,
            _joiner: Arc::new(Joiner {
                tx,
                handle: Mutex::new(Some(handle)),
            }),
        })
    }

    pub fn spec(&self, entry: &str) -> Option<&EntrySpec> {
        self.manifest.entries.get(entry)
    }

    pub fn entry_names(&self) -> Vec<String> {
        self.manifest.entries.keys().cloned().collect()
    }

    pub fn execute_f32(&self, entry: &str, inputs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Call::Execute {
                entry: entry.to_string(),
                inputs,
                reply,
            })
            .context("pjrt actor gone")?;
        rx.recv().context("pjrt actor dropped reply")?
    }

    /// See [`Engine::gp_propose`].
    pub fn gp_propose(
        &self,
        x_obs: &[f32],
        y_obs: &[f32],
        mask: &[f32],
        x_cand: &[f32],
        params: &[f32; 4],
    ) -> Result<Proposal> {
        let outs = self.execute_f32(
            "gp_propose",
            vec![
                x_obs.to_vec(),
                y_obs.to_vec(),
                mask.to_vec(),
                x_cand.to_vec(),
                params.to_vec(),
            ],
        )?;
        let mut it = outs.into_iter();
        Ok(Proposal {
            mu: it.next().unwrap(),
            var: it.next().unwrap(),
            ei: it.next().unwrap(),
        })
    }

    /// See [`Engine::mlp_train`].
    #[allow(clippy::too_many_arguments)]
    pub fn mlp_train(
        &self,
        hparams: &[f32; 4],
        xtr: &[f32],
        ytr: &[f32],
        xval: &[f32],
        yval: &[f32],
        w1: &[f32],
        b1: &[f32],
        w2: &[f32],
        b2: &[f32],
    ) -> Result<TrainOutcome> {
        let outs = self.execute_f32(
            "mlp_train",
            vec![
                hparams.to_vec(),
                xtr.to_vec(),
                ytr.to_vec(),
                xval.to_vec(),
                yval.to_vec(),
                w1.to_vec(),
                b1.to_vec(),
                w2.to_vec(),
                b2.to_vec(),
            ],
        )?;
        Ok(TrainOutcome {
            val_loss: outs[0][0],
            train_loss: outs[1][0],
        })
    }

    /// See [`Engine::al_decision`].
    pub fn al_decision(
        &self,
        stats: &[f32],
        weights: &[f32],
        bias: f32,
        threshold: f32,
    ) -> Result<(f32, bool)> {
        let outs = self.execute_f32(
            "al_decision",
            vec![stats.to_vec(), weights.to_vec(), vec![bias], vec![threshold]],
        )?;
        Ok((outs[0][0], outs[1][0] > 0.5))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifacts_dir;

    #[test]
    fn handle_is_send_sync_and_works_across_threads() {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts missing; run `make artifacts`");
            return;
        }
        let h = EngineHandle::start(&dir).unwrap();
        fn assert_send_sync<T: Send + Sync>(_: &T) {}
        assert_send_sync(&h);
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let h = h.clone();
                std::thread::spawn(move || {
                    let stats = vec![i as f32; 8];
                    let weights = vec![1.0f32; 8];
                    h.al_decision(&stats, &weights, 0.0, 0.5).unwrap()
                })
            })
            .collect();
        for t in handles {
            let (score, _) = t.join().unwrap();
            assert!((0.0..=1.0).contains(&score));
        }
    }
}
