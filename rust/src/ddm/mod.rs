//! Distributed Data Management simulator (Rucio stand-in).
//!
//! Models the slice of a DDM system the paper's workflows exercise:
//! datasets of tape-resident files, a disk buffer in front of the tape
//! system, staging rules at **dataset** granularity (the pre-iDDS coarse
//! carousel) or **file** granularity (the iDDS fine carousel), a replica
//! catalog, and disk-cache accounting (current + peak occupancy — the
//! paper's "minimize the input data footprint on disk" claim is measured
//! directly off these counters).
//!
//! Time is explicit (`tick(now)`), driven by the discrete-event loop; the
//! actual tape mechanics live in [`crate::tape::TapeSystem`].

use std::collections::{HashMap, HashSet};

use crate::tape::{CartridgeId, FileId, TapeSystem};

/// One cataloged file: identity, size, and the dataset it belongs to
/// (staging rules act on datasets in coarse mode).
#[derive(Debug, Clone)]
pub struct DdmFile {
    pub id: FileId,
    pub name: String,
    pub size_bytes: u64,
    pub dataset: String,
}

/// Where a file's only accessible replica currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    /// Only the tape copy exists; reading it requires a recall.
    TapeOnly,
    /// A recall is queued or in flight on the tape system.
    Staging,
    /// A disk replica exists in the buffer and is deliverable.
    OnDisk,
}

/// A staging completion visible to iDDS.
#[derive(Debug, Clone, PartialEq)]
pub struct StagedFile {
    pub file: FileId,
    pub at: f64,
}

/// Disk-buffer occupancy accounting — the quantity behind the paper's
/// "minimize the input data footprint on disk" claim (Fig. 5).
#[derive(Debug, Clone, Copy, Default)]
pub struct DiskStats {
    pub used_bytes: u64,
    pub peak_bytes: u64,
    /// byte-seconds integral of occupancy (mean footprint = integral / T)
    pub byte_seconds: f64,
}

/// The DDM simulator: file/dataset catalog, replica states, staging rules
/// at dataset or file granularity, and the disk buffer in front of the
/// tape system.
pub struct DdmSystem {
    files: HashMap<FileId, DdmFile>,
    datasets: HashMap<String, Vec<FileId>>,
    replicas: HashMap<FileId, ReplicaState>,
    tape: TapeSystem,
    disk: DiskStats,
    last_disk_t: f64,
    staged_total: u64,
    released_total: u64,
    requested: HashSet<FileId>,
}

impl DdmSystem {
    pub fn new(tape: TapeSystem) -> Self {
        DdmSystem {
            files: HashMap::new(),
            datasets: HashMap::new(),
            replicas: HashMap::new(),
            tape,
            disk: DiskStats::default(),
            last_disk_t: 0.0,
            staged_total: 0,
            released_total: 0,
            requested: HashSet::new(),
        }
    }

    /// Register a dataset of tape-resident files. Returns file ids in
    /// registration order.
    pub fn register_dataset(
        &mut self,
        dataset: &str,
        files: impl IntoIterator<Item = (String, u64, CartridgeId)>,
    ) -> Vec<FileId> {
        let mut ids = Vec::new();
        for (name, size, cart) in files {
            let id = crate::util::next_id();
            self.tape.register_file(id, cart, size);
            self.files.insert(
                id,
                DdmFile {
                    id,
                    name,
                    size_bytes: size,
                    dataset: dataset.to_string(),
                },
            );
            self.replicas.insert(id, ReplicaState::TapeOnly);
            self.datasets.entry(dataset.to_string()).or_default().push(id);
            ids.push(id);
        }
        ids
    }

    /// File ids of a dataset, in registration order.
    pub fn dataset_files(&self, dataset: &str) -> Vec<FileId> {
        self.datasets.get(dataset).cloned().unwrap_or_default()
    }

    /// Catalog lookup by file id.
    pub fn file(&self, id: FileId) -> Option<&DdmFile> {
        self.files.get(&id)
    }

    /// Current replica state of a file (`None` for unknown ids).
    pub fn replica_state(&self, id: FileId) -> Option<ReplicaState> {
        self.replicas.get(&id).copied()
    }

    /// True when a disk replica exists — the availability predicate the
    /// WFM's dispatch checks (see `crate::wfm::WfmSim::tick`).
    pub fn is_on_disk(&self, id: FileId) -> bool {
        self.replica_state(id) == Some(ReplicaState::OnDisk)
    }

    /// Coarse staging rule: recall the whole dataset at once (the pre-iDDS
    /// carousel). Idempotent per file.
    pub fn stage_dataset(&mut self, dataset: &str, now: f64) -> usize {
        let ids = self.dataset_files(dataset);
        self.stage_files(&ids, now)
    }

    /// Fine staging rule: recall specific files (the iDDS carousel).
    /// Returns how many recalls were actually queued (idempotent).
    pub fn stage_files(&mut self, ids: &[FileId], now: f64) -> usize {
        let mut n = 0;
        for &id in ids {
            if self.replicas.get(&id) == Some(&ReplicaState::TapeOnly)
                && self.requested.insert(id)
            {
                self.replicas.insert(id, ReplicaState::Staging);
                self.tape.request_recall(id, now);
                n += 1;
            }
        }
        n
    }

    /// Advance to `now`; newly staged files land on the disk buffer.
    pub fn tick(&mut self, now: f64) -> Vec<StagedFile> {
        let done = self.tape.tick(now);
        let mut out = Vec::with_capacity(done.len());
        for r in done {
            let size = self.files[&r.file].size_bytes;
            self.account_disk(r.at);
            self.disk.used_bytes += size;
            self.disk.peak_bytes = self.disk.peak_bytes.max(self.disk.used_bytes);
            self.replicas.insert(r.file, ReplicaState::OnDisk);
            self.staged_total += 1;
            out.push(StagedFile {
                file: r.file,
                at: r.at,
            });
        }
        out
    }

    /// Fine-grained cache release (processed data leaves the buffer
    /// promptly — paper section 3.1). No-op unless the file is on disk.
    pub fn release_file(&mut self, id: FileId, now: f64) -> bool {
        if self.replicas.get(&id) == Some(&ReplicaState::OnDisk) {
            let size = self.files[&id].size_bytes;
            self.account_disk(now);
            self.disk.used_bytes = self.disk.used_bytes.saturating_sub(size);
            self.replicas.insert(id, ReplicaState::TapeOnly);
            self.requested.remove(&id);
            self.released_total += 1;
            true
        } else {
            false
        }
    }

    fn account_disk(&mut self, now: f64) {
        if now > self.last_disk_t {
            self.disk.byte_seconds += self.disk.used_bytes as f64 * (now - self.last_disk_t);
            self.last_disk_t = now;
        }
    }

    /// Flush occupancy accounting up to `now` (call at end of run).
    pub fn finalize_accounting(&mut self, now: f64) {
        self.account_disk(now);
    }

    /// Current/peak/integrated disk-buffer occupancy.
    pub fn disk_stats(&self) -> DiskStats {
        self.disk
    }

    /// Counters of the underlying tape library (mounts, recalls, bytes).
    pub fn tape_stats(&self) -> crate::tape::TapeStats {
        self.tape.stats()
    }

    /// Files that have landed on disk over the whole run.
    pub fn staged_total(&self) -> u64 {
        self.staged_total
    }

    /// Files released from the disk buffer over the whole run.
    pub fn released_total(&self) -> u64 {
        self.released_total
    }

    /// Earliest future tape event — the discrete-event loop's next wakeup.
    pub fn next_event_time(&self) -> Option<f64> {
        self.tape.next_event_time()
    }

    /// Recalls queued or in flight on the tape system.
    pub fn pending_staging(&self) -> usize {
        self.tape.pending_recalls()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ddm() -> DdmSystem {
        DdmSystem::new(TapeSystem::new(2, 60.0, 10.0, 100.0))
    }

    fn one_gb_files(n: usize, carts: u32) -> Vec<(String, u64, CartridgeId)> {
        (0..n)
            .map(|i| (format!("f{i}"), 1_000_000_000, (i as u32) % carts))
            .collect()
    }

    #[test]
    fn register_and_lookup() {
        let mut d = ddm();
        let ids = d.register_dataset("data18", one_gb_files(10, 2));
        assert_eq!(ids.len(), 10);
        assert_eq!(d.dataset_files("data18"), ids);
        assert_eq!(d.replica_state(ids[0]), Some(ReplicaState::TapeOnly));
        assert_eq!(d.file(ids[0]).unwrap().dataset, "data18");
    }

    #[test]
    fn coarse_staging_queues_everything() {
        let mut d = ddm();
        let ids = d.register_dataset("ds", one_gb_files(10, 2));
        assert_eq!(d.stage_dataset("ds", 0.0), 10);
        assert!(ids.iter().all(|&i| d.replica_state(i) == Some(ReplicaState::Staging)));
        // idempotent
        assert_eq!(d.stage_dataset("ds", 0.0), 0);
    }

    #[test]
    fn staged_files_land_on_disk_and_peak_tracks() {
        let mut d = ddm();
        let ids = d.register_dataset("ds", one_gb_files(4, 1));
        d.stage_files(&ids, 0.0);
        let staged = d.tick(1e6);
        assert_eq!(staged.len(), 4);
        assert!(ids.iter().all(|&i| d.is_on_disk(i)));
        assert_eq!(d.disk_stats().used_bytes, 4_000_000_000);
        assert_eq!(d.disk_stats().peak_bytes, 4_000_000_000);
    }

    #[test]
    fn release_shrinks_cache_but_not_peak() {
        let mut d = ddm();
        let ids = d.register_dataset("ds", one_gb_files(2, 1));
        d.stage_files(&ids, 0.0);
        d.tick(1e6);
        assert!(d.release_file(ids[0], 1e6));
        assert_eq!(d.disk_stats().used_bytes, 1_000_000_000);
        assert_eq!(d.disk_stats().peak_bytes, 2_000_000_000);
        // double release is a no-op
        assert!(!d.release_file(ids[0], 1e6));
        assert_eq!(d.released_total(), 1);
    }

    #[test]
    fn released_file_can_be_restaged() {
        let mut d = ddm();
        let ids = d.register_dataset("ds", one_gb_files(1, 1));
        d.stage_files(&ids, 0.0);
        d.tick(1e6);
        d.release_file(ids[0], 1e6);
        assert_eq!(d.stage_files(&ids, 1e6), 1);
        let staged = d.tick(2e6);
        assert_eq!(staged.len(), 1);
        assert!(d.is_on_disk(ids[0]));
    }

    #[test]
    fn byte_seconds_integrates_occupancy() {
        let mut d = DdmSystem::new(TapeSystem::new(1, 0.0, 0.0, 1000.0));
        let ids = d.register_dataset("ds", vec![("a".into(), 1_000_000_000, 0)]);
        d.stage_files(&ids, 0.0);
        // lands at t = 1.0 (1 GB at 1 GB/s)
        d.tick(10.0);
        d.release_file(ids[0], 11.0);
        d.finalize_accounting(20.0);
        // occupied 1 GB from t=1 to t=11 -> 1e10 byte-seconds; zero after
        assert!((d.disk_stats().byte_seconds - 1e10).abs() / 1e10 < 1e-6);
    }

    #[test]
    fn fine_staging_partial() {
        let mut d = ddm();
        let ids = d.register_dataset("ds", one_gb_files(10, 2));
        assert_eq!(d.stage_files(&ids[..3], 0.0), 3);
        let staged = d.tick(1e6);
        assert_eq!(staged.len(), 3);
        assert_eq!(d.disk_stats().used_bytes, 3_000_000_000);
        assert_eq!(d.replica_state(ids[5]), Some(ReplicaState::TapeOnly));
    }
}
