//! Metrics: counters, gauges, histograms, and the campaign timeline
//! recorder that backs the Figure 4 / Figure 5 outputs.
//!
//! Names are dotted lowercase (`pipeline.*`, `workflow.*`, `persist.*`,
//! `replication.*`, `rest.*`); the full naming inventory lives in
//! DESIGN.md's "Observability" section. Everything lands in the shared
//! [`Registry`], exposed by `GET /api/metrics` (JSON snapshot) and
//! `GET /api/metrics?format=prometheus` ([`Registry::render_prometheus`]).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::util::json::Json;

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Signed gauge.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket histogram (log2 buckets over nanoseconds/values).
/// Bucket `i` (for `i >= 1`) holds values in `[2^(i-1), 2^i - 1]`;
/// bucket 0 holds only zero.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, v: u64) {
        let idx = (64 - v.leading_zeros()).min(63) as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observed values (Prometheus `_sum`).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Smallest observed value (0 when empty).
    pub fn min(&self) -> u64 {
        let m = self.min.load(Ordering::Relaxed);
        if m == u64::MAX && self.count() == 0 { 0 } else { m }
    }

    /// Largest observed value.
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Raw per-bucket counts (index = log2 bucket).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile: the upper bound of the bucket containing
    /// the q-th sample, clamped into `[min, max]` of the observed
    /// values — so a single sample in the top bucket reports that
    /// sample's magnitude, not `u64::MAX`, and no quantile can exceed
    /// the largest value actually seen.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let lo = self.min.load(Ordering::Relaxed).min(self.max.load(Ordering::Relaxed));
        let hi = self.max.load(Ordering::Relaxed);
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                let ub = if i >= 63 { u64::MAX } else { 1u64 << i };
                return ub.clamp(lo, hi);
            }
        }
        hi
    }
}

/// Named metrics registry shared across daemons.
#[derive(Default, Clone)]
pub struct Registry {
    inner: Arc<RegistryInner>,
}

#[derive(Default)]
struct RegistryInner {
    counters: RwLock<BTreeMap<String, Arc<Counter>>>,
    gauges: RwLock<BTreeMap<String, Arc<Gauge>>>,
    histograms: RwLock<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        if let Some(c) = self.inner.counters.read().unwrap().get(name) {
            return Arc::clone(c);
        }
        let mut w = self.inner.counters.write().unwrap();
        Arc::clone(w.entry(name.to_string()).or_insert_with(|| Arc::new(Counter::default())))
    }

    /// Counter for a daemon's change-driven poll skips — ticks where the
    /// store generations were unchanged and the daemon touched no table
    /// lock. Standardized naming: `pipeline.<daemon>.poll_skips`.
    pub fn poll_skip_counter(&self, daemon: &str) -> Arc<Counter> {
        self.counter(&format!("pipeline.{daemon}.poll_skips"))
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        if let Some(g) = self.inner.gauges.read().unwrap().get(name) {
            return Arc::clone(g);
        }
        let mut w = self.inner.gauges.write().unwrap();
        Arc::clone(w.entry(name.to_string()).or_insert_with(|| Arc::new(Gauge::default())))
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        if let Some(h) = self.inner.histograms.read().unwrap().get(name) {
            return Arc::clone(h);
        }
        let mut w = self.inner.histograms.write().unwrap();
        Arc::clone(
            w.entry(name.to_string())
                .or_insert_with(|| Arc::new(Histogram::default())),
        )
    }

    /// `(name, value)` of every counter whose name starts with `prefix`
    /// (the `/api/health` per-route rollup).
    pub fn counters_with_prefix(&self, prefix: &str) -> Vec<(String, u64)> {
        self.inner
            .counters
            .read()
            .unwrap()
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.clone(), v.get()))
            .collect()
    }

    /// Prometheus text exposition (`GET /api/metrics?format=prometheus`):
    /// counters and gauges verbatim, histograms as cumulative
    /// `_bucket{le="..."}` series over the log2 bucket bounds plus
    /// `_sum`/`_count`. Dotted names map to legal metric names by
    /// replacing every non-`[a-zA-Z0-9_:]` byte with `_` under an
    /// `idds_` prefix.
    pub fn render_prometheus(&self) -> String {
        fn prom_name(k: &str) -> String {
            let mut out = String::with_capacity(k.len() + 5);
            out.push_str("idds_");
            for ch in k.chars() {
                if ch.is_ascii_alphanumeric() || ch == '_' || ch == ':' {
                    out.push(ch);
                } else {
                    out.push('_');
                }
            }
            out
        }
        use std::fmt::Write as _;
        let mut out = String::new();
        for (k, v) in self.inner.counters.read().unwrap().iter() {
            let name = prom_name(k);
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {}", v.get());
        }
        for (k, v) in self.inner.gauges.read().unwrap().iter() {
            let name = prom_name(k);
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {}", v.get());
        }
        for (k, v) in self.inner.histograms.read().unwrap().iter() {
            let name = prom_name(k);
            let _ = writeln!(out, "# TYPE {name} histogram");
            let counts = v.bucket_counts();
            let last = counts.iter().rposition(|&c| c > 0).unwrap_or(0);
            let mut cum = 0u64;
            // bucket i's largest member is 2^i - 1 (bucket 63 has no
            // finite bound and lands in +Inf only)
            for (i, &c) in counts.iter().enumerate().take(last + 1).take(63) {
                cum += c;
                let le = (1u64 << i) - 1;
                let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cum}");
            }
            let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", v.count());
            let _ = writeln!(out, "{name}_sum {}", v.sum());
            let _ = writeln!(out, "{name}_count {}", v.count());
        }
        out
    }

    pub fn snapshot(&self) -> Json {
        let mut obj = Json::obj();
        for (k, v) in self.inner.counters.read().unwrap().iter() {
            obj = obj.set(&format!("counter.{k}"), v.get());
        }
        for (k, v) in self.inner.gauges.read().unwrap().iter() {
            obj = obj.set(&format!("gauge.{k}"), v.get() as f64);
        }
        for (k, v) in self.inner.histograms.read().unwrap().iter() {
            obj = obj.set(
                &format!("hist.{k}"),
                Json::obj()
                    .set("count", v.count())
                    .set("mean", v.mean())
                    .set("p50", v.quantile(0.5))
                    .set("p99", v.quantile(0.99)),
            );
        }
        obj
    }
}

/// One bounded series: when `pts` reaches the cap, every second point
/// is dropped and the keep-stride doubles, so a series that runs
/// forever keeps a uniformly thinned history in `[cap/2, cap]` points.
#[derive(Default)]
struct Series {
    pts: Vec<(f64, f64)>,
    /// Keep pushes whose index is a multiple of `2^halvings`. Keying
    /// the stride off the global push index (not a since-last-kept
    /// counter) keeps retained samples uniformly spaced across a
    /// halving boundary: the survivors of a halve are exactly the
    /// pushes divisible by the doubled stride.
    halvings: u32,
    pushes: u64,
}

impl Series {
    fn push(&mut self, t: f64, v: f64, cap: usize) {
        let n = self.pushes;
        self.pushes += 1;
        if n % (1u64 << self.halvings.min(63)) != 0 {
            return;
        }
        self.pts.push((t, v));
        if cap > 1 && self.pts.len() >= cap {
            let mut i = 0;
            self.pts.retain(|_| {
                let keep = i % 2 == 0;
                i += 1;
                keep
            });
            self.halvings += 1;
        }
    }
}

/// Time-series recorder for campaign plots (Fig. 5): named series of
/// (t, value) samples. Per-series memory is bounded by `max_points`
/// (`obs.timeline.max_points`, default 65536) with stride-doubling
/// downsampling on insert.
#[derive(Clone)]
pub struct Timeline {
    inner: Arc<TimelineInner>,
}

struct TimelineInner {
    series: Mutex<BTreeMap<String, Series>>,
    max_points: AtomicU64,
}

impl Default for Timeline {
    fn default() -> Self {
        Timeline {
            inner: Arc::new(TimelineInner {
                series: Mutex::new(BTreeMap::new()),
                max_points: AtomicU64::new(65536),
            }),
        }
    }
}

impl std::fmt::Debug for Timeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let names = self.names();
        write!(f, "Timeline({} series)", names.len())
    }
}

impl Timeline {
    /// Cap every series at `n` retained points (shared by all clones).
    pub fn set_max_points(&self, n: usize) {
        self.inner.max_points.store(n.max(2) as u64, Ordering::Relaxed);
    }

    pub fn record(&self, series: &str, t: f64, v: f64) {
        let cap = self.inner.max_points.load(Ordering::Relaxed) as usize;
        self.inner
            .series
            .lock()
            .unwrap()
            .entry(series.to_string())
            .or_default()
            .push(t, v, cap);
    }

    pub fn series(&self, name: &str) -> Vec<(f64, f64)> {
        self.inner
            .series
            .lock()
            .unwrap()
            .get(name)
            .map(|s| s.pts.clone())
            .unwrap_or_default()
    }

    pub fn names(&self) -> Vec<String> {
        self.inner.series.lock().unwrap().keys().cloned().collect()
    }

    /// Downsample a series to at most `n` points (for terminal plots).
    pub fn downsample(&self, name: &str, n: usize) -> Vec<(f64, f64)> {
        let s = self.series(name);
        if s.len() <= n || n == 0 {
            return s;
        }
        let stride = s.len() as f64 / n as f64;
        (0..n)
            .map(|i| s[((i as f64 * stride) as usize).min(s.len() - 1)])
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let guard = self.inner.series.lock().unwrap();
        let mut obj = Json::obj();
        for (k, s) in guard.iter() {
            obj = obj.set(
                k,
                Json::Arr(
                    s.pts
                        .iter()
                        .map(|(t, v)| Json::Arr(vec![Json::Num(*t), Json::Num(*v)]))
                        .collect(),
                ),
            );
        }
        obj
    }

    /// Render an ASCII sparkline-style plot of a series (used by example
    /// binaries to "draw" Fig. 5 in the terminal).
    pub fn ascii_plot(&self, name: &str, width: usize, height: usize) -> String {
        let pts = self.downsample(name, width);
        if pts.is_empty() {
            return format!("{name}: (no data)\n");
        }
        let (min_v, max_v) = pts
            .iter()
            .fold((f64::MAX, f64::MIN), |(lo, hi), (_, v)| (lo.min(*v), hi.max(*v)));
        let span = (max_v - min_v).max(1e-12);
        let mut grid = vec![vec![b' '; pts.len()]; height];
        for (x, (_, v)) in pts.iter().enumerate() {
            let y = (((v - min_v) / span) * (height - 1) as f64).round() as usize;
            grid[height - 1 - y][x] = b'*';
        }
        let mut out = format!("{name}  [{min_v:.3e} .. {max_v:.3e}]\n");
        for row in grid {
            out.push('|');
            out.push_str(std::str::from_utf8(&row).unwrap());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_histogram() {
        let r = Registry::default();
        r.counter("a").inc();
        r.counter("a").add(4);
        assert_eq!(r.counter("a").get(), 5);
        r.gauge("g").set(-3);
        r.gauge("g").add(1);
        assert_eq!(r.gauge("g").get(), -2);
        let h = r.histogram("h");
        for v in [1u64, 2, 4, 100, 1000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() > 0.0);
        assert!(h.quantile(0.5) >= 2);
        assert!(h.quantile(1.0) >= 1000);
    }

    #[test]
    fn registry_is_shared() {
        let r = Registry::default();
        let c1 = r.counter("x");
        let c2 = r.counter("x");
        c1.inc();
        assert_eq!(c2.get(), 1);
        let snap = r.snapshot();
        assert_eq!(snap.get("counter.x").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn timeline_series_and_downsample() {
        let t = Timeline::default();
        for i in 0..1000 {
            t.record("disk", i as f64, (i * 2) as f64);
        }
        assert_eq!(t.series("disk").len(), 1000);
        let d = t.downsample("disk", 50);
        assert_eq!(d.len(), 50);
        assert_eq!(d[0], (0.0, 0.0));
        let plot = t.ascii_plot("disk", 40, 8);
        assert!(plot.contains('*'));
        assert_eq!(t.names(), vec!["disk".to_string()]);
    }

    #[test]
    fn histogram_quantile_clamps_to_observed_range() {
        // v = 0: lives in bucket 0, must report 0 (not the bucket's
        // nominal upper bound of 1)
        let h = Histogram::default();
        h.observe(0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.quantile(1.0), 0);
        assert_eq!((h.min(), h.max()), (0, 0));

        // v = 1: bucket 1's bound is 2, clamp brings it back to 1
        let h = Histogram::default();
        h.observe(1);
        assert_eq!(h.quantile(0.99), 1);

        // v = u64::MAX: the old code was "right" here, and the clamp
        // must not break it
        let h = Histogram::default();
        h.observe(u64::MAX);
        assert_eq!(h.quantile(0.5), u64::MAX);
        assert_eq!(h.max(), u64::MAX);

        // one mid-range sample: before the fix this reported the
        // bucket bound (1024), a 2.4% overshoot — now the exact max
        let h = Histogram::default();
        h.observe(1000);
        assert_eq!(h.quantile(0.5), 1000);
        assert_eq!(h.quantile(1.0), 1000);

        // mixed: no quantile may exceed the largest observed value
        let h = Histogram::default();
        for v in [3u64, 900, 70_000] {
            h.observe(v);
        }
        assert!(h.quantile(0.99) <= 70_000);
        assert!(h.quantile(0.0) >= 3);
        assert_eq!(h.sum(), 70_903);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!((h.min(), h.max(), h.sum()), (0, 0, 0));
    }

    #[test]
    fn timeline_bounded_by_stride_doubling() {
        let t = Timeline::default();
        t.set_max_points(64);
        for i in 0..10_000 {
            t.record("s", i as f64, i as f64);
        }
        let pts = t.series("s");
        assert!(pts.len() <= 64, "cap held: {}", pts.len());
        assert!(pts.len() >= 32, "at least half the cap retained: {}", pts.len());
        assert_eq!(pts[0], (0.0, 0.0), "first sample survives halving");
        for w in pts.windows(2) {
            assert!(w[0].0 < w[1].0, "time stays monotone");
        }
        // spacing is uniform (one stride) apart from rounding
        let stride = pts[1].0 - pts[0].0;
        for w in pts.windows(2) {
            assert_eq!(w[1].0 - w[0].0, stride);
        }
        // downsample still behaves on a bounded series
        let d = t.downsample("s", 10);
        assert_eq!(d.len(), 10);
        assert_eq!(d[0], (0.0, 0.0));
    }

    #[test]
    fn prometheus_exposition_golden() {
        let r = Registry::default();
        r.counter("rest.requests").add(7);
        r.gauge("replication.lag_lsn").set(-2);
        let h = r.histogram("rest.route.GET.api_health.latency_us");
        for v in [1u64, 2, 4, 100, 1000] {
            h.observe(v);
        }
        let text = r.render_prometheus();
        // every sample line: legal name, single space, numeric value
        let mut bucket_counts: Vec<u64> = Vec::new();
        let mut inf = None;
        let (mut sum, mut count) = (None, None);
        for line in text.lines() {
            if line.starts_with("# TYPE ") {
                let mut parts = line[7..].split(' ');
                let (name, kind) = (parts.next().unwrap(), parts.next().unwrap());
                assert!(matches!(kind, "counter" | "gauge" | "histogram"), "{line}");
                assert!(name.starts_with("idds_"), "{line}");
                continue;
            }
            let (name, value) = line.split_once(' ').expect("name value");
            let bare = name.split('{').next().unwrap();
            assert!(
                bare.chars().next().unwrap().is_ascii_alphabetic() || bare.starts_with('_'),
                "{line}"
            );
            assert!(
                bare.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "illegal metric name in {line}"
            );
            assert!(value.parse::<f64>().is_ok(), "unparseable value in {line}");
            if bare == "idds_rest_route_GET_api_health_latency_us_bucket" {
                if name.contains("+Inf") {
                    inf = Some(value.parse::<u64>().unwrap());
                } else {
                    bucket_counts.push(value.parse().unwrap());
                }
            }
            if bare == "idds_rest_route_GET_api_health_latency_us_sum" {
                sum = Some(value.parse::<u64>().unwrap());
            }
            if bare == "idds_rest_route_GET_api_health_latency_us_count" {
                count = Some(value.parse::<u64>().unwrap());
            }
        }
        assert!(text.contains("idds_rest_requests 7"));
        assert!(text.contains("idds_replication_lag_lsn -2"));
        for w in bucket_counts.windows(2) {
            assert!(w[0] <= w[1], "bucket counts must be cumulative");
        }
        assert_eq!(inf, Some(5), "+Inf bucket equals the sample count");
        assert_eq!(count, Some(5));
        assert_eq!(sum, Some(1107));
        assert_eq!(
            *bucket_counts.last().unwrap(),
            5,
            "last finite bucket covers all 5 samples (max is 1000 < 1023)"
        );
    }

    #[test]
    fn counters_with_prefix_filters() {
        let r = Registry::default();
        r.counter("rest.route.a.requests").inc();
        r.counter("rest.route.b.requests").add(2);
        r.counter("pipeline.ticks").inc();
        let got = r.counters_with_prefix("rest.route.");
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, "rest.route.a.requests");
    }

    #[test]
    fn timeline_json_shape() {
        let t = Timeline::default();
        t.record("s", 1.0, 2.0);
        let j = t.to_json();
        let arr = j.get("s").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_arr().unwrap()[1].as_f64(), Some(2.0));
    }
}
