//! Metrics: counters, gauges, histograms, and the campaign timeline
//! recorder that backs the Figure 4 / Figure 5 outputs.
//!
//! Naming inventory (dotted, lowercase): `pipeline.*` for daemon
//! progress (`works_generated`, `transforms_marshalled`,
//! `requests_finalized`, `<daemon>.poll_skips`, ...), `workflow.*` for
//! the engine (`registry.hits`/`registry.misses` — compiled-workflow
//! intern outcomes; `engine.condition_evals` — out-edges evaluated per
//! completion; `engine.edges_fired`), `persist.*` for WAL/checkpoint
//! durability, `replication.*` for WAL shipping (`lag_lsn` gauge —
//! primary durable LSN minus locally applied, the standby's health
//! number; `ship.batches`/`ship.frames`/`ship.bytes` on the primary;
//! `pull.frames`/`pull.bytes`, `bootstraps`, `promotions` on the
//! standby), and `rest.*` for the head service (including
//! `rejected_replica`/`rejected_fenced` write-gate hits). Everything
//! lands in the shared [`Registry`] and is exposed by `GET /api/metrics`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::util::json::Json;

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Signed gauge.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket histogram (log2 buckets over nanoseconds/values).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, v: u64) {
        let idx = (64 - v.leading_zeros()).min(63) as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th sample).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return if i >= 63 { u64::MAX } else { 1u64 << i };
            }
        }
        u64::MAX
    }
}

/// Named metrics registry shared across daemons.
#[derive(Default, Clone)]
pub struct Registry {
    inner: Arc<RegistryInner>,
}

#[derive(Default)]
struct RegistryInner {
    counters: RwLock<BTreeMap<String, Arc<Counter>>>,
    gauges: RwLock<BTreeMap<String, Arc<Gauge>>>,
    histograms: RwLock<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        if let Some(c) = self.inner.counters.read().unwrap().get(name) {
            return Arc::clone(c);
        }
        let mut w = self.inner.counters.write().unwrap();
        Arc::clone(w.entry(name.to_string()).or_insert_with(|| Arc::new(Counter::default())))
    }

    /// Counter for a daemon's change-driven poll skips — ticks where the
    /// store generations were unchanged and the daemon touched no table
    /// lock. Standardized naming: `pipeline.<daemon>.poll_skips`.
    pub fn poll_skip_counter(&self, daemon: &str) -> Arc<Counter> {
        self.counter(&format!("pipeline.{daemon}.poll_skips"))
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        if let Some(g) = self.inner.gauges.read().unwrap().get(name) {
            return Arc::clone(g);
        }
        let mut w = self.inner.gauges.write().unwrap();
        Arc::clone(w.entry(name.to_string()).or_insert_with(|| Arc::new(Gauge::default())))
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        if let Some(h) = self.inner.histograms.read().unwrap().get(name) {
            return Arc::clone(h);
        }
        let mut w = self.inner.histograms.write().unwrap();
        Arc::clone(
            w.entry(name.to_string())
                .or_insert_with(|| Arc::new(Histogram::default())),
        )
    }

    pub fn snapshot(&self) -> Json {
        let mut obj = Json::obj();
        for (k, v) in self.inner.counters.read().unwrap().iter() {
            obj = obj.set(&format!("counter.{k}"), v.get());
        }
        for (k, v) in self.inner.gauges.read().unwrap().iter() {
            obj = obj.set(&format!("gauge.{k}"), v.get() as f64);
        }
        for (k, v) in self.inner.histograms.read().unwrap().iter() {
            obj = obj.set(
                &format!("hist.{k}"),
                Json::obj()
                    .set("count", v.count())
                    .set("mean", v.mean())
                    .set("p50", v.quantile(0.5))
                    .set("p99", v.quantile(0.99)),
            );
        }
        obj
    }
}

/// Time-series recorder for campaign plots (Fig. 5): named series of
/// (t, value) samples.
#[derive(Default, Clone)]
pub struct Timeline {
    series: Arc<Mutex<BTreeMap<String, Vec<(f64, f64)>>>>,
}

impl std::fmt::Debug for Timeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let names = self.names();
        write!(f, "Timeline({} series)", names.len())
    }
}

impl Timeline {
    pub fn record(&self, series: &str, t: f64, v: f64) {
        self.series
            .lock()
            .unwrap()
            .entry(series.to_string())
            .or_default()
            .push((t, v));
    }

    pub fn series(&self, name: &str) -> Vec<(f64, f64)> {
        self.series
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    pub fn names(&self) -> Vec<String> {
        self.series.lock().unwrap().keys().cloned().collect()
    }

    /// Downsample a series to at most `n` points (for terminal plots).
    pub fn downsample(&self, name: &str, n: usize) -> Vec<(f64, f64)> {
        let s = self.series(name);
        if s.len() <= n || n == 0 {
            return s;
        }
        let stride = s.len() as f64 / n as f64;
        (0..n)
            .map(|i| s[((i as f64 * stride) as usize).min(s.len() - 1)])
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let guard = self.series.lock().unwrap();
        let mut obj = Json::obj();
        for (k, pts) in guard.iter() {
            obj = obj.set(
                k,
                Json::Arr(
                    pts.iter()
                        .map(|(t, v)| Json::Arr(vec![Json::Num(*t), Json::Num(*v)]))
                        .collect(),
                ),
            );
        }
        obj
    }

    /// Render an ASCII sparkline-style plot of a series (used by example
    /// binaries to "draw" Fig. 5 in the terminal).
    pub fn ascii_plot(&self, name: &str, width: usize, height: usize) -> String {
        let pts = self.downsample(name, width);
        if pts.is_empty() {
            return format!("{name}: (no data)\n");
        }
        let (min_v, max_v) = pts
            .iter()
            .fold((f64::MAX, f64::MIN), |(lo, hi), (_, v)| (lo.min(*v), hi.max(*v)));
        let span = (max_v - min_v).max(1e-12);
        let mut grid = vec![vec![b' '; pts.len()]; height];
        for (x, (_, v)) in pts.iter().enumerate() {
            let y = (((v - min_v) / span) * (height - 1) as f64).round() as usize;
            grid[height - 1 - y][x] = b'*';
        }
        let mut out = format!("{name}  [{min_v:.3e} .. {max_v:.3e}]\n");
        for row in grid {
            out.push('|');
            out.push_str(std::str::from_utf8(&row).unwrap());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_histogram() {
        let r = Registry::default();
        r.counter("a").inc();
        r.counter("a").add(4);
        assert_eq!(r.counter("a").get(), 5);
        r.gauge("g").set(-3);
        r.gauge("g").add(1);
        assert_eq!(r.gauge("g").get(), -2);
        let h = r.histogram("h");
        for v in [1u64, 2, 4, 100, 1000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() > 0.0);
        assert!(h.quantile(0.5) >= 2);
        assert!(h.quantile(1.0) >= 1000);
    }

    #[test]
    fn registry_is_shared() {
        let r = Registry::default();
        let c1 = r.counter("x");
        let c2 = r.counter("x");
        c1.inc();
        assert_eq!(c2.get(), 1);
        let snap = r.snapshot();
        assert_eq!(snap.get("counter.x").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn timeline_series_and_downsample() {
        let t = Timeline::default();
        for i in 0..1000 {
            t.record("disk", i as f64, (i * 2) as f64);
        }
        assert_eq!(t.series("disk").len(), 1000);
        let d = t.downsample("disk", 50);
        assert_eq!(d.len(), 50);
        assert_eq!(d[0], (0.0, 0.0));
        let plot = t.ascii_plot("disk", 40, 8);
        assert!(plot.contains('*'));
        assert_eq!(t.names(), vec!["disk".to_string()]);
    }

    #[test]
    fn timeline_json_shape() {
        let t = Timeline::default();
        t.record("s", 1.0, 2.0);
        let j = t.to_json();
        let arr = j.get("s").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_arr().unwrap()[1].as_f64(), Some(2.0));
    }
}
