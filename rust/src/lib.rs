//! # iDDS — intelligent Data Delivery Service (reproduction)
//!
//! A Rust + JAX + Pallas reproduction of *"An intelligent Data Delivery
//! Service for and beyond the ATLAS experiment"* (EPJ Web Conf. 251, 02007,
//! 2021): a workflow-oriented orchestration service that sits between a
//! WorkFlow Management system (WFM) and a Distributed Data Management
//! system (DDM) and delivers data to compute at fine granularity.
//!
//! Layer map (see `DESIGN.md`):
//! * **L3 (this crate)** — the iDDS head service, the five daemons
//!   (Clerk, Marshaller, Transformer, Carrier, Conductor), the directed-
//!   graph workflow engine, and every substrate the paper's deployment
//!   relied on (DDM, tape system, WFM, message broker), built as
//!   discrete-event simulators where the real thing is a physical facility.
//! * **L2/L1 (python/, build-time only)** — the numeric payloads (GP
//!   surrogate + EI acquisition for the HPO service, the MLP training
//!   payload, the active-learning decision scorer), lowered once to HLO
//!   text and executed from `runtime` via PJRT. Python is never on the
//!   request path.

pub mod util;
pub mod config;
pub mod persist;
pub mod store;
pub mod broker;
pub mod tape;
pub mod ddm;
pub mod ess;
pub mod wfm;
pub mod workflow;
pub mod daemons;
pub mod rest;
pub mod worker;
pub mod runtime;
pub mod hpo;
pub mod carousel;
pub mod activelearning;
pub mod rubin;
pub mod metrics;
pub mod obs;
pub mod simulation;
