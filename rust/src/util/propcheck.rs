//! Miniature property-testing harness (proptest stand-in).
//!
//! `check(name, cases, |rng| ...)` runs a property closure against `cases`
//! independently seeded [`Rng`]s. On failure it panics with the case seed
//! so the exact input can be replayed with `replay(seed, |rng| ...)`.
//! There is no shrinking — generators in this repo are kept small and
//! structured enough that the seed alone localizes failures.

use super::rng::Rng;

/// Run `prop` for `cases` randomized cases. `prop` returns `Err(msg)` (or
/// panics) to signal a failure.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Fixed base seed for CI determinism; override with PROPCHECK_SEED.
    let base = std::env::var("PROPCHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay one case by seed.
pub fn replay<F>(seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replay(seed {seed:#x}) failed: {msg}");
    }
}

/// Assert helper usable inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("u64 addition commutes", 50, |rng| {
            let a = rng.below(1000);
            let b = rng.below(1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn replay_deterministic() {
        let mut first = None;
        replay(1234, |rng| {
            first = Some(rng.next_u64());
            Ok(())
        });
        let mut second = None;
        replay(1234, |rng| {
            second = Some(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
