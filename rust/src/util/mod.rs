//! Small self-contained utilities the rest of the crate builds on.
//!
//! The build environment is fully offline with only the `xla` crate's
//! vendored dependency set available, so the conveniences a service like
//! this would normally pull from crates.io (serde_json, rand, tokio,
//! proptest, criterion) are implemented here from scratch:
//!
//! * [`json`]      — JSON value model, parser and serializer (client ⇄
//!   head-service interchange, artifact manifest).
//! * [`rng`]       — SplitMix64 / xoshiro256** PRNGs (workload generators,
//!   samplers).
//! * [`clock`]     — wall + simulated clocks behind one trait; the
//!   discrete-event simulation drives the latter.
//! * [`pool`]      — a fixed thread pool with panic isolation (daemon and
//!   REST worker execution).
//! * [`propcheck`] — a miniature property-testing harness (randomized
//!   inputs, shrink-free but seed-reporting) for invariant tests.
//! * [`bench`]     — a micro-bench harness used by the `cargo bench`
//!   targets (criterion stand-in): warmup, timed iterations, mean/p50/p99.

pub mod json;
pub mod rng;
pub mod clock;
pub mod pool;
pub mod propcheck;
pub mod bench;

use std::sync::atomic::{AtomicU64, Ordering};

/// FNV-1a 64-bit offset basis — seed for [`fnv1a`].
pub const FNV1A_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into an FNV-1a 64-bit hash state. The crate's one cheap
/// structural hash: workflow shape/definition hashing and broker topic
/// striping all share this implementation.
pub fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Monotonically increasing id generator (process-wide, lock-free).
pub fn next_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Ensure every future [`next_id`] call returns an id strictly greater
/// than `max_seen`. Used by snapshot restore and WAL replay so recovered
/// rows can never collide with freshly allocated ids; callers no longer
/// advance the counter themselves.
pub fn advance_next_id(max_seen: u64) {
    NEXT_ID.fetch_max(max_seen.saturating_add(1), Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn advance_next_id_skips_past_restored_ids() {
        let seen = next_id();
        advance_next_id(seen + 1000);
        assert!(next_id() > seen + 1000);
        // advancing backwards is a no-op
        advance_next_id(seen);
        assert!(next_id() > seen + 1000);
    }

    #[test]
    fn next_id_unique_across_threads() {
        let handles: Vec<_> = (0..8)
            .map(|_| std::thread::spawn(|| (0..1000).map(|_| next_id()).collect::<Vec<_>>()))
            .collect();
        let mut seen = HashSet::new();
        for h in handles {
            for id in h.join().unwrap() {
                assert!(seen.insert(id), "duplicate id {id}");
            }
        }
    }
}
