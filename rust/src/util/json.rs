//! Hand-rolled JSON: value model, recursive-descent parser, serializer.
//!
//! serde/serde_json are not available offline, and iDDS's client↔server
//! interchange is JSON (paper Fig. 2: workflows are serialized to
//! json-based requests). This module is the single JSON implementation for
//! the whole crate: REST bodies, workflow (de)serialization, the artifact
//! manifest, and bench/report output.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — important for tests and for request signing.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style insert; panics if `self` is not an object.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `get_path(&["a", "b"])` == `self["a"]["b"]`.
    pub fn get_path(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|f| {
            if f.fract() == 0.0 && f >= i64::MIN as f64 && f <= i64::MAX as f64 {
                Some(f as i64)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write_to(&mut out);
        out
    }

    /// Append the compact serialization to a caller-reusable buffer,
    /// pre-reserving an estimate of the encoded size so large payloads
    /// (REST bodies, snapshots) don't reallocate repeatedly. The buffer is
    /// appended to, not cleared — callers decide when to reuse it.
    pub fn write_to(&self, out: &mut String) {
        out.reserve(self.encoded_size_hint());
        self.write(out);
    }

    /// Cheap lower-bound estimate of the serialized length (no formatting
    /// work, one structural walk).
    fn encoded_size_hint(&self) -> usize {
        match self {
            Json::Null => 4,
            Json::Bool(_) => 5,
            Json::Num(_) => 8,
            Json::Str(s) => s.len() + 2,
            Json::Arr(a) => 2 + a.iter().map(|v| v.encoded_size_hint() + 1).sum::<usize>(),
            Json::Obj(m) => {
                2 + m
                    .iter()
                    .map(|(k, v)| k.len() + 4 + v.encoded_size_hint())
                    .sum::<usize>()
            }
        }
    }

    fn write(&self, out: &mut String) {
        use std::fmt::Write as _;
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                // write! into the buffer directly: no per-number String
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    use std::fmt::Write as _;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(a: &[T]) -> Json {
        Json::Arr(a.iter().cloned().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
#[error("json parse error at byte {at}: {msg}")]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

const MAX_DEPTH: usize = 128;

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            at: self.i,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        let v = match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        };
        self.depth -= 1;
        v
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad unicode escape"))?);
                            continue; // hex4 advanced past the digits
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short unicode escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad unicode escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad unicode escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let j = Json::obj()
            .set("name", "carousel")
            .set("n", 42u64)
            .set("pi", 3.5)
            .set("ok", true)
            .set("none", Json::Null)
            .set("arr", Json::Arr(vec![1u64.into(), 2u64.into()]));
        let s = j.to_string();
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": {"b": [1, 2, {"c": "d"}]}, "e": -1.5e3}"#).unwrap();
        assert_eq!(
            v.get_path(&["a", "b"]).unwrap().as_arr().unwrap().len(),
            3
        );
        assert_eq!(v.get("e").unwrap().as_f64(), Some(-1500.0));
    }

    #[test]
    fn parse_string_escapes() {
        let v = parse(r#""a\nb\t\"c\"Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\"Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn escape_roundtrip() {
        let s = "line1\nline2\t\"quoted\" \\ end é😀";
        let j = Json::Str(s.to_string());
        assert_eq!(parse(&j.to_string()).unwrap().as_str(), Some(s));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1,}").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn rejects_deep_nesting() {
        let s = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&s).is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
        assert_eq!(parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(parse("1e-3").unwrap().as_f64(), Some(0.001));
        assert_eq!(parse("123456789").unwrap().as_u64(), Some(123456789));
        assert_eq!(parse("-3").unwrap().as_i64(), Some(-3));
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn deterministic_object_order() {
        let a = parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(a.to_string(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn write_to_appends_and_matches_to_string() {
        let j = Json::obj()
            .set("a", 1u64)
            .set("s", "x\ny")
            .set("arr", Json::Arr(vec![Json::Null, Json::Bool(true)]));
        let mut buf = String::from("prefix:");
        j.write_to(&mut buf);
        assert_eq!(buf, format!("prefix:{}", j.to_string()));
        // reuse the same buffer
        buf.clear();
        j.write_to(&mut buf);
        assert_eq!(parse(&buf).unwrap(), j);
    }
}
