//! Deterministic PRNGs (rand is not available offline).
//!
//! SplitMix64 seeds xoshiro256**; both are the reference algorithms from
//! Blackman & Vigna. Everything downstream (workload generators, samplers,
//! the discrete-event simulators) takes an explicit `Rng` so runs are
//! reproducible from a single seed — which the benches rely on to compare
//! coarse vs fine carousel modes on *identical* workloads.

/// SplitMix64: used for seeding and cheap one-shot hashing.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the main generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (for per-entity generators).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA3EC647659359ACD)
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Uses Lemire's method (unbiased enough
    /// for simulation purposes via 64-bit multiply-shift).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi) .
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Zipf-like heavy-tailed sample in [1, n] with exponent `s`.
    /// Used for file-size and dataset-popularity distributions (grid data
    /// volumes are famously heavy-tailed).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        // inverse-CDF on the continuous approximation
        let u = self.f64();
        if (s - 1.0).abs() < 1e-9 {
            let x = (n as f64).powf(u);
            return x.round().clamp(1.0, n as f64) as u64;
        }
        let a = 1.0 - s;
        let x = ((u * ((n as f64).powf(a) - 1.0)) + 1.0).powf(1.0 / a);
        x.round().clamp(1.0, n as f64) as u64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b), "all buckets hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_heavy_tail() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let samples: Vec<u64> = (0..n).map(|_| r.zipf(1000, 1.2)).collect();
        assert!(samples.iter().all(|&x| (1..=1000).contains(&x)));
        // heavy head: most mass at small ranks even though n=1000
        let small = samples.iter().filter(|&&x| x <= 32).count();
        assert!(
            small as f64 / n as f64 > 0.5,
            "mass at ranks<=32: {}",
            small as f64 / n as f64
        );
        // but the tail is populated too
        assert!(samples.iter().any(|&x| x > 500));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(19);
        let n = 50_000;
        let m = (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((m - 3.0).abs() < 0.1, "mean {m}");
    }
}
