//! Micro-bench harness used by the `cargo bench` targets (criterion is not
//! available offline).
//!
//! [`Bencher::bench`] runs warmup iterations, then timed iterations, and
//! records wall-clock per-iteration stats (mean / p50 / p99 / min). The
//! bench binaries print a fixed-width table plus machine-readable JSON
//! lines (`BENCHJSON {...}`) so results can be scraped into
//! EXPERIMENTS.md.

use std::time::Instant;

use super::json::Json;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("iters", self.iters)
            .set("mean_ns", self.mean_ns)
            .set("p50_ns", self.p50_ns)
            .set("p99_ns", self.p99_ns)
            .set("min_ns", self.min_ns)
    }
}

pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
    results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bencher {
            warmup,
            iters,
            results: Vec::new(),
        }
    }

    /// Quick-mode factor from env (CI smoke runs): BENCH_QUICK=1 shrinks
    /// iteration counts 10x.
    pub fn from_env() -> Self {
        let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
        if quick {
            Bencher::new(1, 5)
        } else {
            Bencher::new(3, 30)
        }
    }

    /// Time `f` per call; `f` should do one logical operation.
    pub fn bench<T>(&mut self, name: &str, f: impl FnMut() -> T) -> BenchResult {
        let mut f = f;
        self.bench_with_setup(name, || (), move |_| f())
    }

    /// Like [`Bencher::bench`], but runs `setup` before every iteration
    /// (warmup and timed) with only `f`'s execution inside the timed
    /// region — for operations that consume their input (e.g. driving
    /// contents to a terminal status needs fresh rows each round). `f`
    /// borrows the state so both its construction *and its teardown* stay
    /// outside the timed window.
    pub fn bench_with_setup<S, T>(
        &mut self,
        name: &str,
        mut setup: impl FnMut() -> S,
        mut f: impl FnMut(&mut S) -> T,
    ) -> BenchResult {
        for _ in 0..self.warmup {
            let mut input = setup();
            std::hint::black_box(f(&mut input));
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let mut input = setup();
            let t0 = Instant::now();
            std::hint::black_box(f(&mut input));
            samples.push(t0.elapsed().as_nanos() as f64);
            drop(input); // teardown after the clock stops
        }
        self.record(name, samples)
    }

    fn record(&mut self, name: &str, mut samples: Vec<f64>) -> BenchResult {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let res = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
            p50_ns: samples[samples.len() / 2],
            p99_ns: samples[((samples.len() as f64 * 0.99) as usize).min(samples.len() - 1)],
            min_ns: samples[0],
        };
        println!(
            "{:<48} mean {:>12}  p50 {:>12}  p99 {:>12}",
            res.name,
            fmt_ns(res.mean_ns),
            fmt_ns(res.p50_ns),
            fmt_ns(res.p99_ns)
        );
        println!("BENCHJSON {}", res.to_json());
        self.results.push(res.clone());
        res
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Section header helper for bench binaries.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bencher::new(1, 5);
        let r = b.bench("noop", || 1 + 1);
        assert_eq!(r.iters, 5);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns || r.p99_ns >= r.min_ns);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn bench_with_setup_excludes_setup_cost() {
        let mut b = Bencher::new(0, 3);
        let r = b.bench_with_setup(
            "setup-heavy",
            || std::thread::sleep(std::time::Duration::from_millis(5)),
            |_| 1 + 1,
        );
        // timed region is the trivial add, not the 5ms sleep
        assert!(r.p50_ns < 4_000_000.0, "setup leaked into timing: {}", r.p50_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
