//! Wall-clock and simulated clocks behind one trait.
//!
//! The live service (REST head + daemons) runs on [`WallClock`]; the
//! discrete-event experiments (carousel campaigns, Rubin DAG runs) run on
//! [`SimClock`], which only advances when the simulation driver tells it
//! to. Times are f64 seconds since an arbitrary epoch — enough resolution
//! for both domains and trivially serializable.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

pub trait Clock: Send + Sync {
    /// Seconds since this clock's epoch.
    fn now(&self) -> f64;
}

/// Real time, epoch = construction.
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock {
            start: Instant::now(),
        }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Simulated time: advanced explicitly by the event loop. Stored as
/// nanoseconds in an atomic so daemons on other threads can read it.
#[derive(Default)]
pub struct SimClock {
    nanos: AtomicU64,
}

impl SimClock {
    pub fn new() -> Arc<Self> {
        Arc::new(SimClock {
            nanos: AtomicU64::new(0),
        })
    }

    pub fn advance_to(&self, t: f64) {
        let target = (t * 1e9) as u64;
        // monotone: never move backwards
        self.nanos.fetch_max(target, Ordering::SeqCst);
    }

    pub fn advance_by(&self, dt: f64) {
        self.nanos
            .fetch_add((dt * 1e9) as u64, Ordering::SeqCst);
    }
}

impl Clock for SimClock {
    fn now(&self) -> f64 {
        self.nanos.load(Ordering::SeqCst) as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_advances() {
        let c = WallClock::new();
        let a = c.now();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(c.now() > a);
    }

    #[test]
    fn sim_clock_explicit() {
        let c = SimClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(10.0);
        assert!((c.now() - 10.0).abs() < 1e-6);
        c.advance_by(2.5);
        assert!((c.now() - 12.5).abs() < 1e-6);
    }

    #[test]
    fn sim_clock_monotone() {
        let c = SimClock::new();
        c.advance_to(100.0);
        c.advance_to(50.0); // ignored
        assert!((c.now() - 100.0).abs() < 1e-6);
    }
}
