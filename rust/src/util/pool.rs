//! Fixed-size thread pool with panic isolation (tokio stand-in).
//!
//! The REST head service and the daemon host run their work on this pool.
//! Jobs are `FnOnce` closures; a panicking job is caught and counted, it
//! never takes a worker down.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Live occupancy counters shared with whoever wants to watch the pool
/// (the REST head surfaces its pool here in `/api/health`). All loads
/// and stores are relaxed: these are monitoring numbers, not a
/// synchronization protocol.
#[derive(Default)]
pub struct PoolStats {
    /// Worker threads currently running a job.
    pub busy: AtomicU64,
    /// Jobs submitted but not yet picked up by a worker.
    pub queued: AtomicU64,
    /// Pool size (set once at construction).
    pub size: AtomicU64,
}

impl PoolStats {
    /// `busy / size` in [0, 1].
    pub fn saturation(&self) -> f64 {
        let size = self.size.load(Ordering::Relaxed);
        if size == 0 {
            return 0.0;
        }
        self.busy.load(Ordering::Relaxed) as f64 / size as f64
    }
}

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    panics: Arc<AtomicU64>,
    stats: Arc<PoolStats>,
}

impl ThreadPool {
    pub fn new(size: usize, name: &str) -> Self {
        Self::with_stats(size, name, Arc::new(PoolStats::default()))
    }

    /// Construct with an externally owned [`PoolStats`] so a caller can
    /// keep reading occupancy after moving the pool elsewhere.
    pub fn with_stats(size: usize, name: &str, stats: Arc<PoolStats>) -> Self {
        assert!(size > 0);
        stats.size.store(size as u64, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let panics = Arc::new(AtomicU64::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let panics = Arc::clone(&panics);
                let stats = Arc::clone(&stats);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                stats.queued.fetch_sub(1, Ordering::Relaxed);
                                stats.busy.fetch_add(1, Ordering::Relaxed);
                                if std::panic::catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    panics.fetch_add(1, Ordering::Relaxed);
                                }
                                stats.busy.fetch_sub(1, Ordering::Relaxed);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            panics,
            stats,
        }
    }

    /// Submit a job. Panics if the pool is shut down.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.stats.queued.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("pool workers gone");
    }

    /// Submit a job, reporting failure instead of panicking: `false`
    /// when the pool is shut down or its workers are gone. The REST
    /// event loop uses this — a dying pool must surface as a 503, not
    /// take the I/O thread down with it.
    pub fn try_execute(&self, job: impl FnOnce() + Send + 'static) -> bool {
        let Some(tx) = self.tx.as_ref() else {
            return false;
        };
        self.stats.queued.fetch_add(1, Ordering::Relaxed);
        if tx.send(Box::new(job)).is_err() {
            self.stats.queued.fetch_sub(1, Ordering::Relaxed);
            return false;
        }
        true
    }

    /// Number of jobs that panicked since construction.
    pub fn panic_count(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    /// Live occupancy counters.
    pub fn stats(&self) -> Arc<PoolStats> {
        Arc::clone(&self.stats)
    }

    /// Drop the sender and join all workers (runs queued jobs first).
    pub fn shutdown(mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run jobs across the pool and wait for all of them (scoped fan-out).
pub fn fan_out<T: Send + 'static>(
    pool: &ThreadPool,
    jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
) -> Vec<T> {
    let (tx, rx) = mpsc::channel();
    let n = jobs.len();
    for (i, job) in jobs.into_iter().enumerate() {
        let tx = tx.clone();
        pool.execute(move || {
            let _ = tx.send((i, job()));
        });
    }
    drop(tx);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for _ in 0..n {
        let (i, v) = rx.recv().expect("fan_out worker died");
        out[i] = Some(v);
    }
    out.into_iter().map(|v| v.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4, "t");
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn survives_panics() {
        let pool = ThreadPool::new(2, "p");
        let counter = Arc::new(AtomicUsize::new(0));
        for i in 0..20 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                if i % 2 == 0 {
                    panic!("boom");
                }
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // give workers time, then check the pool still works
        std::thread::sleep(std::time::Duration::from_millis(50));
        let c = Arc::clone(&counter);
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        let panics = {
            std::thread::sleep(std::time::Duration::from_millis(50));
            pool.panic_count()
        };
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 11);
        assert_eq!(panics, 10);
    }

    #[test]
    fn stats_track_occupancy() {
        let pool = ThreadPool::new(2, "s");
        let stats = pool.stats();
        assert_eq!(stats.size.load(Ordering::Relaxed), 2);
        let (hold_tx, hold_rx) = mpsc::channel::<()>();
        let hold_rx = Arc::new(Mutex::new(hold_rx));
        // occupy both workers until released
        for _ in 0..2 {
            let rx = Arc::clone(&hold_rx);
            pool.execute(move || {
                let _ = rx.lock().unwrap().recv();
            });
        }
        // wait for both to be picked up
        for _ in 0..200 {
            if stats.busy.load(Ordering::Relaxed) == 2 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(stats.busy.load(Ordering::Relaxed), 2);
        assert!(stats.saturation() >= 1.0);
        // a third job has nowhere to go: it queues
        pool.execute(|| {});
        assert!(stats.queued.load(Ordering::Relaxed) >= 1);
        hold_tx.send(()).unwrap();
        hold_tx.send(()).unwrap();
        pool.shutdown();
        assert_eq!(stats.busy.load(Ordering::Relaxed), 0);
        assert_eq!(stats.queued.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fan_out_preserves_order() {
        let pool = ThreadPool::new(4, "f");
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..32usize)
            .map(|i| Box::new(move || i * 2) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let out = fan_out(&pool, jobs);
        assert_eq!(out, (0..32).map(|i| i * 2).collect::<Vec<_>>());
    }
}
