//! WorkFlow Management simulator (PanDA stand-in).
//!
//! Models the WFM behaviour that produces the paper's Figure 4: tasks made
//! of jobs with file-level input dependencies, heterogeneous sites with
//! bounded slots, and the crucial *attempt* mechanism — a dispatched job
//! whose input is not yet on disk burns a failed attempt and is requeued
//! with a retry backoff (this is what the coarse, pre-iDDS carousel did at
//! scale). iDDS avoids those attempts by holding jobs until their inputs
//! are Available and releasing them through Conductor messages.
//!
//! Release modes per task:
//! * [`ReleaseMode::Immediate`] — all jobs enter the dispatch queue as
//!   soon as the task starts (pre-iDDS behaviour).
//! * [`ReleaseMode::Triggered`] — jobs enter the queue only when
//!   explicitly released (iDDS fine-grained delivery).
//!
//! Time is explicit (`tick(now, availability)`), driven by the DES loop.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::tape::FileId;

pub type TaskId = u64;
pub type JobId = u64;
pub type SiteId = u32;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReleaseMode {
    Immediate,
    Triggered,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Waiting,   // Triggered mode: not yet released by iDDS
    Queued,    // in the dispatch queue
    Retrying,  // failed attempt, waiting out the backoff
    Running,
    Finished,
    Exhausted, // max attempts burned
}

#[derive(Debug, Clone)]
pub struct JobSpec {
    pub inputs: Vec<FileId>,
    pub wall_s: f64,
}

#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub task: TaskId,
    pub inputs: Vec<FileId>,
    pub wall_s: f64,
    pub state: JobState,
    pub attempts: u32,
    pub started_at: Option<f64>,
    pub finished_at: Option<f64>,
}

#[derive(Debug, Clone)]
struct Task {
    #[allow(dead_code)]
    id: TaskId,
    jobs: Vec<JobId>,
    mode: ReleaseMode,
    finished_jobs: usize,
    exhausted_jobs: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub enum WfmEvent {
    JobStarted { job: JobId, at: f64 },
    JobFinished { job: JobId, task: TaskId, at: f64, inputs: Vec<FileId> },
    JobAttemptFailed { job: JobId, at: f64, attempt: u32 },
    JobExhausted { job: JobId, at: f64 },
    TaskDone { task: TaskId, at: f64 },
}

#[derive(PartialEq, PartialOrd)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap_or(std::cmp::Ordering::Equal)
    }
}

pub struct WfmSim {
    jobs: HashMap<JobId, Job>,
    tasks: HashMap<TaskId, Task>,
    queue: VecDeque<JobId>,
    /// (ready_at, job) — backoff queue for failed attempts
    retrying: BinaryHeap<Reverse<(OrdF64, JobId)>>,
    /// (finish_at, job, site)
    running: BinaryHeap<Reverse<(OrdF64, JobId, SiteId)>>,
    free_slots: HashMap<SiteId, usize>,
    total_slots: usize,
    busy_slots: usize,
    retry_delay_s: f64,
    max_attempts: u32,
    pub total_attempts: u64,
    pub failed_attempts: u64,
}

impl WfmSim {
    pub fn new(sites: u32, slots_per_site: usize, retry_delay_s: f64, max_attempts: u32) -> Self {
        let free_slots: HashMap<SiteId, usize> =
            (0..sites).map(|s| (s, slots_per_site)).collect();
        WfmSim {
            jobs: HashMap::new(),
            tasks: HashMap::new(),
            queue: VecDeque::new(),
            retrying: BinaryHeap::new(),
            running: BinaryHeap::new(),
            free_slots,
            total_slots: sites as usize * slots_per_site,
            busy_slots: 0,
            retry_delay_s,
            max_attempts,
            total_attempts: 0,
            failed_attempts: 0,
        }
    }

    /// Submit a task. In `Immediate` mode all jobs are queued at once; in
    /// `Triggered` mode they wait for [`WfmSim::release_jobs`].
    pub fn submit_task(&mut self, jobs: Vec<JobSpec>, mode: ReleaseMode) -> (TaskId, Vec<JobId>) {
        let task_id = crate::util::next_id();
        let mut ids = Vec::with_capacity(jobs.len());
        for spec in jobs {
            let id = crate::util::next_id();
            let state = match mode {
                ReleaseMode::Immediate => JobState::Queued,
                ReleaseMode::Triggered => JobState::Waiting,
            };
            self.jobs.insert(
                id,
                Job {
                    id,
                    task: task_id,
                    inputs: spec.inputs,
                    wall_s: spec.wall_s,
                    state,
                    attempts: 0,
                    started_at: None,
                    finished_at: None,
                },
            );
            if mode == ReleaseMode::Immediate {
                self.queue.push_back(id);
            }
            ids.push(id);
        }
        self.tasks.insert(
            task_id,
            Task {
                id: task_id,
                jobs: ids.clone(),
                mode,
                finished_jobs: 0,
                exhausted_jobs: 0,
            },
        );
        (task_id, ids)
    }

    /// Release waiting jobs into the dispatch queue (iDDS Conductor path).
    /// Unknown or already-released jobs are skipped; returns released count.
    pub fn release_jobs(&mut self, ids: &[JobId]) -> usize {
        let mut n = 0;
        for &id in ids {
            if let Some(j) = self.jobs.get_mut(&id) {
                if j.state == JobState::Waiting {
                    j.state = JobState::Queued;
                    self.queue.push_back(id);
                    n += 1;
                }
            }
        }
        n
    }

    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(&id)
    }

    pub fn task_jobs(&self, task: TaskId) -> Vec<JobId> {
        self.tasks.get(&task).map(|t| t.jobs.clone()).unwrap_or_default()
    }

    pub fn task_mode(&self, task: TaskId) -> Option<ReleaseMode> {
        self.tasks.get(&task).map(|t| t.mode)
    }

    pub fn queued_len(&self) -> usize {
        self.queue.len()
    }

    pub fn busy_slots(&self) -> usize {
        self.busy_slots
    }

    pub fn total_slots(&self) -> usize {
        self.total_slots
    }

    /// Attempt histogram over all jobs (Fig. 4's x-axis).
    pub fn attempt_histogram(&self) -> Vec<(u32, usize)> {
        let mut h: HashMap<u32, usize> = HashMap::new();
        for j in self.jobs.values() {
            *h.entry(j.attempts).or_default() += 1;
        }
        let mut v: Vec<_> = h.into_iter().collect();
        v.sort_unstable();
        v
    }

    /// Advance to `now`. `available` answers "is this input file on disk?"
    /// at dispatch time (the DDM replica catalog).
    pub fn tick(&mut self, now: f64, available: &dyn Fn(FileId) -> bool) -> Vec<WfmEvent> {
        let mut events = Vec::new();

        // 1. finish running jobs due by now
        while let Some(Reverse((OrdF64(t), _, _))) = self.running.peek() {
            if *t > now {
                break;
            }
            let Reverse((OrdF64(t), job_id, site)) = self.running.pop().unwrap();
            *self.free_slots.get_mut(&site).unwrap() += 1;
            self.busy_slots -= 1;
            let job = self.jobs.get_mut(&job_id).unwrap();
            job.state = JobState::Finished;
            job.finished_at = Some(t);
            let task_id = job.task;
            let inputs = job.inputs.clone();
            events.push(WfmEvent::JobFinished { job: job_id, task: task_id, at: t, inputs });
            let task = self.tasks.get_mut(&task_id).unwrap();
            task.finished_jobs += 1;
            if task.finished_jobs + task.exhausted_jobs == task.jobs.len() {
                events.push(WfmEvent::TaskDone { task: task_id, at: t });
            }
        }

        // 2. move retry-backoff jobs whose delay expired back into the queue
        while let Some(Reverse((OrdF64(t), _))) = self.retrying.peek() {
            if *t > now {
                break;
            }
            let Reverse((_, job_id)) = self.retrying.pop().unwrap();
            let job = self.jobs.get_mut(&job_id).unwrap();
            job.state = JobState::Queued;
            self.queue.push_back(job_id);
        }

        // 3. dispatch queued jobs onto free slots
        let mut requeue = Vec::new();
        while !self.queue.is_empty() {
            let Some(site) = self
                .free_slots
                .iter()
                .filter(|(_, n)| **n > 0)
                .map(|(s, _)| *s)
                .min()
            else {
                break;
            };
            let job_id = self.queue.pop_front().unwrap();
            let job = self.jobs.get_mut(&job_id).unwrap();
            job.attempts += 1;
            self.total_attempts += 1;
            if job.inputs.iter().all(|f| available(*f)) {
                // real start
                *self.free_slots.get_mut(&site).unwrap() -= 1;
                self.busy_slots += 1;
                job.state = JobState::Running;
                job.started_at.get_or_insert(now);
                let finish = now + job.wall_s;
                self.running.push(Reverse((OrdF64(finish), job_id, site)));
                events.push(WfmEvent::JobStarted { job: job_id, at: now });
            } else {
                // failed attempt: input not on disk (the Fig. 4 mechanism)
                self.failed_attempts += 1;
                let attempt = job.attempts;
                if attempt >= self.max_attempts {
                    job.state = JobState::Exhausted;
                    let task_id = job.task;
                    events.push(WfmEvent::JobExhausted { job: job_id, at: now });
                    let task = self.tasks.get_mut(&task_id).unwrap();
                    task.exhausted_jobs += 1;
                    if task.finished_jobs + task.exhausted_jobs == task.jobs.len() {
                        events.push(WfmEvent::TaskDone { task: task_id, at: now });
                    }
                } else {
                    job.state = JobState::Retrying;
                    requeue.push((now + self.retry_delay_s, job_id));
                    events.push(WfmEvent::JobAttemptFailed { job: job_id, at: now, attempt });
                }
            }
        }
        for (t, id) in requeue {
            self.retrying.push(Reverse((OrdF64(t), id)));
        }

        events
    }

    /// Earliest future event the sim itself will generate (job finish or
    /// retry-backoff expiry). Queued dispatches happen "now", so callers
    /// should tick whenever external state (staging) changes too.
    pub fn next_event_time(&self) -> Option<f64> {
        let a = self.running.peek().map(|Reverse((OrdF64(t), _, _))| *t);
        let b = self.retrying.peek().map(|Reverse((OrdF64(t), _))| *t);
        match (a, b) {
            (Some(x), Some(y)) => Some(x.min(y)),
            (x, y) => x.or(y),
        }
    }

    pub fn idle(&self) -> bool {
        self.running.is_empty() && self.retrying.is_empty() && self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_available(_: FileId) -> bool {
        true
    }
    fn none_available(_: FileId) -> bool {
        false
    }

    #[test]
    fn immediate_job_runs_and_finishes() {
        let mut w = WfmSim::new(1, 4, 900.0, 3);
        let (task, jobs) = w.submit_task(
            vec![JobSpec { inputs: vec![], wall_s: 100.0 }],
            ReleaseMode::Immediate,
        );
        let ev = w.tick(0.0, &all_available);
        assert!(matches!(ev[0], WfmEvent::JobStarted { .. }));
        assert_eq!(w.busy_slots(), 1);
        let ev = w.tick(100.0, &all_available);
        assert!(ev.iter().any(|e| matches!(e, WfmEvent::JobFinished { .. })));
        assert!(ev.iter().any(|e| matches!(e, WfmEvent::TaskDone { task: t, .. } if *t == task)));
        assert_eq!(w.job(jobs[0]).unwrap().attempts, 1);
    }

    #[test]
    fn missing_input_burns_attempts_until_exhausted() {
        let mut w = WfmSim::new(1, 4, 10.0, 3);
        let (_, jobs) = w.submit_task(
            vec![JobSpec { inputs: vec![99], wall_s: 100.0 }],
            ReleaseMode::Immediate,
        );
        let ev = w.tick(0.0, &none_available);
        assert!(matches!(ev[0], WfmEvent::JobAttemptFailed { attempt: 1, .. }));
        let ev = w.tick(10.0, &none_available);
        assert!(matches!(ev[0], WfmEvent::JobAttemptFailed { attempt: 2, .. }));
        let ev = w.tick(20.0, &none_available);
        assert!(ev.iter().any(|e| matches!(e, WfmEvent::JobExhausted { .. })));
        assert!(ev.iter().any(|e| matches!(e, WfmEvent::TaskDone { .. })));
        assert_eq!(w.job(jobs[0]).unwrap().state, JobState::Exhausted);
        assert_eq!(w.failed_attempts, 3);
    }

    #[test]
    fn input_arriving_between_attempts_lets_job_run() {
        let mut w = WfmSim::new(1, 4, 10.0, 6);
        let (_, jobs) = w.submit_task(
            vec![JobSpec { inputs: vec![7], wall_s: 50.0 }],
            ReleaseMode::Immediate,
        );
        w.tick(0.0, &none_available); // attempt 1 fails
        let ev = w.tick(10.0, &all_available); // retry succeeds
        assert!(matches!(ev[0], WfmEvent::JobStarted { .. }));
        let ev = w.tick(60.0, &all_available);
        assert!(ev.iter().any(|e| matches!(e, WfmEvent::JobFinished { .. })));
        assert_eq!(w.job(jobs[0]).unwrap().attempts, 2);
    }

    #[test]
    fn triggered_jobs_wait_for_release() {
        let mut w = WfmSim::new(1, 4, 10.0, 3);
        let (_, jobs) = w.submit_task(
            vec![JobSpec { inputs: vec![], wall_s: 10.0 }],
            ReleaseMode::Triggered,
        );
        assert!(w.tick(0.0, &all_available).is_empty());
        assert_eq!(w.job(jobs[0]).unwrap().state, JobState::Waiting);
        assert_eq!(w.release_jobs(&jobs), 1);
        assert_eq!(w.release_jobs(&jobs), 0, "double release is a no-op");
        let ev = w.tick(1.0, &all_available);
        assert!(matches!(ev[0], WfmEvent::JobStarted { .. }));
    }

    #[test]
    fn slots_bound_parallelism() {
        let mut w = WfmSim::new(2, 2, 10.0, 3); // 4 slots total
        let specs = (0..10)
            .map(|_| JobSpec { inputs: vec![], wall_s: 100.0 })
            .collect();
        w.submit_task(specs, ReleaseMode::Immediate);
        let ev = w.tick(0.0, &all_available);
        let started = ev
            .iter()
            .filter(|e| matches!(e, WfmEvent::JobStarted { .. }))
            .count();
        assert_eq!(started, 4);
        assert_eq!(w.busy_slots(), 4);
        assert_eq!(w.queued_len(), 6);
        // when the first wave finishes, the next 4 start
        let ev = w.tick(100.0, &all_available);
        let started = ev
            .iter()
            .filter(|e| matches!(e, WfmEvent::JobStarted { .. }))
            .count();
        assert_eq!(started, 4);
    }

    #[test]
    fn attempt_histogram_shape() {
        let mut w = WfmSim::new(1, 8, 5.0, 6);
        // 3 jobs with inputs available, 2 without (they'll retry twice then
        // we make data available)
        w.submit_task(
            (0..3).map(|_| JobSpec { inputs: vec![], wall_s: 1.0 }).collect(),
            ReleaseMode::Immediate,
        );
        w.submit_task(
            (0..2).map(|_| JobSpec { inputs: vec![1], wall_s: 1.0 }).collect(),
            ReleaseMode::Immediate,
        );
        let avail_after = |cut: f64, now: f64| move |_f: FileId| now >= cut;
        w.tick(0.0, &avail_after(10.0, 0.0));
        w.tick(5.0, &avail_after(10.0, 5.0));
        w.tick(10.0, &avail_after(10.0, 10.0));
        w.tick(20.0, &all_available);
        let h = w.attempt_histogram();
        // 3 jobs: 1 attempt; 2 jobs: 3 attempts
        assert!(h.contains(&(1, 3)), "{h:?}");
        assert!(h.contains(&(3, 2)), "{h:?}");
    }

    #[test]
    fn next_event_time_tracks_running_and_retrying() {
        let mut w = WfmSim::new(1, 2, 7.0, 3);
        w.submit_task(vec![JobSpec { inputs: vec![], wall_s: 100.0 }], ReleaseMode::Immediate);
        w.submit_task(vec![JobSpec { inputs: vec![1], wall_s: 1.0 }], ReleaseMode::Immediate);
        w.tick(0.0, &|f| f != 1);
        // running finishes at 100, retry ready at 7 -> next event 7
        assert_eq!(w.next_event_time(), Some(7.0));
    }
}
